"""Paper Table II/III + Problem 1 + the metadata-cost observation.

  * verifies the 45-pattern table structure,
  * solves Problem 1 for representative precision distributions under the
    P4/P8/P45 hardware subsets (vector counts + capacity),
  * reproduces the metadata argument (Obs. 4): 3 ints/layer for
    segment-contiguous precisions vs ~1-2 extra bits/element for
    per-element precision tags (paper: Huffman-coded tags grew a ResNet
    layer by 66.4%).
"""
from __future__ import annotations

import numpy as np

from repro.core import patterns
from . import _common


def entropy_bits(ps):
    ps = np.asarray(ps, np.float64)
    ps = ps[ps > 0]
    return float(-(ps * np.log2(ps)).sum())


def run():
    rows = []
    # representative trained distribution (≈ paper Fig. 9 late layers):
    dists = {"early": (0.7, 0.25, 0.05), "mid": (0.45, 0.35, 0.2),
             "late": (0.1, 0.3, 0.6)}
    n_elems = 128 * 64
    for dname, (f4, f2, f1) in dists.items():
        n4, n2, n1 = (int(n_elems * f) for f in (f4, f2, f1))
        for np_pat in (4, 8, 45):
            sol = patterns.solve_problem1(
                n4, n2, n1, patterns.patterns_for(np_pat))
            rows.append((f"problem1.{dname}.P{np_pat}",
                         {"vectors": sol.num_vectors,
                          "avg_bits": (4 * sol.capacity[0]
                                       + 2 * sol.capacity[1]
                                       + sol.capacity[2])
                          / max(sum(sol.capacity), 1)}))
        # metadata cost: segment metadata = 3 ints = 96 bits/layer vs
        # per-element precision tags >= entropy(dist) bits/element.
        tag_bits = entropy_bits([f4, f2, f1]) * n_elems
        payload = (4 * n4 + 2 * n2 + n1)
        rows.append((f"metadata.{dname}",
                     {"segment_bits": 96,
                      "per_elem_tag_bits": int(tag_bits),
                      "overhead_pct": 100.0 * tag_bits / payload}))
    return rows


def main():
    rows, us = _common.timed(run)
    for name, r in rows:
        _common.csv_row(f"table2.{name}", us / len(rows),
                        "|".join(f"{k}={v:.3f}" if isinstance(v, float)
                                 else f"{k}={v}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
