"""Typed SONIQ lifecycle phases (see DESIGN.md §9).

The paper's pipeline is a *lifecycle*:

    FP ──► NOISE ──► QAT ──► SERVE
    (baseline)  Phase I     Phase II    packed deployment

Historically the repo encoded the current phase as ``QuantConfig.mode``
(a string) and branched on it inside every layer primitive. This module
makes the phase a first-class object: each :class:`PhaseSpec` singleton
carries

  * its *param schema* — which arrays a quantized SmolLinear leaf holds in
    that phase (``param_schema`` returns ShapeDtypeStructs, usable for
    eval_shape / dry-run sharding without allocation),
  * its *apply rules* — the forward implementations layer libraries
    register against it (``defrule`` / ``rule``), so dispatch is by phase
    identity rather than string comparison,
  * lifecycle metadata (``trainable``, ``needs_rng``, ``next`` — the legal
    forward transition).

The public lifecycle transforms between phases live in
``repro.api.transforms`` (``soniq.to_qat`` / ``soniq.to_serve``); this
module stays dependency-light so every core/model module can import it.

The rules themselves are backend-polymorphic: each registered rule builds
its forward from the kernel-backend ops (``repro.backend``), resolved per
``QuantConfig`` at trace time — the phase registry here decides *what* to
compute for a leaf, the backend registry decides *which kernels* compute
it (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# (phase name, primitive name) -> apply rule. Filled by the layer libraries
# (repro.core.smol registers the "linear" rules at import time).
_RULES: Dict[Tuple[str, str], Callable] = {}


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class PhaseSpec:
    """One lifecycle phase. Singletons live on :class:`Phase`."""

    name: str                      # the legacy QuantConfig.mode string
    index: int                     # position in the lifecycle (FP=0 .. SERVE=3)
    trainable: bool                # does the phase support a backward pass?
    needs_rng: bool                # does apply() consume an rng (noise draw)?
    # Keys (beyond "w"/"b") that mark a quantized linear leaf as belonging
    # to this phase.
    learned_keys: Tuple[str, ...]

    def __repr__(self) -> str:
        return f"Phase.{self.name.upper()}"

    def __eq__(self, other) -> bool:
        # Phases are singletons, but legacy callers compare against the
        # mode string ("noise" == Phase.NOISE); keep that contract.
        if isinstance(other, str):
            return self.name == other
        return self is other

    def __hash__(self) -> int:
        return hash(self.name)      # consistent with the string equality

    # ------------------------------------------------------ apply rules ----
    def defrule(self, prim: str):
        """Decorator: register the forward implementation of ``prim``
        (e.g. "linear") for this phase."""
        def deco(fn):
            _RULES[(self.name, prim)] = fn
            return fn
        return deco

    def rule(self, prim: str) -> Callable:
        try:
            return _RULES[(self.name, prim)]
        except KeyError:
            raise NotImplementedError(
                f"no '{prim}' apply rule registered for {self!r}") from None

    # ----------------------------------------------------- param schema ----
    def param_schema(self, k: int, n: int, qcfg, *, use_bias: bool = False,
                    dtype=jnp.float32) -> Dict:
        """ShapeDtypeStruct stand-ins for a [K, N] quantized linear in this
        phase (no allocation). ``qcfg`` is a :class:`QuantConfig`; group
        geometry comes from it (single source of truth)."""
        sd = jax.ShapeDtypeStruct
        out: Dict = {}
        if self.name != "serve":
            out["w"] = sd((k, n), dtype)
        if self.name == "noise":
            out["s"] = sd((qcfg.num_groups(k),), jnp.float32)
        elif self.name == "qat":
            out["pbits"] = sd((qcfg.num_groups(k),), jnp.int8)
        elif self.name == "serve":
            k4, k2, k1 = qcfg.segments(k)
            ng = qcfg.num_groups(k)
            out.update({
                "w4": sd((k4 // 2, n), jnp.uint8),
                "w2": sd((k2 // 4, n), jnp.uint8),
                "w1": sd((k1 // 8, n), jnp.uint8),
                "perm": sd((k,), jnp.int32),
                "pbits_sorted": sd((ng,), jnp.int8),
                "wscale": None if qcfg.scale_mode == "none"
                          else sd((ng,), jnp.float32),
            })
        if use_bias:
            out["b"] = sd((n,), dtype)
        return out

    def owns_leaf(self, leaf) -> bool:
        """Does this params dict look like a quantized linear of this phase?
        (FP matches a plain-weight leaf with no learned quant state.)"""
        if not isinstance(leaf, dict):
            return False
        if self.name == "fp":
            return "w" in leaf and not any(
                k in leaf for p in Phase.ALL for k in p.learned_keys)
        return all(k in leaf for k in self.learned_keys)

    @property
    def next(self) -> Optional["PhaseSpec"]:
        """The legal forward transition, or None for the terminal phase."""
        order = Phase.ALL
        return order[self.index + 1] if self.index + 1 < len(order) else None


class Phase:
    """Namespace of the four lifecycle phase singletons."""

    FP = PhaseSpec("fp", 0, trainable=True, needs_rng=False,
                   learned_keys=())
    NOISE = PhaseSpec("noise", 1, trainable=True, needs_rng=True,
                      learned_keys=("s",))
    QAT = PhaseSpec("qat", 2, trainable=True, needs_rng=False,
                    learned_keys=("pbits",))
    SERVE = PhaseSpec("serve", 3, trainable=False, needs_rng=False,
                      learned_keys=("w4", "w2", "w1", "perm",
                                    "pbits_sorted"))

    ALL: Tuple[PhaseSpec, ...] = ()        # filled below

    @staticmethod
    def from_mode(mode) -> PhaseSpec:
        """Coerce a mode string (or a PhaseSpec, passed through) to the
        phase singleton."""
        if isinstance(mode, PhaseSpec):
            return mode
        try:
            return _BY_NAME[mode]
        except KeyError:
            raise ValueError(
                f"unknown phase {mode!r}; expected one of "
                f"{sorted(_BY_NAME)}") from None


Phase.ALL = (Phase.FP, Phase.NOISE, Phase.QAT, Phase.SERVE)
_BY_NAME = {p.name: p for p in Phase.ALL}
