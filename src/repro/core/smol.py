"""SmolLinear — the universal quantized linear primitive.

Every matmul in every model in this framework goes through ``linear_apply``.
The ``QuantConfig.mode`` selects:

  fp     y = x @ W                                  (baseline)
  noise  Phase I:  y = (x + sx*sigma(s)*eps) @ clip(W + sw*sigma(s)*eps')
  qat    Phase II: y = fq(x; p, sx) @ fq(W; p, sw)  (clipped STE)
  serve  y = q(x) @ unpack_dequant(Wpacked)         (packed 1/2/4-bit carriers)

with per-16-channel-group precisions p on the K (input/reduction) dim shared
by weights and activations (paper Obs. 3), segments [K4|K2|K1] contiguous
(paper Obs. 4), and fp32 accumulation (TPU adaptation of the paper's 16.6
fixed-point accumulator).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import noise as noise_lib
from . import pack as pack_lib
from . import patterns as patterns_lib
from . import quant
from .qtypes import QuantConfig


def num_groups(k: int, group_size: int) -> int:
    if k < group_size:
        return 1
    assert k % group_size == 0, (k, group_size)
    return k // group_size


def eff_group_size(k: int, group_size: int) -> int:
    return k if k < group_size else group_size


def init_pbits_from_mix(k: int, qcfg: QuantConfig) -> np.ndarray:
    """Static per-group precisions implementing qcfg.mix, sorted 4 -> 2 -> 1
    (segment-contiguous). Replaced by trained precisions after Phase I."""
    g = eff_group_size(k, qcfg.group_size)
    n = num_groups(k, g)
    g4 = int(round(qcfg.mix[0] * n))
    g2 = int(round(qcfg.mix[1] * n))
    g4 = min(g4, n)
    g2 = min(g2, n - g4)
    return np.array([4] * g4 + [2] * g2 + [1] * (n - g4 - g2), np.int8)


def linear_init(key, k: int, n: int, qcfg: QuantConfig, *,
                use_bias: bool = False, dtype=jnp.float32,
                quantized: bool = True, scale: float = 1.0) -> Dict:
    """Initialize SmolLinear params. ``quantized=False`` for skip layers."""
    wkey, _ = jax.random.split(key)
    std = scale / np.sqrt(k)
    params: Dict = {"w": (jax.random.normal(wkey, (k, n), jnp.float32) * std
                          ).astype(dtype)}
    if use_bias:
        params["b"] = jnp.zeros((n,), dtype)
    if not quantized or qcfg.mode == "fp":
        return params
    g = eff_group_size(k, qcfg.group_size)
    if qcfg.mode == "noise":
        params["s"] = noise_lib.init_s(num_groups(k, g), qcfg.p_init)
    elif qcfg.mode == "qat":
        params["pbits"] = jnp.asarray(init_pbits_from_mix(k, qcfg))
    elif qcfg.mode == "serve":
        # Packed-buffer layout per qcfg.mix (zero codes; real deployments
        # fill these via serve_params_from_qat). Gives eval_shape the exact
        # serve pytree for the dry-run.
        del params["w"]
        k4, k2, k1 = qcfg.segments(k) if k >= qcfg.group_size else (k, 0, 0)
        pbits = init_pbits_from_mix(k, qcfg)
        params.update({
            "w4": jnp.zeros((k4 // 2, n), jnp.uint8),
            "w2": jnp.zeros((k2 // 4, n), jnp.uint8),
            "w1": jnp.zeros((k1 // 8, n), jnp.uint8),
            "perm": jnp.arange(k, dtype=jnp.int32),
            "pbits_sorted": jnp.asarray(pbits),
            "wscale": None if qcfg.scale_mode == "none"
                      else jnp.ones((num_groups(k, g),), jnp.float32),
        })
    return params


def _weight_scales(w, qcfg: QuantConfig, group_size: int):
    if qcfg.scale_mode == "none":
        return jnp.ones((num_groups(w.shape[0], group_size),), jnp.float32)
    return quant.per_group_weight_scale(w, group_size)


def _act_scale(x, qcfg: QuantConfig):
    if qcfg.act_scale_mode == "none":
        return jnp.asarray(1.0, jnp.float32)
    return quant.abs_max_scale(x).astype(jnp.float32)


def _quantize_weight(w, pbits, qcfg: QuantConfig, group_size: int):
    """fake-quant W [K, N] along K with per-group precisions."""
    sw = _weight_scales(w, qcfg, group_size)                  # [K//G]
    wq_t = quant.fake_quant(jnp.swapaxes(w, 0, 1), pbits,
                            sw, group_size)                   # [N, K]
    return jnp.swapaxes(wq_t, 0, 1)


def _quantize_act(x, pbits, qcfg: QuantConfig, group_size: int):
    if not qcfg.quantize_activations:
        return x
    sx = _act_scale(x, qcfg)
    return quant.fake_quant(x, pbits, sx, group_size)


def _matmul(x, w, b=None):
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def linear_apply(params: Dict, x, qcfg: QuantConfig,
                 rng: Optional[jax.Array] = None):
    """x: [..., K] -> [..., N]."""
    b = params.get("b")
    w = params["w"] if "w" in params else None
    mode = qcfg.mode
    if mode != "fp" and w is not None and "s" not in params \
            and "pbits" not in params:
        mode = "fp"  # skip layer: holds only a plain weight

    if mode == "fp":
        return _matmul(x, w, b)

    k = w.shape[0] if w is not None else params["perm"].shape[0]
    g = eff_group_size(k, qcfg.group_size)

    if mode == "noise":
        assert rng is not None, "Phase I needs an rng"
        kw, kx = jax.random.split(rng)
        # Normalize group abs-max to 1.0 (not grid-max 1.875): the Phase-I
        # clip +-(2 - sigma) must not bite below sigma ~= 1, else its loss
        # gradient stalls the precision search at ~sigma 0.27 for every
        # group (the paper's scale-free setting has weights well inside +-2).
        sw = _weight_scales(w, qcfg, g) * float(quant._static_grid_max(4))
        wf = jnp.asarray(w, jnp.float32) / jnp.repeat(
            sw, g, total_repeat_length=k)[:, None]
        wn = noise_lib.inject_weight_noise(wf, params["s"], kw, g)
        wn = (wn * jnp.repeat(sw, g, total_repeat_length=k)[:, None]
              ).astype(x.dtype)
        if qcfg.quantize_activations:
            sx = _act_scale(x, qcfg)
            x = noise_lib.inject_act_noise(x, params["s"], kx, sx, g)
        return _matmul(x, wn, b)

    if mode == "qat":
        pbits = params["pbits"].astype(jnp.float32)
        if qcfg.prequantized:
            wq = w.astype(x.dtype)       # already on the grid (hoisted)
        else:
            wq = _quantize_weight(w, pbits, qcfg, g).astype(x.dtype)
        xq = _quantize_act(x, pbits, qcfg, g)
        return _matmul(xq, wq, b)

    if mode == "serve":
        return _serve_apply(params, x, qcfg, g)

    raise ValueError(mode)


def _serve_apply(params: Dict, x, qcfg: QuantConfig, group_size: int):
    """Packed-weight inference path (pure-jnp emulation of the Pallas
    kernel's arithmetic: uint8 loads -> shift/mask unpack -> affine dequant
    -> bf16 matmul, fp32 accumulate). ``kernels.ops.packed_matmul`` is the
    fused on-TPU version; its HLO byte traffic matches this path's."""
    # Segment sizes are static: recover them from the packed buffer shapes.
    k4 = params["w4"].shape[0] * 2
    k2 = params["w2"].shape[0] * 4
    k1 = params["w1"].shape[0] * 8
    k = k4 + k2 + k1
    x = jnp.take(x, params["perm"], axis=-1)          # channel reordering
    # Dequantize directly in the compute dtype: every SMOL grid value is
    # exactly representable in bf16 (4 mantissa bits suffice), and the fp32
    # intermediate would double the dequant-materialization traffic (§Perf).
    cdt = x.dtype
    parts = []
    for name, p, kp in (("w4", 4, k4), ("w2", 2, k2), ("w1", 1, k1)):
        if kp == 0:
            continue
        u = pack_lib.unpack_codes(params[name], p, kp).astype(cdt)
        wd_p = (2.0 * u - jnp.asarray(2 ** p - 1, cdt)) \
            * jnp.asarray(2.0 ** (1 - p), cdt)
        parts.append(wd_p)
    wd = jnp.concatenate(parts, axis=0)
    if params.get("wscale") is not None:
        s_full = jnp.repeat(params["wscale"].astype(cdt), group_size,
                            total_repeat_length=k)
        wd = wd * s_full[:, None]
    if qcfg.quantize_activations:
        pbits = params["pbits_sorted"].astype(jnp.float32)
        sx = _act_scale(x, qcfg)
        x = quant.fake_quant(x, pbits, sx, group_size)
    y = _matmul(x, wd, params.get("b"))
    return y


def prequantize_tree(params, qcfg: QuantConfig, compute_dtype=jnp.bfloat16):
    """Fake-quantize every (w, pbits) weight in the tree ONCE (per step),
    casting to the compute dtype. Differentiable: wrap in jax.vjp at the
    call site so the microbatch scan consumes already-quantized weights and
    the quantize backward runs once (§Perf 'hoisted weight quantization').
    Handles stacked scan/expert leading dims via vmap."""
    def fix(node):
        if not (isinstance(node, dict) and "w" in node and "pbits" in node):
            return node
        node = dict(node)
        w, pbits = node["w"], node["pbits"]
        g = eff_group_size(w.shape[-2], qcfg.group_size)

        def q2d(w2, pb):
            return _quantize_weight(w2, pb.astype(jnp.float32), qcfg, g)

        fn = q2d
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        node["w"] = fn(w, pbits).astype(compute_dtype)
        return node
    return _tree_map_dicts(fix, params)


def serve_params_from_qat(params: Dict, qcfg: QuantConfig) -> Dict:
    """Offline deploy conversion: trained (w, pbits) -> channel-reordered
    packed buffers + metadata. The returned dict is a valid SmolLinear
    "serve" params pytree."""
    w = np.asarray(params["w"], np.float32)
    pbits = np.asarray(params["pbits"])
    k, n = w.shape
    g = eff_group_size(k, qcfg.group_size)
    gperm = patterns_lib.reorder_channels(pbits)
    perm = patterns_lib.expand_group_perm(gperm, g)
    w_sorted = w[perm]
    pbits_sorted = pbits[gperm]
    if qcfg.scale_mode == "none":
        scales = None
    else:
        scales = np.asarray(quant.per_group_weight_scale(
            jnp.asarray(w_sorted), g))
    packed = pack_lib.quantize_pack_weight(jnp.asarray(w_sorted),
                                           pbits_sorted, scales, g)
    out = {
        "w4": packed["w4"], "w2": packed["w2"], "w1": packed["w1"],
        "perm": jnp.asarray(perm, jnp.int32),
        "pbits_sorted": jnp.asarray(pbits_sorted),
        "wscale": None if scales is None else jnp.asarray(scales),
    }
    if "b" in params:
        out["b"] = params["b"]
    return out


def serve_param_specs(k: int, n: int, qcfg: QuantConfig, *,
                      use_bias: bool = False, dtype=jnp.float32) -> Dict:
    """ShapeDtypeStruct stand-ins for a serve-mode SmolLinear — used by the
    multi-pod dry-run (no allocation)."""
    k4, k2, k1 = qcfg.segments(k) if k >= qcfg.group_size else (k, 0, 0)
    g = eff_group_size(k, qcfg.group_size)
    sd = jax.ShapeDtypeStruct
    out = {
        "w4": sd((k4 // 2, n), jnp.uint8),
        "w2": sd((k2 // 4, n), jnp.uint8),
        "w1": sd((k1 // 8, n), jnp.uint8),
        "perm": sd((k,), jnp.int32),
        "pbits_sorted": sd((num_groups(k, g),), jnp.int8),
        "wscale": None if qcfg.scale_mode == "none"
                  else sd((num_groups(k, g),), jnp.float32),
    }
    if use_bias:
        out["b"] = sd((n,), dtype)
    return out


def bit_penalty_of_params(params) -> jnp.ndarray:
    """Sum the Phase-I bit regularizer over every ``s`` leaf in a pytree."""
    total = jnp.asarray(0.0, jnp.float32)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if path and getattr(path[-1], "key", None) == "s":
            total = total + noise_lib.bit_penalty(leaf)
    return total


def project_noise_weights(params, qcfg: QuantConfig):
    """Post-optimizer projection (paper Alg. 1 line 7) applied to every
    (w, s) pair in a pytree of SmolLinear params. Handles stacked scan /
    expert leading dims via vmap."""
    def fix(node):
        if isinstance(node, dict) and "s" in node and "w" in node:
            node = dict(node)
            w = node["w"]
            k = w.shape[-2]
            g = eff_group_size(k, qcfg.group_size)

            def proj2d(w2, s1):
                sw = _weight_scales(w2, qcfg, g)
                sfull = jnp.repeat(sw, g, total_repeat_length=k)[:, None]
                lim = noise_lib.clip_weights(
                    jnp.asarray(w2, jnp.float32) / sfull, s1, g)
                return (lim * sfull).astype(w2.dtype)

            fn = proj2d
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            node["w"] = fn(w, node["s"])
            return node
        return node
    return _tree_map_dicts(fix, params)


def _tree_map_dicts(fn, tree):
    if isinstance(tree, dict):
        new = fn(tree)
        if new is not tree:
            return new
        return {k: _tree_map_dicts(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_tree_map_dicts(fn, v) for v in tree)
    return tree
