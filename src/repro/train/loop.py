"""The training loop: two-phase SONIQ orchestration + checkpoint/restart.

Drives train_step; at step t1 it runs the Phase I -> Phase II boundary
(Problem-1 solve + PatternMatch + precision freeze) on host, swaps the
QuantConfig mode, and re-jits. Checkpoints periodically (async) and resumes
from the latest checkpoint if one exists (crash tolerance — exercised by
tests/test_fault_tolerance.py through SIGKILL).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.api import transforms as soniq
from repro.core.phases import Phase
from repro.optim import adamw
from . import checkpoint as ckpt_lib
from . import state as state_lib


def train(arch_cfg, tcfg: state_lib.TrainConfig,
          batches: Iterator[Dict], *,
          hooks: Optional[List[Callable]] = None,
          host_id: int = 0) -> Dict:
    """Runs Phase I + boundary + Phase II for tcfg.t2 steps total.
    Returns {"state", "history", "pattern_report"}."""
    hooks = hooks or []
    key = jax.random.PRNGKey(tcfg.seed)
    noise_cfg = arch_cfg.with_quant_mode(Phase.NOISE)
    qat_cfg = arch_cfg.with_quant_mode(Phase.QAT)

    start_step = 0
    pattern_report = None
    state = None
    in_phase1 = tcfg.t1 > 0
    if tcfg.ckpt_dir:
        try:
            latest = ckpt_lib.latest_step(tcfg.ckpt_dir)
        except Exception:
            latest = None
        if latest is not None:
            # Checkpoints are written post-step, pre-boundary: a checkpoint
            # labeled exactly t1 still holds Phase-I (noise) params.
            in_phase1 = latest <= tcfg.t1 and tcfg.t1 > 0
            cfg_now = noise_cfg if in_phase1 else qat_cfg
            template = state_lib.init_state(key, cfg_now, tcfg)
            state, start_step = ckpt_lib.restore(tcfg.ckpt_dir, template,
                                                 host_id=host_id)
    if state is None:
        state = state_lib.init_state(key, noise_cfg if tcfg.t1 > 0
                                     else qat_cfg, tcfg)

    def make_step(cfg):
        return jax.jit(lambda s, b, r: state_lib.train_step(s, b, cfg,
                                                            tcfg, r))

    step_fn = make_step(noise_cfg if in_phase1 else qat_cfg)
    history = []
    step = start_step
    while step < tcfg.t2:
        if step == tcfg.t1 and in_phase1:
            # ---- Phase I -> Phase II boundary (host-side) ----
            params, pattern_report = soniq.freeze_qat(
                jax.device_get(state["params"]), arch_cfg.quant)
            state["params"] = params
            state["opt"] = adamw.init_state(params)   # fresh moments
            step_fn = make_step(qat_cfg)
            in_phase1 = False

        batch = next(batches)
        t0 = time.time()
        rng = jax.random.fold_in(jax.random.PRNGKey(tcfg.seed + 1), step)
        state, metrics = step_fn(state, batch, rng)
        metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
        metrics.update(step=step, wall=time.time() - t0,
                       phase=1 if step < tcfg.t1 else 2)
        history.append(metrics)
        for h in hooks:
            h(step, state, metrics)
        step += 1
        if tcfg.ckpt_dir and step % tcfg.checkpoint_every == 0:
            ckpt_lib.async_save(state, tcfg.ckpt_dir, step,
                                host_id=host_id).join()
    return {"state": state, "history": history,
            "pattern_report": pattern_report}
