"""Counter-based in-kernel PRNG (xxhash-style avalanche).

``pltpu.prng_random_bits`` has no CPU interpret lowering, so the Phase-I
noise kernel derives its randomness from a stateless integer hash of
(global element index, seed). The same function runs inside the Pallas
kernel and in the pure-jnp oracle, so kernel vs. ref comparisons are exact,
and the kernel is bit-identical between interpret mode and real TPU.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_C1 = np.uint32(0x9E3779B1)
_C2 = np.uint32(0x85EBCA77)
_C3 = np.uint32(0xC2B2AE3D)


def hash_u32(idx, seed):
    """Avalanche hash: uint32 index x uint32 seed -> uint32."""
    h = idx.astype(jnp.uint32) * _C1 + jnp.asarray(seed, jnp.uint32)
    h = h ^ (h >> np.uint32(15))
    h = h * _C2
    h = h ^ (h >> np.uint32(13))
    h = h * _C3
    h = h ^ (h >> np.uint32(16))
    return h


def uniform_pm1(idx, seed):
    """Deterministic U[-1, 1) from (index, seed), float32."""
    bits = hash_u32(idx, seed) >> np.uint32(8)       # 24 mantissa-safe bits
    return bits.astype(jnp.float32) * np.float32(2.0 / (1 << 24)) - 1.0
