"""Re-export of the typed lifecycle phases at the façade level.

The implementation lives in ``repro.core.phases`` (dependency-light so
every core/model module can import it); the public import path is

    from repro import soniq
    soniq.Phase.QAT
"""
from repro.core.phases import Phase, PhaseSpec  # noqa: F401

__all__ = ["Phase", "PhaseSpec"]
