"""Serve engines over packed SONIQ weights (DESIGN.md §10).

Two engines share the packed-weight serve path (``soniq.to_serve`` /
``repro.api.transforms.convert_tree``: per-layer precisions re-budgeted to
the static segment mix, channels reordered (paper Obs. 4), codes
bit-packed into 1/2/4-bit carriers):

* :class:`LockstepEngine` — the original fixed-batch loop: one blocking
  ``generate()`` call, full-batch prefill, every row decodes until the
  longest request finishes. Kept as the parity/throughput baseline.
* :class:`DecodeEngine` — request-level **continuous batching**: an
  admission queue of :class:`repro.serve.scheduler.Request`, slot-based
  batch state, chunked prefill that fills idle slots while other slots
  decode, per-slot sampling params (temperature + seeded rng), and a
  streaming iterator returning :class:`Completion` objects as requests
  finish. Per-slot rows are independent, so its temperature-0 tokens are
  identical to the lockstep engine's (pinned by
  ``tests/test_serve_scheduler.py``).

``rebudget_pbits`` / ``serve_convert`` are deprecation shims kept for
external callers; the implementations moved to ``repro.api.transforms``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Iterable, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import transforms as lifecycle
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig
from repro.models import lm

from .scheduler import Completion, Request, Scheduler


def rebudget_pbits(pbits: np.ndarray, w: np.ndarray,
                   qcfg: QuantConfig) -> np.ndarray:
    """DEPRECATED — moved to ``repro.api.transforms.rebudget_pbits``."""
    warnings.warn(
        "engine.rebudget_pbits is deprecated; use "
        "repro.api.transforms.rebudget_pbits (soniq.rebudget_pbits)",
        DeprecationWarning, stacklevel=2)
    return lifecycle.rebudget_pbits(pbits, w, qcfg)


def serve_convert(params, qcfg: QuantConfig):
    """DEPRECATED — use ``soniq.to_serve`` (or the pytree-level
    ``repro.api.transforms.convert_tree``)."""
    warnings.warn(
        "engine.serve_convert is deprecated; use soniq.to_serve / "
        "repro.api.transforms.convert_tree",
        DeprecationWarning, stacklevel=2)
    return lifecycle.convert_tree(params, qcfg, rebudget=True)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 256
    temperature: float = 0.0        # 0 = greedy (default for generate())
    cache_dtype: str = "float32"
    # Prompt tokens fed per slot per prefill step (1 = token-level prefill;
    # auto-reduced to 1 for SSM/hybrid/enc-dec archs, which need strictly
    # sequential state updates — see lm.supports_chunked_prefill).
    prefill_chunk: int = 8
    # Kernel backend for the jitted decode/prefill steps — a registry name
    # ("xla_ref", "pallas_interpret", "pallas_mosaic", alias "pallas") or
    # None to keep the model config's choice / SONIQ_BACKEND / negotiation
    # (repro.backend.registry; DESIGN.md §11). Baked into QuantConfig at
    # engine construction, so it is jit-trace-stable.
    backend: Optional[str] = None
    # Allow the backend to fuse the per-decode-step activation quantization
    # into the packed-GEMM prologue (bit-exact with the two-pass form —
    # DESIGN.md §11). False pins the two-pass reference; benchmarks flip
    # this to record the fused-vs-unfused delta.
    fuse_act_quant: bool = True
    # KV-cache precision (DESIGN.md §12). None = fp ring cache in
    # ``cache_dtype`` (status quo); 4 = packed 4-bit ring cache
    # (serve/kv_quant.py): ~4x fewer K/V payload bytes, decode attention
    # runs on the backend's ``qkv_attn_decode`` op (fused flash-decode
    # kernel on Pallas). Greedy tokens stay engine- and backend-parity at
    # q4; they differ from kv_bits=None by the pinned KV round-trip error.
    kv_bits: Optional[int] = None


class _PackedEngine:
    """Shared packed-params + jitted-step plumbing of both engines."""

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        self.cfg = arch_cfg.with_quant_mode(Phase.SERVE)
        if ecfg.backend is not None:
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, backend=ecfg.backend))
        if not ecfg.fuse_act_quant:
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, fuse_act_quant=False))
        if self.cfg.quant.act_scale_mode == "per_tensor":
            # Per-tensor dynamic act scales couple batch rows; serving needs
            # every request's tokens independent of batch composition
            # (continuous batching + lockstep parity), so the engines run
            # the row-independent per-token scale (DESIGN.md §10).
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, act_scale_mode="per_token"))
        self.ecfg = ecfg
        self.params = params if already_serve else lifecycle.convert_tree(
            params, self.cfg.quant, rebudget=True)
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, self.cfg, c, t, pos))

    def init_cache(self, batch: int):
        return lm.init_cache(self.cfg, batch, self.ecfg.cache_len,
                             jnp.dtype(self.ecfg.cache_dtype),
                             kv_bits=self.ecfg.kv_bits)


class LockstepEngine(_PackedEngine):
    """Fixed-batch generation loop (greedy / shared-rng temperature
    sampling): the pre-continuous-batching baseline. Every row prefills and
    decodes in lockstep, so mixed-length batches burn full decode steps on
    rows that are already finished — `benchmarks/serve_throughput.py`
    quantifies the gap."""

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """prompts [B, S0] int32 -> [B, S0 + max_new] (greedy unless
        temperature > 0)."""
        b, s0 = prompts.shape
        cache = self.init_cache(b)
        toks = jnp.asarray(prompts, jnp.int32)
        out = [toks]
        logits = None
        for t in range(s0):
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = self._step(self.params, cache, toks[:, t], pos)
        cur = self._sample(logits, rng, 0)
        for t in range(max_new_tokens):
            out.append(cur[:, None])
            if t == max_new_tokens - 1:
                break
            pos = jnp.full((b,), s0 + t, jnp.int32)
            logits, cache = self._step(self.params, cache, cur, pos)
            cur = self._sample(logits, rng, t + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _sample(self, logits, rng, t):
        if self.ecfg.temperature <= 0 or rng is None:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        k = jax.random.fold_in(rng, t)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature).astype(jnp.int32)


def _key_bits(key) -> np.ndarray:
    """Raw uint32 bits of a PRNG key (accepts legacy raw or typed keys)."""
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, np.uint32)


def _sample_tokens(logits, keys, temps, counts):
    """Per-slot sampling: greedy where temp <= 0, else categorical with the
    slot's request key folded by its generated-token index (scheduling-
    invariant: request i's t-th token always uses fold_in(key_i, t))."""
    def one(lg, key, temp, n):
        greedy = jnp.argmax(lg, -1).astype(jnp.int32)
        k = jax.random.fold_in(key, n)
        samp = jax.random.categorical(
            k, lg / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        return jnp.where(temp > 0, samp, greedy)
    return jax.vmap(one)(logits, keys, temps, counts)


class DecodeEngine(_PackedEngine):
    """Request-level continuous-batching engine (DESIGN.md §10).

    Usage — streaming::

        eng = DecodeEngine(params, cfg, EngineConfig(max_batch=8))
        for completion in eng.serve(requests):   # yields as they finish
            ...

    or incremental (``submit`` / ``step``) for request loops that interleave
    admission with other work. ``generate()`` is a lockstep-compatible
    wrapper (same-shape prompts in, stacked tokens out) used by the legacy
    callers; at temperature 0 it returns exactly the lockstep tokens.
    """

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        super().__init__(params, arch_cfg, ecfg,
                         already_serve=already_serve)
        self.chunk = (ecfg.prefill_chunk
                      if lm.supports_chunked_prefill(self.cfg) else 1)
        b = ecfg.max_batch

        # Sampling is fused into the jitted step: one dispatch and one
        # [B]-int transfer per engine step (the decode loop is host-latency
        # bound at small batch).
        def decode_sample(p, c, t, pos, act, keys, temps, counts):
            logits, c2 = lm.decode_step(p, self.cfg, c, t, pos, active=act)
            return _sample_tokens(logits, keys, temps, counts), c2

        def prefill_sample(p, c, t, pos, last, keys, temps, counts):
            logits, c2 = lm.prefill_step(p, self.cfg, c, t, pos, last)
            return _sample_tokens(logits, keys, temps, counts), c2

        self._decode = jax.jit(decode_sample)
        self._prefill = jax.jit(prefill_sample)
        # One compiled reset for any admission set: idx is padded to
        # max_batch by repeating the first slot (re-wiping a row is
        # idempotent), so eager per-admission scatters never compile.
        self._reset = jax.jit(lm.reset_cache_slots)
        self.sched = Scheduler(b)
        self.cache = None
        self._keys = np.zeros((b, 2), np.uint32)
        self._temps = np.zeros((b,), np.float32)

    # --------------------------------------------------------- requests ----
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request_id."""
        return self.sched.submit(request)

    def reset(self):
        """Drop all queued/active requests and cache state."""
        self.sched = Scheduler(self.ecfg.max_batch)
        self.cache = None

    # ------------------------------------------------------------- step ----
    def step(self) -> List[Completion]:
        """One engine step: admit arrived requests into free slots (wiping
        their cache rows), feed every active slot (chunked prefill for
        prompt-phase slots, one token for decode-phase slots), sample, and
        return any completions (their slots free up for the next step)."""
        b = self.ecfg.max_batch
        if self.cache is None:
            self.cache = self.init_cache(b)
        admitted = self.sched.admit()
        if admitted:
            idx = np.full((b,), admitted[0][0], np.int32)
            idx[:len(admitted)] = [s for s, _ in admitted]
            self.cache = self._reset(self.cache, idx)
            for slot, req in admitted:
                self._keys[slot] = _key_bits(jax.random.PRNGKey(req.seed))
                self._temps[slot] = req.temperature
        plan = self.sched.plan(self.chunk)
        if not plan:                       # idle: let queued arrivals age in
            return self.sched.advance({}, {})
        widths = {s: len(t) for s, t in plan.items()}
        counts = np.zeros((b,), np.int32)
        for slot in plan:
            counts[slot] = len(self.sched.slots[slot].generated)
        if max(widths.values()) > 1:
            c = self.chunk                 # fixed width: one compiled shape
            tokens = np.zeros((b, c), np.int32)
            pos = np.full((b, c), -1, np.int32)
            last = np.zeros((b,), np.int32)
            for slot, toks in plan.items():
                n = widths[slot]
                st = self.sched.slots[slot]
                tokens[slot, :n] = toks
                pos[slot, :n] = st.n_fed + np.arange(n)
                last[slot] = n - 1
            out, self.cache = self._prefill(self.params, self.cache,
                                            tokens, pos, last, self._keys,
                                            self._temps, counts)
        else:
            tokens = np.zeros((b,), np.int32)
            pos = np.zeros((b,), np.int32)
            active = np.zeros((b,), bool)
            for slot, toks in plan.items():
                tokens[slot] = toks[0]
                pos[slot] = self.sched.slots[slot].n_fed
                active[slot] = True
            out, self.cache = self._decode(self.params, self.cache,
                                           tokens, pos, active, self._keys,
                                           self._temps, counts)
        sampled = np.asarray(out)
        return self.sched.advance(
            widths, {s: int(sampled[s]) for s in plan})

    # -------------------------------------------------------- streaming ----
    def run(self) -> Iterator[Completion]:
        """Drive steps until queue and slots drain, yielding completions in
        finish order."""
        while self.sched.has_work():
            yield from self.step()

    def serve(self, requests: Iterable[Request]) -> Iterator[Completion]:
        """Submit all requests, then stream completions."""
        for r in requests:
            self.submit(r)
        return self.run()

    # ------------------------------------------------------------ compat ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """Lockstep-compatible batch call: same-length prompts [B, S0] ->
        stacked [B, S0 + max_new]. Resets any in-flight engine state.
        Greedy unless the engine temperature > 0 AND ``rng`` is given (the
        per-request seeds are then derived from ``rng``; the stream is
        reproducible but not bitwise-identical to lockstep sampling, which
        shares one rng across the batch)."""
        self.reset()
        prompts = np.asarray(prompts, np.int32)
        temp = self.ecfg.temperature if rng is not None else 0.0
        base = int(_key_bits(rng).ravel()[-1]) if rng is not None else 0
        reqs = [Request(prompt=p, max_new_tokens=max_new_tokens,
                        temperature=temp, seed=base + i)
                for i, p in enumerate(prompts)]
        out = {c.request_id - reqs[0].request_id: c.tokens
               for c in self.serve(reqs)}
        return np.stack([out[i] for i in range(len(reqs))])


# Leaf-name vocabulary for packed_model_bytes. Packed carriers count one
# byte per element; fp leaves count their dtype itemsize; metadata leaves
# (permutations / precision maps — the paper's "3 ints per layer" lives in
# buffer shapes, not here) are excluded from the network-size metric.
_PACKED_LEAVES = frozenset({"w4", "w2", "w1"})
_FP_LEAVES = frozenset({"w", "table", "wscale", "b", "g", "conv_w",
                        "conv_b", "A_log", "D", "dt_bias", "norm_g"})
_META_LEAVES = frozenset({"perm", "pbits_sorted", "pbits", "s"})


def packed_model_bytes(serve_params) -> int:
    """Total packed weight bytes (the paper's network-size metric).

    Every leaf name must be classified (packed carrier / fp weight /
    metadata); an unknown name raises ``ValueError`` instead of being
    silently skipped — a renamed carrier leaf must not make the metric
    under-report."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(serve_params)[0]:
        if leaf is None:
            continue
        name = str(getattr(path[-1], "key", ""))
        if name in _PACKED_LEAVES:
            total += leaf.size
        elif name in _FP_LEAVES:
            total += leaf.size * np.dtype(leaf.dtype).itemsize
        elif name not in _META_LEAVES:
            raise ValueError(
                f"packed_model_bytes: unknown leaf {jax.tree_util.keystr(path)!r}"
                f" (name {name!r}) — classify it in engine._PACKED_LEAVES/"
                "_FP_LEAVES/_META_LEAVES so the size metric stays honest")
    return int(total)
