"""Fused quantized-KV flash-decode attention (serve hot path, DESIGN §12).

One (batch row, kv-head) per program: q [SG, D] (S decode/prefill tokens x
G grouped query heads) attends over the slot's full ring of T packed
4-bit K/V entries. The packed codes (2 per byte — ``serve/kv_quant``'s
carrier convention) and their per-(slot, head) fp16 scales stream through
VMEM as *bytes*; each ``block_t`` tile is unpacked (shift/mask), affine
SMOL-dequantized ``v = (2u - 15) * 2^-3`` and scaled **inside the
attention inner loop** — the [T, D] floating-point K/V tensor never exists
in HBM (that materialized dequant buffer is exactly what the decode_32k
cells are bound on).

Two block-tiled passes per program, with an exact softmax between them:

    pass 1   scores[SG, T]  += q @ dequant(k_tile)^T       (per tile)
    mask     causal-by-position (+ sliding window), pos<0 entries dropped
    softmax  full-row fp32 (same op order as the jnp oracle)
    pass 2   out[SG, D]     += softmax_tile @ dequant(v_tile)

VMEM per step at T=32k, D=128, SG=8: codes 2x 32k*64 B = 4 MiB, scores
8x32k*4 = 1 MiB, one unpacked [block_t, D] f32 tile 128 KiB — the fp32
score row is the only O(T) fp buffer. Numerics mirror
``backend.base.qkv_attn_jnp`` element-for-element (dequant, 1/sqrt(D)
scaling, -1e30 mask fill, fp32 softmax); only the tiled f32 accumulation
order of pass 2 may differ from the oracle's single contraction, which is
why the parity bound is "token-identical greedy decode", not bitwise
logits.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30                        # matches models.attention.NEG_INF


def _dequant_tile(codes, scale):
    """[bt, D//2] uint8 codes + [bt, 1] f16 scales -> [bt, D] f32 on the
    4-bit SMOL grid — the same element ops as ``kv_quant.dequantize_kv``
    at f32 read dtype (channel 2j in the low nibble, 2j+1 in the high)."""
    lo = (codes & 0xF).astype(jnp.float32)
    hi = ((codes >> 4) & 0xF).astype(jnp.float32)
    u = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[0],
                                             codes.shape[1] * 2)
    v = (2.0 * u - 15.0) * 0.125
    return v * scale.astype(jnp.float32)


def _kernel(q_ref, kc_ref, vc_ref, ks_ref, vs_ref, kpos_ref, qpos_ref,
            o_ref, *, g: int, bt: int, window: Optional[int]):
    sg, d = q_ref.shape[2], q_ref.shape[3]
    t = kc_ref.shape[2]
    nb = t // bt
    q = q_ref[0, 0].astype(jnp.float32)                    # [SG, D]

    def score_tile(i, acc):
        kc = kc_ref[0, 0, pl.ds(i * bt, bt), :]            # [bt, D//2] u8
        ks = ks_ref[0, 0, pl.ds(i * bt, bt), :]            # [bt, 1] f16
        kd = _dequant_tile(kc, ks)                         # [bt, D] f32
        sc = jax.lax.dot_general(q, kd, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        return jax.lax.dynamic_update_slice(acc, sc, (0, i * bt))

    scores = jax.lax.fori_loop(0, nb, score_tile,
                               jnp.zeros((sg, t), jnp.float32))
    scores = scores * (1.0 / np.sqrt(d))
    kpos = kpos_ref[...]                                   # [1, T]
    qpos = jnp.repeat(qpos_ref[...], g, axis=1)            # [1, SG] s-major
    qcol = qpos.reshape(sg, 1)
    mask = (qcol >= kpos) & (kpos >= 0)                    # [SG, T]
    if window is not None:
        mask &= (qcol - kpos) < window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)                    # exact, full row

    def out_tile(i, acc):
        vc = vc_ref[0, 0, pl.ds(i * bt, bt), :]
        vs = vs_ref[0, 0, pl.ds(i * bt, bt), :]
        vd = _dequant_tile(vc, vs)                         # [bt, D] f32
        pt = jax.lax.dynamic_slice(p, (0, i * bt), (sg, bt))
        return acc + jax.lax.dot(pt, vd,
                                 preferred_element_type=jnp.float32)

    o_ref[0, 0] = jax.lax.fori_loop(0, nb, out_tile,
                                    jnp.zeros((sg, d), jnp.float32))


@functools.partial(jax.jit, static_argnames=("window", "block_t",
                                             "interpret"))
def qkv_attn_decode(q, k_codes, v_codes, k_scale, v_scale, kv_pos, q_pos,
                    *, window: Optional[int] = None, block_t: int = 256,
                    interpret: bool = True):
    """Fused decode attention over the packed 4-bit ring-KV cache.

    q [B,S,Hk,G,D] (RoPE applied); k_codes/v_codes [B,T,Hk,D//2] uint8;
    k_scale/v_scale [B,T,Hk,1] f16; kv_pos [B,T] ring positions (< 0 =
    empty entry); q_pos [B,S] (< 0 = masked lane). -> [B,S,Hk,G,D] f32.
    """
    from .packed_matmul import fit_block
    b, s, hk, g, d = q.shape
    t = k_codes.shape[1]
    bt = fit_block(t, block_t)
    sg = s * g
    # Head-major relayout: one contiguous (b, h) tile per program. The
    # transposed operands are *bytes* (codes) and f16 scalars — 4x+ less
    # traffic than a dequantized fp cache would move.
    qh = jnp.transpose(q, (0, 2, 1, 3, 4)).reshape(b, hk, sg, d)
    kc = jnp.swapaxes(k_codes, 1, 2)                       # [B,Hk,T,D//2]
    vc = jnp.swapaxes(v_codes, 1, 2)
    ks = jnp.swapaxes(k_scale, 1, 2)                       # [B,Hk,T,1]
    vs = jnp.swapaxes(v_scale, 1, 2)
    kern = functools.partial(_kernel, g=g, bt=bt, window=window)
    out = pl.pallas_call(
        kern,
        grid=(b, hk),
        in_specs=[
            pl.BlockSpec((1, 1, sg, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, d // 2), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, d // 2), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, t), lambda i, j: (i, 0)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, sg, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, sg, d), jnp.float32),
        interpret=interpret,
    )(qh, kc, vc, ks, vs,
      jnp.asarray(kv_pos, jnp.int32), jnp.asarray(q_pos, jnp.int32))
    return jnp.transpose(out.reshape(b, hk, s, g, d), (0, 2, 1, 3, 4))


# ---------------------------------------------------------------------------
# Paged variant: page-table walk + online softmax (DESIGN.md §13).
# ---------------------------------------------------------------------------
# Same per-program shape as the ring kernel — one (batch row, kv-head) per
# program, q [SG, D] — but K/V live in the global page pool ([P, ps, ...]
# flattened to a [P*ps, ...] byte stream) and the program walks the slot's
# page table: tile i covers logical page i // ntile, whose physical page id
# comes from the table (unmapped ids clamp to the null page 0, whose
# ``pos`` stamps are -1, so holes mask out exactly like empty ring
# entries). The softmax is *online* (flash-decode): a running (m, l, acc)
# carry replaces the ring kernel's full [SG, T] fp32 score row — the only
# O(T) state left is the carry, so T can grow with the pool, not with a
# per-slot score buffer. An all-masked tile contributes exp(0) = 1 weights
# at m = -1e30; the first real tile's rescale exp(-1e30 - m_real) = 0
# flushes them, and a row that stays fully masked divides to the uniform
# average — exactly what the oracle's softmax over an all--1e30 row gives.

def _paged_kernel(q_ref, kc_ref, vc_ref, ks_ref, vs_ref, pos_ref, tbl_ref,
                  qpos_ref, o_ref, *, g: int, ps: int, bt: int,
                  window: Optional[int]):
    sg, d = q_ref.shape[2], q_ref.shape[3]
    npg = tbl_ref.shape[1]
    ntile = ps // bt
    q = q_ref[0, 0].astype(jnp.float32)                    # [SG, D]
    qpos = jnp.repeat(qpos_ref[...], g, axis=1)            # [1, SG] s-major
    qcol = qpos.reshape(sg, 1)

    def tile(i, carry):
        m, l, acc = carry
        pid = tbl_ref[0, i // ntile]                       # traced scalar
        base = jnp.maximum(pid, 0) * ps + (i % ntile) * bt
        kc = kc_ref[0, pl.ds(base, bt), :]                 # [bt, D//2] u8
        ks = ks_ref[0, pl.ds(base, bt), :]                 # [bt, 1] f16
        kd = _dequant_tile(kc, ks)                         # [bt, D] f32
        sc = jax.lax.dot_general(q, kd, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sc = sc * (1.0 / np.sqrt(d))
        kpos = pos_ref[0, pl.ds(base, bt)].reshape(1, bt)
        mask = (qcol >= kpos) & (kpos >= 0) & (pid >= 0)   # [SG, bt]
        if window is not None:
            mask &= (qcol - kpos) < window
        sc = jnp.where(mask, sc, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(sc, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m2)                            # [SG, 1]
        p = jnp.exp(sc - m2)                               # [SG, bt]
        vc = vc_ref[0, pl.ds(base, bt), :]
        vs = vs_ref[0, pl.ds(base, bt), :]
        vd = _dequant_tile(vc, vs)                         # [bt, D] f32
        l2 = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc2 = acc * alpha + jax.lax.dot(
            p, vd, preferred_element_type=jnp.float32)
        return m2, l2, acc2

    m0 = jnp.full((sg, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((sg, 1), jnp.float32)
    a0 = jnp.zeros((sg, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, npg * ntile, tile, (m0, l0, a0))
    o_ref[0, 0] = acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "block_t",
                                             "interpret"))
def qkv_attn_decode_paged(q, k_codes, v_codes, k_scale, v_scale, pool_pos,
                          page_table, q_pos, *,
                          window: Optional[int] = None, block_t: int = 128,
                          interpret: bool = True):
    """Paged flash-decode attention over the packed 4-bit page pool.

    q [B,S,Hk,G,D] (RoPE applied); k_codes/v_codes [P,ps,Hk,D//2] uint8
    pool pages; k_scale/v_scale [P,ps,Hk,1] f16; pool_pos [P,ps] absolute
    position stamps (< 0 = empty); page_table [B,NP] physical page per
    logical page (< 0 = unmapped); q_pos [B,S] (< 0 = masked lane).
    -> [B,S,Hk,G,D] f32. ``block_t`` tiles *within* a page (clipped to a
    divisor of ``page_size``); pages are already the natural tile."""
    from .packed_matmul import fit_block
    b, s, hk, g, d = q.shape
    npages, ps = pool_pos.shape
    npg = page_table.shape[1]
    bt = fit_block(ps, block_t)
    sg = s * g
    # Head-major byte streams over the whole pool: [P, ps, Hk, c] ->
    # [Hk, P*ps, c]. Pool operands carry no batch dim — every program of a
    # batch row reads the same stream through its own page table.
    def pool_stream(x):
        return jnp.transpose(x, (2, 0, 1, 3)).reshape(
            x.shape[2], npages * ps, x.shape[3])
    qh = jnp.transpose(q, (0, 2, 1, 3, 4)).reshape(b, hk, sg, d)
    kc, vc = pool_stream(k_codes), pool_stream(v_codes)
    ks, vs = pool_stream(k_scale), pool_stream(v_scale)
    pos = jnp.asarray(pool_pos, jnp.int32).reshape(1, npages * ps)
    kern = functools.partial(_paged_kernel, g=g, ps=ps, bt=bt,
                             window=window)
    out = pl.pallas_call(
        kern,
        grid=(b, hk),
        in_specs=[
            pl.BlockSpec((1, 1, sg, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, npages * ps, d // 2), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, npages * ps, d // 2), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, npages * ps, 1), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, npages * ps, 1), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, npages * ps), lambda i, j: (0, 0)),
            pl.BlockSpec((1, npg), lambda i, j: (i, 0)),
            pl.BlockSpec((1, s), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, sg, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, sg, d), jnp.float32),
        interpret=interpret,
    )(qh, kc, vc, ks, vs, pos,
      jnp.asarray(page_table, jnp.int32), jnp.asarray(q_pos, jnp.int32))
    return jnp.transpose(out.reshape(b, hk, s, g, d), (0, 2, 1, 3, 4))
