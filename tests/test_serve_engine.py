"""Serve path: QAT -> packed conversion -> batched generation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine


def _tiny(mode="qat"):
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode=mode))


def test_rebudget_pbits_respects_ranking():
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25))
    w = np.random.default_rng(0).normal(0, 1, (128, 16)).astype(np.float32)
    pbits = np.array([1, 4, 4, 2, 1, 2, 4, 4], np.int8)
    out = engine.rebudget_pbits(pbits, w, qcfg)
    assert sorted(out.tolist()) == sorted([4, 4, 4, 4, 2, 2, 1, 1])
    # trained 4-bit groups keep 4 bits while budget allows
    assert all(out[i] == 4 for i in (1, 2, 6, 7))


def test_serve_convert_stacked_layers():
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    sp = engine.serve_convert(jax.device_get(params), cfg.quant)
    wq = sp["groups"][0]["attn"]["wq"]
    assert "w4" in wq and wq["w4"].dtype == jnp.uint8
    assert wq["w4"].shape[0] == 2          # stacked over 2 layers
    assert engine.packed_model_bytes(sp) > 0


def test_generate_shapes_and_determinism():
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = engine.DecodeEngine(jax.device_get(params), cfg,
                              engine.EngineConfig(cache_len=64))
    prompts = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
    out1 = eng.generate(prompts, max_new_tokens=5)
    out2 = eng.generate(prompts, max_new_tokens=5)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)      # greedy = deterministic
    assert (out1[:, 3:] < cfg.vocab_size).all()


def test_serve_logits_close_to_qat():
    """Packed decode must track the QAT model it was converted from."""
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.asarray([3, 7], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)

    cache_q = lm.init_cache(cfg, 2, 32, jnp.float32)
    lg_qat, _ = lm.decode_step(params, cfg, cache_q, tok, pos)

    scfg = dataclasses.replace(cfg,
                               quant=dataclasses.replace(cfg.quant,
                                                         mode="serve"))
    sp = engine.serve_convert(jax.device_get(params), scfg.quant)
    cache_s = lm.init_cache(scfg, 2, 32, jnp.float32)
    lg_srv, _ = lm.decode_step(sp, scfg, cache_s, tok, pos)
    # same argmax on a clear margin is the serving contract
    corr = np.corrcoef(np.asarray(lg_qat).ravel(),
                       np.asarray(lg_srv).ravel())[0, 1]
    assert corr > 0.98
