"""Serve a SONIQ-quantized LM with batched requests.

    PYTHONPATH=src python examples/serve_quantized.py

Trains a tiny LM briefly (QAT), converts to packed 1/2/4-bit weights, then
serves a batch of prompts through the DecodeEngine; reports the packed-size
win and tokens generated.
"""
import sys

sys.path.insert(0, "src")

import jax                                      # noqa: E402
import numpy as np                              # noqa: E402

from repro import soniq                         # noqa: E402
from repro.configs.base import ArchConfig       # noqa: E402
from repro.data import synthetic                # noqa: E402
from repro.train import loop, state as state_lib  # noqa: E402


def main():
    quant = soniq.QuantConfig(mode=soniq.Phase.QAT)
    cfg = ArchConfig(
        name="serve-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        dtype="float32", param_dtype="float32", quant=quant, q_block=64)

    # quick QAT-only training (t1=0 -> no Phase I, mix from config)
    tcfg = state_lib.TrainConfig(t1=0, t2=30, warmup=3)
    stream = synthetic.TokenStream(synthetic.TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=64, batch_size=8))
    result = loop.train(cfg, tcfg, stream.batches())
    params = jax.device_get(result["state"]["params"])

    eng = soniq.DecodeEngine(
        params, cfg, soniq.EngineConfig(cache_len=128, temperature=0.0))
    fp_bytes = sum(v.size * 4 for v in jax.tree.leaves(params)
                   if hasattr(v, "size"))
    q_bytes = soniq.packed_bytes(eng.params)
    print(f"model bytes: fp32 {fp_bytes:,} -> packed {q_bytes:,} "
          f"({fp_bytes/q_bytes:.1f}x smaller)")

    prompts = np.asarray([[1, 7, 3, 1], [2, 9, 9, 4],
                          [5, 5, 5, 5], [11, 3, 7, 2]], np.int32)
    out = eng.generate(prompts, max_new_tokens=12)
    for i, row in enumerate(out):
        print(f"request {i}: prompt={row[:4].tolist()} "
              f"-> {row[4:].tolist()}")


if __name__ == "__main__":
    main()
