"""Serving launcher: packed-weight batched decoding behind a request loop.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --requests 4 --new-tokens 16

Initializes (or loads) QAT weights, converts to the packed 1/2/4-bit serve
format, and runs greedy generation for a batch of synthetic prompts —
the deployment path of the paper's pipeline (decode_32k / long_500k
dry-run cells lower exactly this step at production scale).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import soniq
from repro.configs import get_config
from repro.models import lm
from repro.train import checkpoint as ckpt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = soniq.with_phase(cfg, soniq.Phase.QAT)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        state, step = ckpt_lib.restore(args.ckpt, {"params": params})
        params = state["params"]
        print(f"loaded checkpoint step {step}")

    eng = soniq.DecodeEngine(
        jax.device_get(params), cfg,
        soniq.EngineConfig(cache_len=args.cache_len,
                           temperature=args.temperature))
    print(f"packed model: {soniq.packed_bytes(eng.params):,} bytes")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.new_tokens,
                       jax.random.PRNGKey(1) if args.temperature > 0
                       else None)
    dt = time.time() - t0
    total_new = args.requests * args.new_tokens
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, CPU interpret path)")
    for i, row in enumerate(out):
        print(f"req {i}: {row[:args.prompt_len].tolist()} -> "
              f"{row[args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
