"""Decomposition tooling over compiled HLO: attribute trip-count-corrected
bytes / collective bytes to individual instructions, and quantify the
dequant-materialization traffic a fused Pallas packed-matmul eliminates.

Used by the §Perf hillclimbs to locate dominant-term contributors instead
of guessing.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Tuple

from . import hlo_cost


def _multipliers(comps, entry):
    mult: Dict[str, float] = {}
    internal = set()

    def visit(name, m, is_int):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        if is_int:
            internal.add(name)
        for ins in comps[name].instrs:
            if ins.op == "while":
                tm = hlo_cost._TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                for c in hlo_cost._CALLED.findall(ins.line):
                    visit(c, m * trip, is_int)
            elif ins.op == "conditional":
                bm = hlo_cost._BRANCHES.search(ins.line)
                if bm:
                    for b in hlo_cost._OPERAND.findall(bm.group(1)):
                        visit(b, m, is_int)
            elif ins.op in ("fusion", "reduce", "scatter", "sort", "map",
                            "reduce-window", "select-and-scatter", "call",
                            "reduce-scatter", "all-reduce",
                            "all-reduce-start"):
                for c in hlo_cost._CALLED.findall(ins.line):
                    visit(c, m, True)

    visit(entry, 1.0, False)
    return mult, internal


def top_bytes(hlo: str, n: int = 20) -> List[Tuple[float, str]]:
    """Largest per-instruction corrected byte contributors."""
    comps, entry = hlo_cost.parse_computations(hlo)
    mult, internal = _multipliers(comps, entry)
    rows = []
    for name, m in mult.items():
        if name in internal:
            continue
        comp = comps[name]
        for ins in comp.instrs:
            b = hlo_cost._instr_bytes(ins, comp, comps) * m
            if b > 0:
                rows.append((b, f"x{m:.0f} {ins.op} {ins.ty[:40]} "
                             f"{ins.line.strip()[:110]}"))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def top_collectives(hlo: str, n: int = 20) -> List[Tuple[float, str]]:
    comps, entry = hlo_cost.parse_computations(hlo)
    mult, internal = _multipliers(comps, entry)
    rows = []
    for name, m in mult.items():
        if name in internal:
            continue
        comp = comps[name]
        for ins in comp.instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in hlo_cost.COLLECTIVES:
                b = hlo_cost._type_bytes(ins.ty) * m
                rows.append((b, f"x{m:.0f} {base} {ins.ty[:50]} "
                             f"meta={_meta(ins.line)}"))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def _meta(line: str) -> str:
    i = line.find("op_name=")
    return line[i + 9:i + 100].split('"')[0] if i > 0 else ""


def dequant_materialization_bytes(hlo: str) -> float:
    """Corrected bytes of fusions that unpack uint8 codes into a wide
    weight tensor consumed by a dot — exactly the traffic the Pallas
    packed_matmul keeps in VMEM (write + re-read of the fusion output)."""
    comps, entry = hlo_cost.parse_computations(hlo)
    mult, internal = _multipliers(comps, entry)
    total = 0.0
    for name, m in mult.items():
        if name in internal:
            continue
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op != "fusion":
                continue
            ops = hlo_cost._OPERAND.findall(hlo_cost._args_str(ins))
            has_u8 = any("u8[" in comp.shapes.get(o, "") for o in ops)
            out_b = hlo_cost._type_bytes(ins.ty)
            if has_u8 and out_b > (1 << 20) and \
                    ("bf16[" in ins.ty or "f32[" in ins.ty):
                total += 2.0 * out_b * m      # write + re-read by the dot
    return total
