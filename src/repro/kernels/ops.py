"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) and False on TPU.
Every op has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes and
assert_allclose against it.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.qtypes import GROUP_SIZE
from . import noise_inject as _ni
from . import packed_matmul as _pm
from . import quant_pack as _qp
from . import ref  # noqa: F401  (re-exported for tests/benchmarks)


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def packed_segment_matmul(x, wp, scales=None, *, p: int,
                          act_quant: bool = False, act_scale=None,
                          interpret: Optional[bool] = None, **blocks):
    """Uniform-precision packed GEMM; see packed_matmul.py."""
    interpret = default_interpret() if interpret is None else interpret
    if act_quant and act_scale is not None:
        x = x / act_scale
    y = _pm.packed_segment_matmul(x, wp, scales, p=p, act_quant=act_quant,
                                  interpret=interpret, **blocks)
    if act_quant and act_scale is not None:
        y = y * act_scale
    return y


def packed_matmul(x, serve_params: Dict, *, act_quant: bool = True,
                  interpret: Optional[bool] = None, **blocks):
    """Full SmolLinear serve-mode matmul over the [K4|K2|K1] segments of a
    packed serve leaf (``soniq.to_serve`` / ``repro.api.transforms
    .pack_linear``). Drop-in for the jnp serve path."""
    interpret = default_interpret() if interpret is None else interpret
    x = jnp.take(x, serve_params["perm"], axis=-1)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    k4 = serve_params["w4"].shape[0] * 2
    k2 = serve_params["w2"].shape[0] * 4
    k1 = serve_params["w1"].shape[0] * 8
    scales = serve_params.get("wscale")
    act_scale = quant.abs_max_scale(x2) if act_quant else None
    n = max(serve_params[k].shape[1] for k in ("w4", "w2", "w1"))
    y = jnp.zeros((x2.shape[0], n), jnp.float32)
    off, goff = 0, 0
    for name, p, kp in (("w4", 4, k4), ("w2", 2, k2), ("w1", 1, k1)):
        if kp == 0:
            continue
        seg_scales = None if scales is None else \
            jax.lax.dynamic_slice_in_dim(scales, goff, kp // GROUP_SIZE)
        y = y + packed_segment_matmul(
            x2[:, off:off + kp], serve_params[name], seg_scales, p=p,
            act_quant=act_quant, act_scale=act_scale, interpret=interpret,
            **blocks)
        off += kp
        goff += kp // GROUP_SIZE
    if serve_params.get("b") is not None and "b" in serve_params:
        y = y + serve_params["b"].astype(y.dtype)
    return y.reshape(lead + (n,))


def quantize_pack(w, scales=None, *, p: int,
                  interpret: Optional[bool] = None, **blocks):
    interpret = default_interpret() if interpret is None else interpret
    return _qp.quantize_pack(w, scales, p=p, interpret=interpret, **blocks)


def noise_inject(w, s, seed, *, interpret: Optional[bool] = None, **blocks):
    interpret = default_interpret() if interpret is None else interpret
    return _ni.noise_inject(w, s, seed, interpret=interpret, **blocks)
