"""SQ008 — interprocedural scale-dataflow pass (DESIGN.md §16).

SQ002 (lint.py) is deliberately intraprocedural: it catches a raw
abs-max divided in the *same* function. This pass closes the known gap
where the producer and the divide live in different functions::

    def scale(x):
        return jnp.max(jnp.abs(x))      # scale-like, never clamped

    def norm(x):
        return x / scale(x)             # SQ002-silent; SQ008 fires

It is a flow-insensitive abstract interpretation over a three-value
lattice per value:

    NOT_SCALE (0)  ->  CLAMPED (1)  ->  RAW_SCALE (2)     join = max

* abs-max-style reductions (``jnp.max(jnp.abs(x))``, ``.max()`` over an
  ``abs``) produce RAW_SCALE;
* clamp constructs (``jnp.maximum``/``clip``/``clamp``/``where``) lower
  RAW to CLAMPED — so producers that clamp internally
  (``core.quant.abs_max_scale``/``per_group_weight_scale``) come out
  CLAMPED from analyzing their bodies, not from a hard-coded list;
* the tag propagates through assignments, returns, call arguments, one
  level of dict/tuple/attribute packing, identity-ish wrappers
  (``stop_gradient``/``astype``/``reshape``/...), and closures (nested
  functions are analyzed in the enclosing bindings at their definition
  site).

Function summaries — return lattice, which params flow to the return,
and which params are divided-by unclamped inside — are computed to a
fixpoint over the whole call graph (calls resolve by terminal attribute
name, conservatively joining over same-named functions; external
numeric namespaces ``jnp``/``np``/``lax``/... are exempt). A final
reporting pass flags every divide, divide-call (``lax.div`` /
``jnp.divide`` / ``jnp.true_divide``) or reciprocal whose divisor is
RAW_SCALE on some path — including passing a RAW value into a function
that divides by that parameter unclamped.

Suppressions reuse the lint syntax (``# soniq-lint: disable=
SQ008(reason)``); a *stale* SQ008 suppression is reported as SQ007 by
this pass (lint.py leaves SQ008 suppressions alone — this module owns
them).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint import (Suppression, Violation, _call_name,
                                 _default_root, _parse_suppressions)

NOT_SCALE, CLAMPED, RAW_SCALE = 0, 1, 2

# External numeric namespaces: `<base>.foo(...)` with one of these bases
# never resolves to a user-defined function, whatever `foo` is called.
_EXTERNAL_BASES = {"jnp", "np", "numpy", "jax", "lax", "math", "pl",
                   "pltpu", "plgpu", "scipy", "torch", "tf", "os", "re",
                   "json", "hashlib", "dataclasses", "functools",
                   "itertools", "collections", "operator", "logging",
                   "time", "random"}
_MAX_TERMINALS = {"max", "amax"}
_ABS_TERMINALS = {"abs", "absolute"}
_CLAMP_TERMINALS = {"maximum", "clip", "clamp", "where"}
# Identity-ish wrappers: the tag rides through unchanged.
_PROPAGATE_TERMINALS = {"stop_gradient", "optimization_barrier", "asarray",
                        "array", "astype", "reshape", "ravel", "squeeze",
                        "expand_dims", "broadcast_to", "copy", "minimum",
                        "transpose", "flatten", "float32", "bfloat16"}
_RECIP_TERMINALS = {"reciprocal"}
_DIV_TERMINALS = {"div", "divide", "true_divide"}
_FIXPOINT_LIMIT = 12


@dataclasses.dataclass(frozen=True)
class _Val:
    """Abstract value: lattice point + the analyzed function's params it
    (still unclamped) depends on — the carrier for interprocedural
    propagation of both returns and divide-by-param obligations."""
    lat: int = NOT_SCALE
    deps: frozenset = frozenset()    # param names of the current function

    def join(self, other: "_Val") -> "_Val":
        return _Val(max(self.lat, other.lat), self.deps | other.deps)


_BOTTOM = _Val()


@dataclasses.dataclass
class _Summary:
    ret: int = NOT_SCALE             # lattice of the returned value
    ret_params: Set[str] = dataclasses.field(default_factory=set)
    div_params: Set[str] = dataclasses.field(default_factory=set)

    def key(self) -> Tuple:
        return (self.ret, tuple(sorted(self.ret_params)),
                tuple(sorted(self.div_params)))


@dataclasses.dataclass
class _Func:
    name: str
    path: str
    node: ast.AST                    # FunctionDef / AsyncFunctionDef
    env0: Dict[str, _Val]            # closure bindings at definition site
    summary: _Summary = dataclasses.field(default_factory=_Summary)

    @property
    def params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in
                list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]


@dataclasses.dataclass
class DataflowResult:
    """SQ008 findings that stand, suppressions that fired, and SQ007
    findings for stale SQ008 suppressions (folded into ``findings``)."""
    findings: List[Violation] = dataclasses.field(default_factory=list)
    suppressed: List[Suppression] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings


def _terminal(func: ast.AST) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _base_name(func: ast.AST) -> str:
    node = func
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _contains_abs(node: ast.AST) -> bool:
    return any(isinstance(sub, ast.Call)
               and _terminal(sub.func) in _ABS_TERMINALS
               for sub in ast.walk(node))


class _Analysis:
    """Shared state across the whole multi-module analysis: the terminal-
    name call table and the node-identity function registry (AST nodes
    are parsed once, so ``id(node)`` is a stable key across fixpoint
    iterations)."""

    def __init__(self):
        self.table: Dict[str, List[_Func]] = {}
        self.registry: Dict[int, _Func] = {}

    def define(self, node, path: str, env0: Dict[str, _Val]) -> _Func:
        fn = self.registry.get(id(node))
        if fn is None:
            fn = _Func(node.name, path, node, dict(env0))
            self.registry[id(node)] = fn
            self.table.setdefault(node.name, []).append(fn)
        else:
            fn.env0 = dict(env0)     # refresh the closure snapshot
        return fn

    def summaries_key(self) -> Tuple:
        return tuple(f.summary.key() for f in self.registry.values())


class _FunctionAnalyzer:
    """One pass over one function body: computes its summary, registers
    and recursively analyzes nested definitions with the current bindings
    as their closure snapshot, and (when ``report`` is set) emits SQ008
    findings. Flow-insensitive: statements interpret in order, branch
    bodies share the environment (over-approximating toward RAW is fine —
    suppressions carry the per-site argument)."""

    def __init__(self, fn: _Func, an: _Analysis,
                 report: Optional[List[Violation]], lines: List[str]):
        self.fn = fn
        self.an = an
        self.report = report
        self.lines = lines
        self.env: Dict[str, _Val] = dict(fn.env0)
        for p in fn.params:
            self.env[p] = _Val(NOT_SCALE, frozenset([p]))
        self.out = _Summary()

    def run(self) -> _Summary:
        self._exec_body(self.fn.node.body)
        self.fn.summary = self.out
        return self.out

    # ------------------------------------------------------------- flags --
    def _flag(self, node: ast.AST, message: str) -> None:
        if self.report is None:
            return
        line = getattr(node, "lineno", 1)
        src = (self.lines[line - 1].strip()
               if line <= len(self.lines) else "")
        self.report.append(Violation(
            self.fn.path, line, getattr(node, "col_offset", 0),
            "SQ008", message, src))

    def _check_divisor(self, node: ast.AST, val: _Val, how: str) -> None:
        if val.lat == RAW_SCALE:
            self._flag(node, f"{how} by a scale-like value (raw abs-max) "
                             f"with no ACT_SCALE_EPS clamp on this path — "
                             f"an all-zero input makes the divisor 0; "
                             f"floor it with jnp.maximum(s, "
                             f"ACT_SCALE_EPS) (core.quant)")
        # Dividing by a still-unclamped param: the obligation moves to
        # every call site (fixpoint summary).
        self.out.div_params |= val.deps

    # ---------------------------------------------------------- resolve --
    def _resolve(self, func: ast.AST) -> List[_Func]:
        term = _terminal(func)
        if not term or term not in self.an.table:
            return []
        if isinstance(func, ast.Attribute) and \
                _base_name(func) in _EXTERNAL_BASES:
            return []
        return self.an.table[term]

    def _call_args(self, call: ast.Call, callee: _Func
                   ) -> Dict[str, _Val]:
        """Map call arguments onto the callee's param names."""
        params = callee.params
        bound: Dict[str, _Val] = {}
        for i, arg in enumerate(call.args):
            if not isinstance(arg, ast.Starred) and i < len(params):
                bound[params[i]] = self.eval(arg)
        for kw in call.keywords:
            if kw.arg is not None and kw.arg in params:
                bound[kw.arg] = self.eval(kw.value)
        return bound

    # ------------------------------------------------------------- eval --
    def eval(self, node) -> _Val:
        if node is None:
            return _BOTTOM
        if isinstance(node, ast.Name):
            return self.env.get(node.id, _BOTTOM)
        if isinstance(node, ast.Call):
            return self._eval_call(node)
        if isinstance(node, ast.BinOp):
            return self._eval_binop(node)
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            out = _BOTTOM
            for e in node.elts:
                out = out.join(self.eval(e))
            return out
        if isinstance(node, ast.Dict):
            out = _BOTTOM
            for v in node.values:
                out = out.join(self.eval(v))
            return out
        if isinstance(node, ast.Subscript):
            self.eval(node.slice)
            return self.eval(node.value)
        if isinstance(node, ast.IfExp):
            self.eval(node.test)
            return self.eval(node.body).join(self.eval(node.orelse))
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.Attribute):
            # one level of object packing: `obj.scale` carries obj's tag
            return self.eval(node.value)
        if isinstance(node, (ast.Compare, ast.BoolOp)):
            for sub in ast.iter_child_nodes(node):
                if isinstance(sub, ast.expr):
                    self.eval(sub)
            return _BOTTOM
        if isinstance(node, (ast.ListComp, ast.SetComp,
                             ast.GeneratorExp)):
            for gen in node.generators:
                self.eval(gen.iter)
            return self.eval(node.elt)
        if isinstance(node, ast.DictComp):
            for gen in node.generators:
                self.eval(gen.iter)
            return self.eval(node.value)
        if isinstance(node, ast.Await):
            return self.eval(node.value)
        if isinstance(node, (ast.Yield, ast.YieldFrom)):
            val = self.eval(node.value) if node.value is not None \
                else _BOTTOM
            self.out.ret = max(self.out.ret, val.lat)
            self.out.ret_params |= val.deps
            return _BOTTOM
        return _BOTTOM

    def _eval_binop(self, node: ast.BinOp) -> _Val:
        left = self.eval(node.left)
        right = self.eval(node.right)
        if isinstance(node.op, ast.Div):
            self._check_divisor(node, right, "dividing")
            # raw-scale / constant is still a raw scale (m / grid_max)
            return left
        if isinstance(node.op, ast.Mult):
            # scale * numeric constant keeps the tag; act * scale is NOT
            if isinstance(node.right, ast.Constant):
                return left
            if isinstance(node.left, ast.Constant):
                return right
        return _BOTTOM

    def _eval_call(self, node: ast.Call) -> _Val:
        term = _terminal(node.func)
        # abs-max reduction: jnp.max(jnp.abs(x)) / jnp.abs(x).max(...)
        if term in _MAX_TERMINALS and _contains_abs(node):
            for arg in node.args:
                self.eval(arg)
            return _Val(RAW_SCALE)
        if term in _CLAMP_TERMINALS:
            joined = _BOTTOM
            for arg in node.args:
                joined = joined.join(self.eval(arg))
            if joined.lat != NOT_SCALE or joined.deps:
                return _Val(CLAMPED)
            return _BOTTOM
        if term in _RECIP_TERMINALS and node.args:
            val = self.eval(node.args[0])
            self._check_divisor(node, val, "taking the reciprocal of")
            return val
        if term in _DIV_TERMINALS and len(node.args) >= 2:
            left = self.eval(node.args[0])
            self._check_divisor(node, self.eval(node.args[1]),
                                f"{_call_name(node)}(x, s): dividing")
            return left
        if term in _PROPAGATE_TERMINALS:
            joined = _BOTTOM
            if isinstance(node.func, ast.Attribute):
                joined = joined.join(self.eval(node.func.value))
            for arg in node.args:
                joined = joined.join(self.eval(arg))
            return joined
        callees = self._resolve(node.func)
        if callees:
            out = _BOTTOM
            for callee in callees:
                bound = self._call_args(node, callee)
                s = callee.summary
                # param divided-by unclamped inside the callee: RAW here
                # is the cross-function SQ002 bug; a dep means our own
                # caller owns the obligation next.
                for p in sorted(s.div_params):
                    v = bound.get(p, _BOTTOM)
                    if v.lat == RAW_SCALE:
                        self._flag(node, f"passing a raw (unclamped) "
                                         f"abs-max into {callee.name}() "
                                         f"which divides by parameter "
                                         f"'{p}' with no clamp on that "
                                         f"path")
                    self.out.div_params |= v.deps
                ret = _Val(s.ret)
                for p in sorted(s.ret_params):
                    ret = ret.join(bound.get(p, _BOTTOM))
                out = out.join(ret)
            for kw in node.keywords:
                self.eval(kw.value)
            return out
        # Unknown external call: evaluate children (divides inside
        # argument expressions still get checked), result untagged.
        for arg in node.args:
            self.eval(arg)
        for kw in node.keywords:
            self.eval(kw.value)
        if isinstance(node.func, ast.Attribute):
            self.eval(node.func.value)
        return _BOTTOM

    # -------------------------------------------------------- statements --
    def _exec_body(self, body: Iterable[ast.stmt]) -> None:
        for stmt in body:
            self._exec(stmt)

    def _assign_target(self, target, val: _Val) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign_target(e, val)
        elif isinstance(target, ast.Starred):
            self._assign_target(target.value, val)
        # Subscript/Attribute stores: no tracked cell, drop.

    def _exec(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested (or module/class-level) definition: register with
            # the CURRENT bindings as its closure snapshot and analyze it
            # in place — the closure arm of the propagation contract.
            for deco in stmt.decorator_list:
                self.eval(deco)
            fn = self.an.define(stmt, self.fn.path, self.env)
            _FunctionAnalyzer(fn, self.an, self.report, self.lines).run()
            self.env[stmt.name] = _BOTTOM
        elif isinstance(stmt, ast.ClassDef):
            self._exec_body(stmt.body)
        elif isinstance(stmt, ast.Assign):
            if isinstance(stmt.value, ast.Tuple) and \
                    len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], (ast.Tuple, ast.List)) \
                    and len(stmt.targets[0].elts) == len(stmt.value.elts):
                for t, v in zip(stmt.targets[0].elts, stmt.value.elts):
                    self._assign_target(t, self.eval(v))
            else:
                val = self.eval(stmt.value)
                for t in stmt.targets:
                    self._assign_target(t, val)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._assign_target(stmt.target, self.eval(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            left = self.eval(stmt.target)
            right = self.eval(stmt.value)
            if isinstance(stmt.op, ast.Div):
                self._check_divisor(stmt, right, "dividing (/=)")
                self._assign_target(stmt.target, left)
            else:
                self._assign_target(stmt.target, _BOTTOM)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                val = self.eval(stmt.value)
                self.out.ret = max(self.out.ret, val.lat)
                self.out.ret_params |= val.deps
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value)
        elif isinstance(stmt, ast.If):
            self.eval(stmt.test)
            self._exec_body(stmt.body)
            self._exec_body(stmt.orelse)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self.eval(stmt.iter)
            self._assign_target(stmt.target, _BOTTOM)
            self._exec_body(stmt.body)
            self._exec_body(stmt.orelse)
        elif isinstance(stmt, ast.While):
            self.eval(stmt.test)
            self._exec_body(stmt.body)
            self._exec_body(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.eval(item.context_expr)
            self._exec_body(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._exec_body(stmt.body)
            for handler in stmt.handlers:
                self._exec_body(handler.body)
            self._exec_body(stmt.orelse)
            self._exec_body(stmt.finalbody)
        elif isinstance(stmt, (ast.Assert, ast.Raise, ast.Delete)):
            for sub in ast.iter_child_nodes(stmt):
                if isinstance(sub, ast.expr):
                    self.eval(sub)


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------


def _module_func(path: str, tree: ast.Module) -> _Func:
    """Wrap a module's top-level statements as a pseudo-function: running
    it interprets module-level code AND (via the FunctionDef handler)
    registers + analyzes every function, method, and nested closure."""
    node = ast.FunctionDef(
        name=f"<module:{path or 'source'}>",
        args=ast.arguments(posonlyargs=[], args=[], vararg=None,
                           kwonlyargs=[], kw_defaults=[], kwarg=None,
                           defaults=[]),
        body=list(tree.body), decorator_list=[], returns=None,
        type_comment=None)
    ast.fix_missing_locations(node)
    return _Func(node.name, path, node, {})


def analyze_sources(sources: List[Tuple[str, str]]) -> DataflowResult:
    """Analyze ``[(path, source), ...]`` as one program (cross-module
    calls resolve across the whole list)."""
    modules: List[Tuple[_Func, List[str], str, str]] = []
    findings: List[Violation] = []
    for path, source in sources:
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            findings.append(Violation(path, e.lineno or 1, e.offset or 0,
                                      "SQ000",
                                      f"syntax error: {e.msg}"))
            continue
        modules.append((_module_func(path, tree), source.splitlines(),
                        path, source))
    an = _Analysis()
    for _ in range(_FIXPOINT_LIMIT):
        before = an.summaries_key()
        for mod_fn, lines, _path, _src in modules:
            _FunctionAnalyzer(mod_fn, an, None, lines).run()
        if an.summaries_key() == before:
            break
    raw: List[Violation] = []
    for mod_fn, lines, _path, _src in modules:
        _FunctionAnalyzer(mod_fn, an, raw, lines).run()
    # Dedup (a site reachable through several same-named callees flags
    # once) and apply per-file SQ008 suppressions + staleness (SQ007).
    seen: set = set()
    per_file: Dict[str, List[Violation]] = {}
    for v in raw:
        k = (v.path, v.line, v.col, v.message)
        if k not in seen:
            seen.add(k)
            per_file.setdefault(v.path, []).append(v)
    result = DataflowResult(findings=list(findings))
    for _mod_fn, lines, path, source in modules:
        supp_map, _malformed = _parse_suppressions(source, path)
        used: set = set()
        for v in per_file.get(path, []):
            reason = supp_map.get(v.line, {}).get("SQ008")
            if reason is not None:
                used.add(v.line)
                result.suppressed.append(Suppression(
                    path, v.line, "SQ008", reason, v.source_line))
            else:
                result.findings.append(v)
        for line in sorted(supp_map):
            if "SQ008" in supp_map[line] and line not in used:
                src = (lines[line - 1].strip()
                       if line <= len(lines) else "")
                result.findings.append(Violation(
                    path, line, 0, "SQ007",
                    "unused suppression: SQ008 does not fire on this "
                    "line — the hazard was fixed or moved; remove the "
                    "stale disable=SQ008(...)", src))
    result.findings.sort(key=lambda v: (v.path, v.line, v.col))
    return result


def analyze_source(source: str, path: str = "") -> DataflowResult:
    """Single-source convenience wrapper (fixtures and tests)."""
    return analyze_sources([(path, source)])


def analyze_paths(paths: Iterable[Path],
                  root: Optional[Path] = None) -> DataflowResult:
    """Analyze files/directories (``.py`` recursively) as one program,
    with repo-relative paths like :func:`lint.lint_paths`."""
    paths = [Path(p) for p in paths]
    if root is None:
        root = _default_root(paths)
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    sources: List[Tuple[str, str]] = []
    for f in files:
        rel = f.resolve()
        if root is not None:
            try:
                rel = rel.relative_to(Path(root).resolve())
            except ValueError:
                pass
        sources.append((rel.as_posix(), f.read_text()))
    return analyze_sources(sources)
