"""Qwen2-VL-72B [arXiv:2409.12191; hf] — VLM backbone with M-RoPE, dynamic
resolution (frontend is a stub per the task brief): 80L d_model=8192 64H
(GQA kv=8) d_ff=29568 vocab=152064."""
from .base import ArchConfig
from .registry import register


@register("qwen2-vl-72b")
def qwen2_vl() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        rope_theta=1e6, mrope_sections=(16, 24, 24),   # t/h/w; sums to Dh/2
        attn_bias=True, mlp_act="swiglu",
        frontend="vision_stub", tie_embeddings=False,
        source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B-Instruct",
    )
