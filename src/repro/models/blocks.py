"""Decoder/encoder block assembly.

Block kinds (cfg.layer_plan()):
  attn_mlp / attn_moe / mamba / mamba_mlp / mamba_moe — pre-norm residual
  hybrid_unit — Jamba: cfg.attn_every sub-blocks (1 attn per unit, MoE every
                moe_every-th ffn), scanned as one repeating unit
  enc — bidirectional (whisper encoder)
  dec — causal self-attn + cross-attn + FFN (whisper decoder)
Suffix "@dense0" overrides d_ff with cfg.dense_d_ff (DeepSeekMoE's first
dense layer).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qtypes import QuantConfig
from . import attention, mlp as mlp_lib, moe as moe_lib, ssm as ssm_lib
from .common import layer_norm, layer_norm_init, rms_norm, rms_norm_init


def _norm_init(cfg):
    return layer_norm_init(cfg.d_model) if cfg.norm == "ln" \
        else rms_norm_init(cfg.d_model)


def _norm(cfg, params, x):
    fn = layer_norm if cfg.norm == "ln" else rms_norm
    return fn(params, x, cfg.norm_eps)


def _dff(kind: str, cfg) -> int:
    return cfg.dense_d_ff if kind.endswith("@dense0") and cfg.dense_d_ff \
        else cfg.d_ff


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def block_init(key, kind: str, cfg, qcfg: QuantConfig) -> Dict:
    base = kind.split("@")[0]
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    p: Dict = {}
    if base == "hybrid_unit":
        subs = cfg.hybrid_unit_kinds()
        return {f"sub{i}": block_init(ks[i % 8] if i < 8 else ks[0],
                                      sub, cfg, qcfg)
                for i, sub in enumerate(subs)}
    if "attn" in base or base in ("enc", "dec"):
        p["ln_attn"] = _norm_init(cfg)
        p["attn"] = attention.attn_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
            qcfg, use_bias=cfg.attn_bias, dtype=dt)
    if base == "dec":
        p["ln_cross"] = _norm_init(cfg)
        p["cross"] = attention.attn_init(
            ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
            qcfg, use_bias=cfg.attn_bias, dtype=dt)
    if "mamba" in base:
        p["ln_mixer"] = _norm_init(cfg)
        p["mamba"] = ssm_lib.mamba2_init(ks[2], cfg.d_model, cfg.ssm_state,
                                         qcfg, expand=cfg.ssm_expand,
                                         dtype=dt)
    if "moe" in base:
        p["ln_ffn"] = _norm_init(cfg)
        p["moe"] = moe_lib.moe_init(
            ks[3], cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.top_k, qcfg,
            num_shared=cfg.num_shared_experts, act=cfg.mlp_act, dtype=dt)
    elif "mlp" in base or base in ("enc", "dec"):
        p["ln_ffn"] = _norm_init(cfg)
        p["mlp"] = mlp_lib.mlp_init(ks[4], cfg.d_model, _dff(kind, cfg),
                                    qcfg, act=cfg.mlp_act,
                                    use_bias=cfg.attn_bias, dtype=dt)
    return p


def _attn_kwargs(cfg, qcfg):
    return dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd, qcfg=qcfg, rope_theta=cfg.rope_theta,
                mrope_sections=cfg.mrope_sections, window=cfg.window,
                use_rope=cfg.family != "audio")


def block_apply(params: Dict, kind: str, x, positions, cfg,
                qcfg: QuantConfig, rng=None, *, cross_x=None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (x, moe_aux_loss)."""
    base = kind.split("@")[0]
    aux = jnp.zeros((), jnp.float32)
    if base == "hybrid_unit":
        # Each sub-block is its own remat unit: the backward pass holds one
        # sublayer's (all-gathered) weights at a time instead of all
        # attn_every of them — required to fit the 398B hybrid's MoE units.
        subs = cfg.hybrid_unit_kinds()
        policy = jax.checkpoint_policies.nothing_saveable

        for i, sub in enumerate(subs):
            r = None if rng is None else jax.random.fold_in(rng, i)

            def sub_fn(p_, x_, r_, _sub=sub):
                return block_apply(p_, _sub, x_, positions, cfg, qcfg, r_)

            if cfg.remat != "none":
                sub_fn = jax.checkpoint(sub_fn, policy=policy)
            x, a = sub_fn(params[f"sub{i}"], x, r)
            aux = aux + a
        return x, aux

    rngs = [None] * 4 if rng is None else list(jax.random.split(rng, 4))
    if "attn" in base or base in ("enc", "dec"):
        h = _norm(cfg, params["ln_attn"], x)
        a = attention.attn_apply(
            params["attn"], h, positions, rng=rngs[0],
            causal=(base != "enc"), q_block=cfg.q_block,
            **_attn_kwargs(cfg, qcfg))
        x = x + a
    if base == "dec":
        h = _norm(cfg, params["ln_cross"], x)
        a = attention.attn_apply(params["cross"], h, positions,
                                 rng=rngs[1], cross_x=cross_x,
                                 q_block=cfg.q_block,
                                 **_attn_kwargs(cfg, qcfg))
        x = x + a
    if "mamba" in base:
        h = _norm(cfg, params["ln_mixer"], x)
        x = x + ssm_lib.mamba2_apply(params["mamba"], h, qcfg, rngs[2],
                                     d_state=cfg.ssm_state,
                                     expand=cfg.ssm_expand,
                                     chunk=cfg.ssm_chunk)
    if "moe" in base:
        h = _norm(cfg, params["ln_ffn"], x)
        y, a = moe_lib.moe_apply(params["moe"], h, qcfg, rngs[3],
                                 num_experts=cfg.num_experts,
                                 top_k=cfg.top_k, act=cfg.mlp_act)
        x = x + y
        aux = aux + a
    elif "mlp" in base or base in ("enc", "dec"):
        h = _norm(cfg, params["ln_ffn"], x)
        x = x + mlp_lib.mlp_apply(params["mlp"], h, qcfg, rngs[3],
                                  act=cfg.mlp_act)
    return x, aux


# --------------------------------------------------------------- decode ----
def block_cache_init(kind: str, cfg, batch: int, cache_len: int,
                     dtype=jnp.bfloat16, *, specs: bool = False,
                     kv_bits: Optional[int] = None,
                     kv_layout: str = "ring", page_size: int = 16,
                     num_pages: Optional[int] = None) -> Dict:
    """``kv_bits=None`` allocates the fp ring-KV cache in ``dtype``;
    ``kv_bits=4`` the packed 4-bit family (``serve/kv_quant.py`` — codes +
    fp16 scales, consumed by the ``qkv_attn_decode`` backend op). SSM
    state always stays fp (the recurrent state is the accumulator —
    DESIGN.md §5).

    ``kv_layout="paged"`` swaps the per-slot ring for the page-pool layout
    (``serve/kv_pool.py``, DESIGN.md §13): payload lives in ``num_pages``
    pool pages of ``page_size`` tokens (page 0 reserved as the null page;
    ``None`` sizes the pool to full per-slot residency,
    ``batch * pages_per_seq + 1``) plus a per-slot page table whose
    logical length is the ring length in pages — ``page_size`` must divide
    the effective ring length so rollover wraps at the same token the ring
    layout would."""
    base = kind.split("@")[0]
    kv = attention.kv_cache_specs if specs else attention.init_kv_cache
    sm = ssm_lib.ssm_cache_specs if specs else ssm_lib.init_ssm_cache
    if base == "hybrid_unit":
        return {f"sub{i}": block_cache_init(sub, cfg, batch, cache_len,
                                            dtype, specs=specs,
                                            kv_bits=kv_bits,
                                            kv_layout=kv_layout,
                                            page_size=page_size,
                                            num_pages=num_pages)
                for i, sub in enumerate(cfg.hybrid_unit_kinds())}
    c: Dict = {}
    if "attn" in base or base == "dec":
        clen = min(cache_len, cfg.window) if cfg.window else cache_len
        if kv_bits is not None:
            assert kv_bits == 4, f"kv_bits must be None or 4, got {kv_bits}"
        if kv_layout == "paged":
            from repro.serve import kv_pool    # lazy: serve imports models
            assert clen % page_size == 0, \
                (f"page_size {page_size} must divide the effective ring "
                 f"length {clen} (cache_len clipped to the window) so "
                 f"paged rollover wraps where the ring does")
            pps = clen // page_size
            npages = num_pages if num_pages is not None \
                else batch * pps + 1
            pkv = kv_pool.paged_cache_specs if specs \
                else kv_pool.init_paged_cache
            c["kv"] = pkv(npages, page_size, pps, batch,
                          cfg.num_kv_heads, cfg.hd, kv_bits=kv_bits,
                          dtype=dtype)
        elif kv_bits is None:
            c["kv"] = kv(batch, clen, cfg.num_kv_heads, cfg.hd, dtype)
        else:
            from repro.serve import kv_quant   # lazy: serve imports models
            qkv = kv_quant.qkv_cache_specs if specs \
                else kv_quant.init_qkv_cache
            c["kv"] = qkv(batch, clen, cfg.num_kv_heads, cfg.hd)
    if "mamba" in base:
        c["ssm"] = sm(batch, cfg.d_model, cfg.ssm_state,
                      expand=cfg.ssm_expand, dtype=dtype)
    return c


def block_decode(params: Dict, kind: str, x, cache: Dict, pos, cfg,
                 qcfg: QuantConfig, *, cross_kv=None, layer_idx=None
                 ) -> Tuple[jax.Array, Dict]:
    """One-token decode. x [B, 1, D]; pos [B]. With layer_idx, cache leaves
    are stacked [L, ...] scan-carry buffers updated in place."""
    base = kind.split("@")[0]
    if base == "hybrid_unit":
        new_cache = {}
        for i, sub in enumerate(cfg.hybrid_unit_kinds()):
            x, new_cache[f"sub{i}"] = block_decode(
                params[f"sub{i}"], sub, x, cache[f"sub{i}"], pos, cfg, qcfg,
                layer_idx=layer_idx)
        return x, new_cache

    new_cache = dict(cache)
    if "attn" in base or base == "dec":
        h = _norm(cfg, params["ln_attn"], x)
        a, new_kv = attention.attn_decode(params["attn"], h, cache["kv"],
                                          pos, layer_idx=layer_idx,
                                          **_attn_kwargs(cfg, qcfg))
        new_cache["kv"] = new_kv
        x = x + a
    if base == "dec" and cross_kv is not None:
        h = _norm(cfg, params["ln_cross"], x)
        a, _ = attention.attn_decode(params["cross"], h, None, pos,
                                     cross_kv=cross_kv,
                                     **_attn_kwargs(cfg, qcfg))
        x = x + a
    if "mamba" in base:
        h = _norm(cfg, params["ln_mixer"], x)
        y, new_ssm = ssm_lib.mamba2_decode(params["mamba"], h, cache["ssm"],
                                           qcfg, d_state=cfg.ssm_state,
                                           expand=cfg.ssm_expand,
                                           layer_idx=layer_idx)
        new_cache["ssm"] = new_ssm
        x = x + y
    if "moe" in base:
        h = _norm(cfg, params["ln_ffn"], x)
        y, _ = moe_lib.moe_apply(params["moe"], h, qcfg, None,
                                 num_experts=cfg.num_experts,
                                 top_k=cfg.top_k, act=cfg.mlp_act)
        x = x + y
    elif "mlp" in base or base in ("enc", "dec"):
        h = _norm(cfg, params["ln_ffn"], x)
        x = x + mlp_lib.mlp_apply(params["mlp"], h, qcfg, None,
                                  act=cfg.mlp_act)
    return x, new_cache
