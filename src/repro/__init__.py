"""SONIQ/SySMOL on TPU: ultra-low fine-grained mixed-precision training and
serving in JAX. Public API: ``from repro import soniq`` (see DESIGN.md §9)."""
__version__ = "1.1.0"


def __getattr__(name):
    # Lazy: `from repro import soniq` loads the façade (which pulls in the
    # model libraries) only when asked for, keeping `import repro.core.*`
    # light for kernels/tests.
    if name in ("soniq", "api"):
        import importlib
        return importlib.import_module(".api", __name__)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
