"""repro.analysis.model_check: the exhaustive PagePool interleaving
checker passes the real allocator, finds the seeded refcount-leak and
missing-poison-cancel mutants with a minimal trace within the depth
bound, and reports its search honestly (DESIGN.md §16)."""
import pytest

from repro.analysis import model_check
from repro.serve import kv_pool


# --------------------------------------------------------- real pool ----

def test_real_pool_is_clean_at_default_depth():
    r = model_check.explore()
    assert r.ok
    assert r.states_explored > 50          # the BFS actually went places
    assert r.depth_reached == 6
    j = r.to_json()
    assert j["ok"] and j["trace"] == [] and j["messages"] == []


def test_real_pool_clean_without_poison():
    r = model_check.explore(model_check.MCConfig(poison=False))
    assert r.ok


# ------------------------------------------------------------ mutants ----

class LeakyReleasePool(kv_pool.PagePool):
    """Seeded bug: release() clears the table rows but skips the unref —
    the classic allocator leak (pages stay referenced forever)."""

    def release(self, slot, ops):
        for lp in range(self.pages_per_seq):
            self.table[slot, lp] = -1
        self._target_pages.pop(slot, None)
        self._slot_hashes.pop(slot, None)


class NoPoisonCancelPool(kv_pool.PagePool):
    """Seeded bug: _alloc() hands a freed page back out without
    cancelling its pending poison — the stale poison would scribble over
    the fresh allocation after the wipe. Everything else (refcount init,
    wipe scheduling, cached eviction) matches the real allocator."""

    def _alloc(self, ops, *, wipe):
        if self.free:
            pid = self.free.pop()          # missing ops.poisons.remove
        elif self.cached:
            pid, _digest = self.cached.popitem(last=False)
            self._unregister(pid)
        else:
            raise RuntimeError("exhausted")
        self.refcount[pid] = 1
        if wipe:
            ops.wipes.append(pid)
        self.peak_resident = max(self.peak_resident, self.resident_pages)
        return pid


def test_refcount_leak_mutant_found_with_minimal_trace():
    r = model_check.explore(pool_factory=LeakyReleasePool)
    assert not r.ok
    assert len(r.violation.trace) <= 6
    text = "\n".join(r.violation.messages)
    assert "lost" in text or "refcount" in text
    # The trace is concrete and replayable: every step names an op.
    assert all(step for step in r.violation.trace)


def test_poison_cancel_mutant_found_with_minimal_trace():
    r = model_check.explore(pool_factory=NoPoisonCancelPool)
    assert not r.ok
    assert len(r.violation.trace) <= 6
    assert any("poison" in m for m in r.violation.messages)


def test_violation_format_is_replayable():
    r = model_check.explore(pool_factory=LeakyReleasePool)
    out = r.violation.format()
    assert "PagePool invariant violation" in out
    assert "1." in out and "violated:" in out


# ------------------------------------------------- search honesty ----

def test_max_states_valve_raises_not_truncates():
    with pytest.raises(RuntimeError, match="max_states"):
        model_check.explore(max_depth=10, max_states=50)


def test_shared_invariants_are_the_checked_set():
    """The checker asserts the same invariant definition PagePool.check()
    and the fuzz harness use — one source of truth (DESIGN.md §16)."""
    pool = kv_pool.PagePool(4, 2, 2, 2, poison=True)
    assert kv_pool.invariant_violations(pool) == []
    # Seed a drift the shared definition must see.
    pool.refcount[1] = 3
    assert any("refcount" in m
               for m in kv_pool.invariant_violations(pool))
