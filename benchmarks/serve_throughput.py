"""Continuous-batching vs lockstep serve throughput (tokens/s).

    PYTHONPATH=src python benchmarks/serve_throughput.py

Mixed-length synthetic workload (skewed prompt and generation lengths —
the traffic shape the ROADMAP's heavy-traffic story cares about) through
both engines over the SAME packed weights:

* lockstep baseline: requests grouped into fixed batches of ``max_batch``,
  prompts right-padded to the batch max, every row decoding until the
  batch's longest request finishes — the pre-PR serve loop;
* continuous batching: request-level admission, slot reuse, chunked
  prefill (DESIGN.md §10).

Reports useful tokens/s (only each request's own ``max_new_tokens`` count
as useful; padded prompt positions and overshoot decode steps are waste)
and the speedup. The PR-2 acceptance bar is >= 1.5x on this workload.

``--backends`` additionally sweeps the continuous engine across kernel
backends (default: every backend available here) and appends the per-
backend tokens/s to ``BENCH_backend.json`` next to this script — the
record the perf trajectory of the backend work is measured against. The
sweep includes a ``+kv4_paged`` leg: ring vs paged KV layout at q4 on
shared-system-prompt traffic, recording peak-resident vs reserved cache
payload bytes and the prefix-hit rate next to tokens/s (DESIGN.md §13),
and two self-speculative legs (DESIGN.md §14): ``+spec`` — the stock
checkpoint with speculation on, recording tokens/s and the mean
accepted-draft length honestly (a random-init checkpoint's draft slice
rarely agrees with the full mix, so acceptance is near zero and the
rounds are overhead) — and ``+spec_oracle`` — an acceptance-upper-bound
checkpoint (high-bit segment scales zeroed, so the draft IS the full
mix bitwise and every draft survives verification) on a
linear-dominated shape, where the skipped carriers pay for themselves:
the measured tokens/s win of zero-extra-weight-memory speculation.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import soniq
from repro.backend import registry as backend_registry
from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine as engine_lib
from repro.serve import kv_quant
from repro.serve.scheduler import Request

try:                                   # package run (benchmarks.run)
    from . import _common
except ImportError:                    # direct script run
    import _common

record_backend_bench = _common.record_backend_bench


def make_workload(num_requests: int, rng) -> list:
    """Skewed mixed-length traffic: short chats dominate, a few long
    prompts / long generations drag lockstep batches out."""
    reqs = []
    for i in range(num_requests):
        if i % 4 == 3:                       # 1-in-4 heavy request
            plen = int(rng.integers(24, 48))
            new = int(rng.integers(48, 64))
        else:
            plen = int(rng.integers(4, 12))
            new = int(rng.integers(8, 24))
        reqs.append(Request(prompt=rng.integers(1, 500, (plen,)),
                            max_new_tokens=new, seed=i))
    return reqs


def make_shared_prefix_workload(num_requests: int, rng) -> list:
    """Shared-system-prompt traffic (the paged-KV story, DESIGN.md §13):
    every request opens with the same 64-token system prompt, then a
    short per-user tail — the regime where the prefix map stores the
    system pages once and resident pages stay far below the ring's
    reserved capacity."""
    system = rng.integers(1, 500, (64,)).astype(np.int32)
    reqs = []
    for i in range(num_requests):
        tail = rng.integers(1, 500, (int(rng.integers(2, 9)),))
        reqs.append(Request(
            prompt=np.concatenate([system, tail.astype(np.int32)]),
            max_new_tokens=int(rng.integers(8, 17)), seed=i))
    return reqs


def oracle_low_slice_params(packed_params, draft_bits: int):
    """Acceptance-upper-bound checkpoint for the ``+spec_oracle`` leg:
    zero the per-group scales of every segment ABOVE ``draft_bits``, so
    the low-slice draft forward is bitwise identical to the full mix
    (those segments contribute exactly nothing) while still reading only
    the low-bit carriers. Same packed buffers, zero extra weight bytes —
    this isolates the machinery's ceiling from checkpoint-dependent
    draft/target agreement."""
    def walk(tree):
        if isinstance(tree, dict):
            if "w4" in tree and tree.get("wscale") is not None:
                out = dict(tree)
                n4 = tree["w4"].shape[-2] * 2 // 16
                n2 = tree["w2"].shape[-2] * 4 // 16
                ws = np.array(tree["wscale"])
                ws[..., :(n4 if draft_bits >= 2 else n4 + n2)] = 0.0
                out["wscale"] = jnp.asarray(ws)
                return out
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v) for v in tree]
        if isinstance(tree, tuple):
            return tuple(walk(v) for v in tree)
        return tree
    return walk(packed_params)


def run_lockstep(eng, reqs, max_batch: int) -> float:
    """Grouped fixed batches, padded to the batch max; returns seconds."""
    t0 = time.time()
    for i in range(0, len(reqs), max_batch):
        group = reqs[i:i + max_batch]
        s0 = max(len(r.prompt) for r in group)
        new = max(r.max_new_tokens for r in group)
        prompts = np.zeros((len(group), s0), np.int32)
        for j, r in enumerate(group):        # right-pad to the batch max
            prompts[j, :len(r.prompt)] = r.prompt
        eng.generate(prompts, new)
    return time.time() - t0


def run_continuous(eng, reqs) -> float:
    eng.reset()
    t0 = time.time()
    for _ in eng.serve(list(reqs)):
        pass
    return time.time() - t0


def _tokens_of_engine(eng, reqs):
    """Serve a fresh copy of ``reqs`` and return {request order: tokens}
    (the spec-leg parity assert; also doubles as the jit warm-up run)."""
    got = {c.request_id: c.tokens for c in eng.serve(
        [dataclasses.replace(r) for r in reqs])}
    return {k - min(got): v for k, v in got.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backends", default=None,
                    help="comma-separated kernel backends to sweep the "
                         "continuous engine over (default: all available; "
                         "'' skips the sweep)")
    args = ap.parse_args(argv)

    cfg = ArchConfig(
        name="bench", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=64,
        quant=QuantConfig(mode="qat"))
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    ecfg = soniq.EngineConfig(max_batch=args.max_batch, cache_len=128,
                              prefill_chunk=args.prefill_chunk)
    lock = engine_lib.LockstepEngine(params, cfg, ecfg)
    cont = engine_lib.DecodeEngine(params, cfg, ecfg,
                                   already_serve=False)

    rng = np.random.default_rng(args.seed)
    reqs = make_workload(args.requests, rng)
    useful = sum(r.max_new_tokens for r in reqs)

    # Warm both jit caches on a toy batch before timing.
    lock.generate(np.ones((args.max_batch, 4), np.int32), 2)
    warm = [Request(prompt=np.ones(5, np.int32), max_new_tokens=2, seed=0)]
    list(cont.serve(warm))

    t_lock = run_lockstep(lock, reqs, args.max_batch)
    t_cont = run_continuous(cont, reqs)
    tps_lock = useful / t_lock
    tps_cont = useful / t_cont
    print(f"workload: {len(reqs)} requests, {useful} useful new tokens, "
          f"max_batch {args.max_batch}, prefill_chunk {args.prefill_chunk}")
    print(f"lockstep   : {t_lock:6.2f}s  {tps_lock:8.1f} tok/s")
    print(f"continuous : {t_cont:6.2f}s  {tps_cont:8.1f} tok/s")
    print(f"speedup    : {tps_cont / tps_lock:.2f}x  (target >= 1.5x)")
    # harness CSV row (us per generated token; derived = speedup)
    print(f"serve_throughput,{1e6 * t_cont / useful:.1f},"
          f"{tps_cont / tps_lock:.2f}x_vs_lockstep", flush=True)

    # ------------------------------------------- kernel-backend sweep ----
    names = (backend_registry.available() if args.backends is None
             else [b for b in args.backends.split(",") if b])
    sweep = {}
    for name in names:
        # Backends carrying the fused activation-quant prologue are timed
        # both ways ("+two_pass" = fused-vs-unfused engine delta); every
        # backend is additionally timed on the quantized KV cache
        # ("+kv4": packed 4-bit ring + qkv_attn_decode — the tokens/s leg
        # of the cache-bytes record below). BENCH_backend.json is the
        # running record.
        variants = [(name, True, None)]
        if backend_registry.resolve(name).supports(
                "fused_act_segment_matmul"):
            variants.append((f"{name}+two_pass", False, None))
        variants.append((f"{name}+kv4", True, 4))
        for label, fuse, kv_bits in variants:
            eng = engine_lib.DecodeEngine(
                params, cfg, soniq.EngineConfig(
                    max_batch=args.max_batch, cache_len=128,
                    prefill_chunk=args.prefill_chunk, backend=name,
                    fuse_act_quant=fuse, kv_bits=kv_bits))
            list(eng.serve([Request(prompt=np.ones(5, np.int32),
                                    max_new_tokens=2, seed=0)]))  # warm jit
            t = run_continuous(eng, reqs)
            sweep[label] = {"tok_s": round(useful / t, 1),
                            "seconds": round(t, 3)}
            print(f"backend {label:>26}: {t:6.2f}s  "
                  f"{useful / t:8.1f} tok/s")
    # --------------------------------------------- paged-KV comparison ----
    # Ring vs paged layout at q4 on shared-system-prompt traffic: same
    # packed weights, same requests — records tokens/s side by side plus
    # the pool's occupancy (peak resident vs reserved payload bytes) and
    # prefix-hit rate. The §13 acceptance bar: resident <= 0.5x the ring's
    # reserved bytes with tokens/s within 10% of the ring engine.
    shared_reqs = make_shared_prefix_workload(args.requests, rng)
    shared_useful = sum(r.max_new_tokens for r in shared_reqs)
    for name in names:
        legs = {}
        for label, layout in (("ring", "ring"), ("paged", "paged")):
            eng = engine_lib.DecodeEngine(
                params, cfg, soniq.EngineConfig(
                    max_batch=args.max_batch, cache_len=128,
                    prefill_chunk=args.prefill_chunk, backend=name,
                    kv_bits=4, kv_layout=layout))
            # Warm the jit caches AND (paged) the prefix map with one
            # system-prompt request: steady-state shared-prefix traffic
            # finds the system pages already registered, the regime the
            # occupancy claim is about. No reset before timing — the
            # warm pages must survive into the measured run.
            list(eng.serve([dataclasses.replace(shared_reqs[0])]))
            t0 = time.time()
            for _ in eng.serve([dataclasses.replace(r)
                                for r in shared_reqs]):
                pass
            legs[label] = (time.time() - t0, eng)
        t_ring, _ = legs["ring"]
        t_paged, paged_eng = legs["paged"]
        stats = paged_eng.paged_kv_stats()
        row = {
            "tok_s": round(shared_useful / t_paged, 1),
            "seconds": round(t_paged, 3),
            "ring_tok_s": round(shared_useful / t_ring, 1),
            "tok_s_vs_ring": round(t_ring / t_paged, 3),
            "page_size": stats["page_size"],
            "peak_resident_payload_bytes":
                stats["peak_resident_payload_bytes"],
            "reserved_payload_bytes": stats["reserved_payload_bytes"],
            "resident_over_reserved": round(
                stats["peak_resident_payload_bytes"]
                / stats["reserved_payload_bytes"], 3),
            "prefix_hit_rate": round(stats["prefix_hit_rate"], 3),
        }
        sweep[f"{name}+kv4_paged"] = row
        print(f"backend {name + '+kv4_paged':>26}: {t_paged:6.2f}s  "
              f"{shared_useful / t_paged:8.1f} tok/s "
              f"({row['tok_s_vs_ring']:.2f}x ring, resident "
              f"{row['resident_over_reserved']:.2f}x reserved, prefix hit "
              f"{row['prefix_hit_rate']:.2f})")

    # -------------------------------------------- speculative decoding ----
    # "+spec": the stock checkpoint/workload with the draft-k/verify-1
    # round on (k=3, draft slice <= 2 bits). Tokens are spec-off
    # identical at temp 0 (asserted); tokens/s and the mean accepted
    # draft length are recorded AS MEASURED — a random-init checkpoint's
    # low slice almost never matches the full-mix argmax, so acceptance
    # ~0 and the extra rounds cost throughput. The row exists so the
    # record separates machinery cost from checkpoint-dependent
    # acceptance (DESIGN.md §14).
    for name in names:
        eng = engine_lib.DecodeEngine(
            params, cfg, soniq.EngineConfig(
                max_batch=args.max_batch, cache_len=128,
                prefill_chunk=args.prefill_chunk, backend=name,
                spec_tokens=3, spec_draft_bits=2))
        list(eng.serve([Request(prompt=np.ones(5, np.int32),
                                max_new_tokens=2, seed=0)]))  # warm jit
        t = run_continuous(eng, reqs)
        st = eng.spec_stats()
        sweep[f"{name}+spec"] = {
            "tok_s": round(useful / t, 1), "seconds": round(t, 3),
            "tok_s_vs_base": round(
                (useful / t) / sweep[name]["tok_s"], 3),
            "spec_tokens": 3, "spec_draft_bits": 2,
            "mean_accepted": round(st["mean_accepted"], 3)}
        print(f"backend {name + '+spec':>26}: {t:6.2f}s  "
              f"{useful / t:8.1f} tok/s (mean accepted "
              f"{st['mean_accepted']:.2f}/3)")

    # "+spec_oracle": the acceptance upper bound, on the backend fast
    # enough to time a linear-dominated shape (the interpreted Pallas
    # backend is orders of magnitude off real kernel timing anyway).
    if "xla_ref" in names:
        big = dataclasses.replace(
            cfg, name="bench-spec", num_layers=4, d_model=256,
            num_heads=4, num_kv_heads=4, d_ff=2048, head_dim=64)
        big_params = jax.device_get(
            lm.init_params(jax.random.PRNGKey(0), big))
        base_kw = dict(max_batch=args.max_batch, cache_len=64,
                       prefill_chunk=args.prefill_chunk, backend="xla_ref")
        probe = engine_lib.DecodeEngine(big_params, big,
                                        soniq.EngineConfig(**base_kw))
        oracle = oracle_low_slice_params(jax.device_get(probe.params),
                                         draft_bits=1)
        spec_reqs = [Request(prompt=rng.integers(1, 500, (int(l),)),
                             max_new_tokens=32, seed=i)
                     for i, l in enumerate((8, 12, 6, 10))]
        spec_useful = sum(r.max_new_tokens for r in spec_reqs)

        def best_of(ecfg, reps=3):
            eng = engine_lib.DecodeEngine(oracle, big, ecfg,
                                          already_serve=True)
            tokens = _tokens_of_engine(eng, spec_reqs)     # warm + tokens
            best = min(run_continuous(eng, [dataclasses.replace(r)
                                            for r in spec_reqs])
                       for _ in range(reps))
            return eng, best, tokens

        _, t_off, tok_off = best_of(soniq.EngineConfig(**base_kw))
        eng_on, t_on, tok_on = best_of(soniq.EngineConfig(
            **base_kw, spec_tokens=5, spec_draft_bits=1))
        for k in tok_off:                     # greedy spec-on == spec-off
            np.testing.assert_array_equal(tok_off[k], tok_on[k])
        st = eng_on.spec_stats()
        row = {
            "tok_s": round(spec_useful / t_on, 1),
            "base_tok_s": round(spec_useful / t_off, 1),
            "tok_s_vs_base": round(t_off / t_on, 3),
            "spec_tokens": 5, "spec_draft_bits": 1,
            "mean_accepted": round(st["mean_accepted"], 3),
            "packed_model_bytes": engine_lib.packed_model_bytes(oracle),
            "model": {"num_layers": 4, "d_model": 256, "d_ff": 2048},
        }
        sweep["xla_ref+spec_oracle"] = row
        print(f"backend {'xla_ref+spec_oracle':>26}: {t_on:6.2f}s  "
              f"{spec_useful / t_on:8.1f} tok/s "
              f"({row['tok_s_vs_base']:.2f}x no-spec, mean accepted "
              f"{st['mean_accepted']:.2f}/5)")

    # Cache-byte accounting for the q4 claim (specs=True: no allocation).
    # Payload = K/V codes + scales (q4) vs fp16 k/v buffers; the ``pos``
    # ring bookkeeping is identical in both families and reported
    # separately so the ratio stays honest (DESIGN.md §12).
    fp16_cache = lm.init_cache(cfg, args.max_batch, 128, jnp.float16,
                               specs=True)
    q4_cache = lm.init_cache(cfg, args.max_batch, 128, jnp.float16,
                             specs=True, kv_bits=4)
    fp_payload = kv_quant.cache_payload_bytes(fp16_cache)
    q4_payload = kv_quant.cache_payload_bytes(q4_cache)
    kv_bytes = {"fp16_payload_bytes": fp_payload,
                "q4_payload_bytes": q4_payload,
                "payload_ratio": round(fp_payload / q4_payload, 2),
                "pos_meta_bytes": kv_quant.cache_meta_bytes(q4_cache)}
    print(f"kv cache payload: fp16 {fp_payload:,} B -> q4 {q4_payload:,} B "
          f"({kv_bytes['payload_ratio']}x, + {kv_bytes['pos_meta_bytes']:,}"
          " B pos metadata either way)")
    if sweep:
        record_backend_bench("serve_throughput", {
            "workload": {"requests": len(reqs), "useful_tokens": useful,
                         "max_batch": args.max_batch,
                         "prefill_chunk": args.prefill_chunk},
            "shared_prefix_workload": {
                "requests": len(shared_reqs), "system_prompt_tokens": 64,
                "useful_tokens": shared_useful},
            "backends": sweep,
            "kv_cache": kv_bytes})
    return tps_cont / tps_lock


if __name__ == "__main__":
    main()
