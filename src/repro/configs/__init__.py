"""Architecture configs: 10 assigned archs + the paper's own CNN family."""
from .base import ArchConfig
from .registry import get_config, list_archs

# Import for registration side effects.
from . import (starcoder2_7b, h2o_danube_1_8b, deepseek_67b,
               mistral_large_123b, deepseek_moe_16b, mixtral_8x22b,
               qwen2_vl_72b, mamba2_2_7b, jamba_1_5_large_398b,
               whisper_medium)

ASSIGNED = [
    "starcoder2-7b", "h2o-danube-1.8b", "deepseek-67b", "mistral-large-123b",
    "deepseek-moe-16b", "mixtral-8x22b", "qwen2-vl-72b", "mamba2-2.7b",
    "jamba-1.5-large-398b", "whisper-medium",
]

__all__ = ["ArchConfig", "get_config", "list_archs", "ASSIGNED"]
