"""Deterministic synthetic data pipelines.

Offline container: no real corpora. Two generators:

  * token_stream — a Zipf-distributed Markov token source with injected
    n-gram structure, so an LM has real signal to fit (loss decreases and
    quantization quality differences are visible).
  * classification — Gaussian-cluster images/vectors for the paper-faithful
    CNN/MLP benchmarks (Table I / Fig 7 analogs).

Both are seeded, host-shardable (each data-parallel host draws its own
disjoint substream via fold_in(seed, host_id)), and cheap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int             # per-host
    seed: int = 0
    ngram: int = 3
    zipf_a: float = 1.2


class TokenStream:
    """Markov chain over a Zipf marginal: next ~ mix(bigram(cur), zipf)."""

    def __init__(self, cfg: TokenStreamConfig, host_id: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, host_id]))
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.marginal = ranks ** -cfg.zipf_a
        self.marginal /= self.marginal.sum()
        # deterministic "grammar": token t prefers (t*7+3)%v next
        self.next_pref = (np.arange(v) * 7 + 3) % v

    def _sample_seq(self, n: int) -> np.ndarray:
        v = self.cfg.vocab_size
        out = np.empty(n, np.int32)
        cur = int(self.rng.choice(v, p=self.marginal))
        for i in range(n):
            out[i] = cur
            if self.rng.random() < 0.7:          # structured transition
                cur = int(self.next_pref[cur])
            else:
                cur = int(self.rng.choice(v, p=self.marginal))
        return out

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        b, s = self.cfg.batch_size, self.cfg.seq_len
        while True:
            seq = self._sample_seq(b * (s + 1)).reshape(b, s + 1)
            yield {"tokens": seq[:, :-1].astype(np.int32),
                   "labels": seq[:, 1:].astype(np.int32)}


def classification_dataset(num_classes: int = 10, dim: Tuple[int, ...] =
                           (8, 8, 3), n_train: int = 2048, n_test: int = 512,
                           seed: int = 0, noise: float = 0.35):
    """Gaussian class prototypes + structured masks — linearly nontrivial,
    learnable by a small CNN in a few hundred steps on CPU."""
    rng = np.random.default_rng(seed)
    d = int(np.prod(dim))
    protos = rng.normal(0, 1.0, (num_classes, d)).astype(np.float32)
    # second-order structure: class-specific feature crosses
    mix = rng.normal(0, 0.5, (num_classes, d, 8)).astype(np.float32)

    def draw(n):
        y = rng.integers(0, num_classes, n).astype(np.int32)
        z = rng.normal(0, 1.0, (n, 8)).astype(np.float32)
        x = protos[y] + np.einsum("ndk,nk->nd", mix[y], z) * 0.3
        x += rng.normal(0, noise, (n, d)).astype(np.float32)
        x = np.tanh(x)
        return x.reshape((n,) + dim), y

    xtr, ytr = draw(n_train)
    xte, yte = draw(n_test)
    return (xtr, ytr), (xte, yte)


def shard_batches(stream: TokenStream, num_hosts: int):
    """Per-host disjoint substreams for multi-host data parallelism."""
    return [TokenStream(stream.cfg, host_id=h) for h in range(num_hosts)]
