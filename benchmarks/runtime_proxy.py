"""Paper §V-D run-time analog at the kernel level: bytes moved and MXU
FLOPs per GEMM as a function of the precision pattern — the quantities the
TPU roofline converts into time. Uses the real packed layouts (and checks
the Pallas kernel agrees with its oracle on one spot shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack
from repro.core.qtypes import QuantConfig
from repro.kernels import ops, ref
from . import _common

M, K, N = 64, 2048, 2048


def gemm_bytes(mix):
    qcfg = QuantConfig(mode="serve", mix=mix)
    k4, k2, k1 = qcfg.segments(K)
    w_bytes = k4 * N // 2 + k2 * N // 4 + k1 * N // 8
    scales = (K // 16) * 4
    act_bytes = M * K * 2          # bf16 activations in
    out_bytes = M * N * 4
    flops = 2 * M * K * N
    return {"w_bytes": w_bytes + scales, "act_bytes": act_bytes,
            "out_bytes": out_bytes, "flops": flops,
            "arith_intensity": flops / (w_bytes + scales + act_bytes
                                        + out_bytes)}


def run():
    rows = []
    bf16 = {"w_bytes": K * N * 2, "act_bytes": M * K * 2,
            "out_bytes": M * N * 4, "flops": 2 * M * K * N}
    bf16["arith_intensity"] = bf16["flops"] / (
        bf16["w_bytes"] + bf16["act_bytes"] + bf16["out_bytes"])
    rows.append(("bf16", bf16))
    for name, mix in [("u4", (1.0, 0, 0)), ("u2", (0, 1.0, 0)),
                      ("u1", (0, 0, 1.0)), ("p4_mix", (0.5, 0.375, 0.125))]:
        rows.append((name, gemm_bytes(mix)))
    base = rows[0][1]["w_bytes"]
    for name, r in rows:
        r["w_compression"] = base / r["w_bytes"]

    # spot-check kernel vs oracle at this shape (correctness anchor)
    key = jax.random.PRNGKey(0)
    u = jax.random.randint(key, (256, 128), 0, 16).astype(jnp.uint8)
    wp = pack.pack_codes(u, 4)
    x = jax.random.normal(key, (8, 256))
    got = ops.packed_segment_matmul(x, wp, None, p=4, interpret=True)
    want = ref.packed_segment_matmul_ref(x, wp, None, 4)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append(("kernel_spot_check", {"max_err": err}))
    return rows


def main():
    rows, us = _common.timed(run)
    for name, r in rows:
        _common.csv_row(
            f"runtime_proxy.{name}", us / len(rows),
            "|".join(f"{k}={v:.4g}" for k, v in r.items()))
    return rows


if __name__ == "__main__":
    main()
