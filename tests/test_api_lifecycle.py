"""The ``soniq`` façade: typed phases, lifecycle round-trips, serve parity,
and the legacy-entry-point deprecation shims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import soniq
from repro.configs.base import ArchConfig
from repro.models import cnn, lm


def _tiny_lm(mode="qat"):
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=soniq.QuantConfig(mode=mode))


# ------------------------------------------------------------- phases ----
def test_phase_objects_replace_mode_strings():
    assert soniq.Phase.from_mode("qat") is soniq.Phase.QAT
    assert soniq.Phase.from_mode(soniq.Phase.SERVE) is soniq.Phase.SERVE
    with pytest.raises(ValueError):
        soniq.Phase.from_mode("int3")
    # QuantConfig accepts phase objects and round-trips them.
    qc = soniq.QuantConfig(mode=soniq.Phase.NOISE)
    assert qc.mode == "noise" and qc.phase is soniq.Phase.NOISE
    assert qc.with_mode(soniq.Phase.QAT).phase is soniq.Phase.QAT
    # lifecycle ordering
    assert soniq.Phase.FP.next is soniq.Phase.NOISE
    assert soniq.Phase.QAT.next is soniq.Phase.SERVE
    assert soniq.Phase.SERVE.next is None
    assert soniq.Phase.NOISE.needs_rng and not soniq.Phase.QAT.needs_rng
    assert not soniq.Phase.SERVE.trainable


@pytest.mark.parametrize("phase", ["noise", "qat", "serve"])
def test_param_schema_matches_init(phase):
    """Each phase's param_schema must describe exactly what linear_init
    builds for that phase."""
    from repro.core import smol
    qc = soniq.QuantConfig(mode=phase)
    k, n = 128, 32
    params = smol.linear_init(jax.random.PRNGKey(0), k, n, qc)
    schema = soniq.Phase.from_mode(phase).param_schema(k, n, qc)
    assert set(schema) == set(params)
    for name, sd in schema.items():
        if sd is None:
            assert params[name] is None
        else:
            assert params[name].shape == sd.shape, name
            assert params[name].dtype == sd.dtype, name


def test_segments_handles_k_below_group_size():
    qc = soniq.QuantConfig(mode="qat")
    k4, k2, k1 = qc.segments(8)
    assert (k4 + k2 + k1) == 8
    assert qc.num_groups(8) == 1 and qc.eff_group_size(8) == 8
    # one source of truth: the single group's precision matches the segments
    (pb,) = qc.group_pbits(8).tolist()
    assert {4: k4, 2: k2, 1: k1}[pb] == 8
    # multiples of group_size keep the historical behaviour
    assert qc.segments(128) == (64, 48, 16)


# ------------------------------------------------- linear round-trip ----
def test_linear_noise_to_qat_to_serve_roundtrip():
    qc = soniq.QuantConfig(mode=soniq.Phase.NOISE)
    k, n = 128, 16
    state = soniq.init_linear(jax.random.PRNGKey(0), k, n, qc)
    assert state.phase is soniq.Phase.NOISE
    assert state.params["s"].shape == (qc.num_groups(k),)

    qat, report = soniq.to_qat(state)
    assert qat.phase is soniq.Phase.QAT
    # shapes preserved across the boundary
    assert qat.params["w"].shape == state.params["w"].shape
    assert qat.params["pbits"].shape == (qc.num_groups(k),)
    assert report["layers"], "pattern report must cover the layer"

    served = soniq.to_serve(qat)
    assert served.phase is soniq.Phase.SERVE
    schema = soniq.Phase.SERVE.param_schema(k, n, qat.qcfg)
    # packed buffers must be uint8 and cover all k channels
    total_k = (served.params["w4"].shape[0] * 2
               + served.params["w2"].shape[0] * 4
               + served.params["w1"].shape[0] * 8)
    assert total_k == k
    assert set(schema) == set(served.params)

    # wrong-phase transitions are rejected
    with pytest.raises(ValueError):
        soniq.to_qat(qat)
    with pytest.raises(ValueError):
        soniq.to_serve(served)


def test_linear_serve_matches_qat_forward():
    """to_serve output must match the QAT fake-quant forward exactly on the
    grid (same weights, same activation quantization)."""
    qc = soniq.QuantConfig(mode=soniq.Phase.QAT)
    state = soniq.init_linear(jax.random.PRNGKey(1), 256, 32, qc)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 256))
    y_qat = soniq.apply(state, x)
    y_srv = soniq.apply(soniq.to_serve(state), x)
    np.testing.assert_allclose(np.asarray(y_qat), np.asarray(y_srv),
                               atol=1e-5, rtol=1e-5)


# ----------------------------------------------------- LM round-trip ----
def test_lm_serve_matches_qat_forward():
    cfg = _tiny_lm("qat")
    state = soniq.init(cfg, rng=jax.random.PRNGKey(0))
    tokens = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)
    lg_qat = soniq.apply(state, tokens)
    served = soniq.to_serve(state)   # stacked leaves -> rebudget (identity
    lg_srv = soniq.apply(served, tokens)  # for the mix-derived init pbits)
    assert lg_qat.shape == (2, 4, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(lg_qat), np.asarray(lg_srv),
                               atol=5e-4, rtol=1e-3)


def test_lm_noise_to_qat_preserves_shapes():
    cfg = _tiny_lm("noise")
    state = soniq.init(cfg, rng=jax.random.PRNGKey(0))
    qat, _ = soniq.to_qat(state)
    shapes = jax.tree.map(lambda a: str(a.shape), state.params)
    qshapes = jax.tree.map(lambda a: str(a.shape), qat.params)
    flat = dict(jax.tree_util.tree_flatten_with_path(shapes)[0])
    qflat = dict(jax.tree_util.tree_flatten_with_path(qshapes)[0])
    for path, shape in flat.items():
        last = str(getattr(path[-1], "key", ""))
        if last == "s":
            continue                 # replaced by pbits at the boundary
        assert qflat[path] == shape, path
    # every s leaf became a pbits leaf of the same shape
    for path, shape in flat.items():
        if str(getattr(path[-1], "key", "")) == "s":
            twin = path[:-1] + (jax.tree_util.DictKey("pbits"),)
            assert qflat[twin] == shape


# ---------------------------------------------------- CNN round-trip ----
def test_cnn_serve_matches_qat_forward():
    qc = soniq.QuantConfig(mode=soniq.Phase.QAT)
    ccfg = cnn.CNNConfig(quant=qc, channels=(32, 32), blocks_per_stage=1)
    state = soniq.init(ccfg, rng=jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 8, 3))
    y_qat = soniq.apply(state, x)
    served = soniq.to_serve(state)
    y_srv = soniq.apply(served, x)
    np.testing.assert_allclose(np.asarray(y_qat), np.asarray(y_srv),
                               atol=1e-4, rtol=1e-3)


# --------------------------------------------------- legacy shims ----
def test_legacy_entry_points_warn_and_delegate():
    from repro.core import smol
    from repro.serve import engine

    qc = soniq.QuantConfig(mode="qat")
    k, n = 64, 8
    w = np.random.default_rng(0).normal(0, 0.3, (k, n)).astype(np.float32)
    pbits = qc.group_pbits(k)
    leaf = {"w": jnp.asarray(w), "pbits": jnp.asarray(pbits)}

    with pytest.warns(DeprecationWarning):
        legacy = smol.serve_params_from_qat(leaf, qc)
    new = soniq.pack_linear(leaf, qc)
    for key in ("w4", "w2", "w1", "perm", "pbits_sorted"):
        np.testing.assert_array_equal(np.asarray(legacy[key]),
                                      np.asarray(new[key]))

    with pytest.warns(DeprecationWarning):
        rb = engine.rebudget_pbits(pbits, w, qc)
    np.testing.assert_array_equal(rb, soniq.rebudget_pbits(pbits, w, qc))

    tree = {"layer": leaf}
    with pytest.warns(DeprecationWarning):
        legacy_tree = engine.serve_convert(tree, qc)
    new_tree = soniq.convert_tree(tree, qc, rebudget=True)
    np.testing.assert_array_equal(np.asarray(legacy_tree["layer"]["w4"]),
                                  np.asarray(new_tree["layer"]["w4"]))


def test_state_is_a_pytree_through_jit():
    qc = soniq.QuantConfig(mode="qat")
    state = soniq.init_linear(jax.random.PRNGKey(0), 64, 8, qc)
    x = jnp.ones((2, 64))

    @jax.jit
    def f(s, x):
        return soniq.apply(s, x)

    np.testing.assert_allclose(np.asarray(f(state, x)),
                               np.asarray(soniq.apply(state, x)),
                               rtol=1e-6, atol=1e-6)
