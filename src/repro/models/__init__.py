from . import attention, blocks, common, lm, mlp, moe, shard, ssm
