"""GQA attention: RoPE / M-RoPE, sliding windows, chunked prefill, decode.

Memory posture (matters for the 32k-prefill dry-run cells): above
``CHUNK_THRESHOLD`` query positions, attention runs as a lax.map over query
blocks — each step sees the full KV (or, for sliding-window, a
dynamic-sliced KV band, which also removes the out-of-window FLOPs), so the
transient score tensor is [B, H, q_blk, T] instead of [B, H, S, T]. Blocks
are independent (exact softmax per step, no online-softmax carry), so remat
of the body keeps backward memory bounded too.

All projections are SmolLinear (the paper's technique applies to every
attention matmul); GQA KV heads are never materialized to H (grouped
einsum).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smol
from repro.core.qtypes import QuantConfig
from .common import apply_rope
from .shard import shard

CHUNK_THRESHOLD = 2048
Q_BLOCK = 512
NEG_INF = -1e30


def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, qcfg: QuantConfig, *, use_bias: bool = False,
              dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": smol.linear_init(ks[0], d_model, num_heads * head_dim, qcfg,
                               use_bias=use_bias, dtype=dtype),
        "wk": smol.linear_init(ks[1], d_model, num_kv_heads * head_dim, qcfg,
                               use_bias=use_bias, dtype=dtype),
        "wv": smol.linear_init(ks[2], d_model, num_kv_heads * head_dim, qcfg,
                               use_bias=use_bias, dtype=dtype),
        "wo": smol.linear_init(ks[3], num_heads * head_dim, d_model, qcfg,
                               use_bias=use_bias, dtype=dtype),
    }


def _proj_qkv(params, x, xkv, num_heads, num_kv_heads, head_dim, qcfg, rng):
    rngs = [None] * 3 if rng is None else list(jax.random.split(rng, 3))
    b, s = x.shape[:2]
    t = xkv.shape[1]
    q = smol.linear_apply(params["wq"], x, qcfg, rngs[0])
    k = smol.linear_apply(params["wk"], xkv, qcfg, rngs[1])
    v = smol.linear_apply(params["wv"], xkv, qcfg, rngs[2])
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, t, num_kv_heads, head_dim)
    v = v.reshape(b, t, num_kv_heads, head_dim)
    return (shard(q, "batch", "seq", "heads", None),
            shard(k, "batch", "seq", "kv_heads", None),
            shard(v, "batch", "seq", "kv_heads", None))


def _sdpa(q, k, v, mask):
    """q [B,S,Hk,G,D], k/v [B,T,Hk,D], mask [B,1,1,S,T] or None -> [B,S,Hk,G,D].
    fp32 scores/softmax."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(dh))
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def _causal_mask(q_pos, k_pos, window: Optional[int]):
    """[B,Sq],[B,Sk] -> bool [B,1,1,Sq,Sk] (True = attend)."""
    m = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    m &= k_pos[:, None, :] >= 0
    return m[:, None, None]


def full_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                   window: Optional[int]):
    """Dense path (short sequences / cross attention)."""
    mask = _causal_mask(q_pos, k_pos, window) if causal else None
    return _sdpa(q, k, v, mask)


def chunked_attention(q, k, v, q_pos, k_pos, *, causal: bool,
                      window: Optional[int], q_block: int = Q_BLOCK):
    """lax.map over query blocks. For sliding windows the KV is
    dynamic-sliced to the [lo, lo + window + q_block) band per block, which
    makes the FLOPs O(S * window) — exact SWA cost."""
    b, s, hk, g, d = q.shape
    t = k.shape[1]
    qb = q_block if s % q_block == 0 else int(np.gcd(s, q_block))
    nq = s // qb
    banded = causal and window is not None and (window + qb) < t
    band = None
    if banded:
        band = int(np.ceil((window + qb) / qb)) * qb     # static band width

    def one_block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * qb, qb, axis=1)
        qpi = jax.lax.dynamic_slice_in_dim(q_pos, i * qb, qb, axis=1)
        if banded:
            lo = jnp.maximum(i * qb + qb - band, 0)
            ki = jax.lax.dynamic_slice_in_dim(k, lo, band, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, lo, band, axis=1)
            kpi = jax.lax.dynamic_slice_in_dim(k_pos, lo, band, axis=1)
        else:
            ki, vi, kpi = k, v, k_pos
        mask = _causal_mask(qpi, kpi, window) if causal else None
        return _sdpa(qi, ki, vi, mask)

    out = jax.lax.map(one_block, jnp.arange(nq))          # [nq,B,qb,Hk,G,D]
    return jnp.moveaxis(out, 0, 1).reshape(b, s, hk, g, d)


def attn_apply(params, x, positions, *, num_heads: int, num_kv_heads: int,
               head_dim: int, qcfg: QuantConfig, rng=None,
               rope_theta: float = 1e4, mrope_sections=None,
               window: Optional[int] = None, causal: bool = True,
               cross_x=None, q_block: int = Q_BLOCK, use_rope: bool = True):
    """Full-sequence attention (training / prefill / encoder / cross)."""
    b, s, _ = x.shape
    xkv = x if cross_x is None else cross_x
    rng_o = None
    if rng is not None:
        rng, rng_o = jax.random.split(rng)
    q, k, v = _proj_qkv(params, x, xkv, num_heads, num_kv_heads, head_dim,
                        qcfg, rng)
    pos2d = positions if positions.ndim == 2 else positions[0]
    if cross_x is None:
        if use_rope:
            q = apply_rope(q, positions, rope_theta, mrope_sections)
            k = apply_rope(k, positions, rope_theta, mrope_sections)
        k_pos = pos2d
    else:
        k_pos = jnp.broadcast_to(jnp.arange(xkv.shape[1])[None],
                                 (b, xkv.shape[1]))
    g = num_heads // num_kv_heads
    q = q.reshape(b, s, num_kv_heads, g, head_dim)
    is_causal = causal and cross_x is None
    if s > q_block and s > CHUNK_THRESHOLD:
        # cross attention chunks too (mask-free blocks): keeps the score
        # transient at [B, H, q_blk, T] for 32k x 32k enc-dec prefill.
        o = chunked_attention(q, k, v, pos2d, k_pos, causal=is_causal,
                              window=window, q_block=q_block)
    else:
        o = full_attention(q, k, v, pos2d, k_pos, causal=is_causal,
                           window=window)
    o = o.reshape(b, s, num_heads * head_dim)
    return smol.linear_apply(params["wo"], o, qcfg, rng_o)


# ------------------------------------------------------------- decode ----
def init_kv_cache(batch: int, cache_len: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def kv_cache_specs(batch: int, cache_len: int, num_kv_heads: int,
                   head_dim: int, dtype=jnp.bfloat16) -> Dict:
    sd = jax.ShapeDtypeStruct
    return {
        "k": sd((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": sd((batch, cache_len, num_kv_heads, head_dim), dtype),
        "pos": sd((batch, cache_len), jnp.int32),
    }


def attn_decode(params, x, cache: Dict, pos, *, num_heads: int,
                num_kv_heads: int, head_dim: int, qcfg: QuantConfig,
                rope_theta: float = 1e4, mrope_sections=None,
                window: Optional[int] = None, cross_kv=None,
                use_rope: bool = True, layer_idx=None):
    """One-token decode. x [B,1,D]; pos [B] absolute position; ring-buffer
    write at pos % cache_len (cache_len == window for SWA archs).

    layer_idx: when given, cache leaves are the STACKED [L, ...] buffers
    carried through the decode scan — the new K/V are scattered in place at
    [layer_idx, b, slot] (one token's bytes) instead of rebuilding a full
    per-layer cache slice (67 MB/layer for the 32k cells — the dominant
    decode write traffic, §Perf C3).

    Positions < 0 are per-slot masks (continuous batching, DESIGN.md §10):
    an ``x`` row/lane whose position is negative is an idle batch slot or a
    prefill-chunk padding lane — its ring write is redirected out of bounds
    and dropped, so it cannot clobber a live cache entry, and the causal
    mask (``q_pos >= k_pos``) already ignores its scores. ``x`` may carry
    S > 1 tokens per row (chunked prefill): all S tokens are scattered into
    the ring, then attended with the causal-by-position mask.

    Cache families (selected by ``lm.init_cache(kv_bits=...)``, detected
    here by leaf name): the fp ring (``k``/``v``/``pos``) runs the jnp
    path below; the packed 4-bit ring (``k_codes``/... —
    ``serve/kv_quant.py``) quantizes the new K/V into the ring and
    dispatches attention to the kernel backend's ``qkv_attn_decode`` op
    (a fused flash-decode kernel on Pallas, the dequantize-and-SDPA
    oracle on ``xla_ref`` — DESIGN.md §12). Both honor the same mask /
    masked-lane / S>1 / stacked-[L,...] semantics.

    cross_kv: optional precomputed (k, v, k_pos) for encoder-decoder cross
    attention (whisper) — used as-is, no cache update.
    """
    b = x.shape[0]
    q, k_new, v_new = _proj_qkv(params, x, x, num_heads, num_kv_heads,
                                head_dim, qcfg, None)
    posb = pos[:, None] if pos.ndim == 1 else pos            # [B,S]
    if mrope_sections is not None:
        pos_r = jnp.broadcast_to(posb[None], (3,) + posb.shape)
    else:
        pos_r = posb
    if use_rope:
        q = apply_rope(q, pos_r, rope_theta, mrope_sections)
    if cross_kv is None:
        if use_rope:
            k_new = apply_rope(k_new, pos_r, rope_theta, mrope_sections)
        stacked = layer_idx is not None
        if "page_table" in cache:                # paged block-pool family
            return _paged_attn_decode(params, x, cache, posb, k_new, v_new,
                                      q, num_heads=num_heads,
                                      num_kv_heads=num_kv_heads,
                                      head_dim=head_dim, qcfg=qcfg,
                                      window=window, layer_idx=layer_idx)
        if "k_codes" in cache:                   # packed 4-bit ring family
            return _qkv_attn_decode(params, x, cache, posb, k_new, v_new,
                                    q, num_heads=num_heads,
                                    num_kv_heads=num_kv_heads,
                                    head_dim=head_dim, qcfg=qcfg,
                                    window=window, layer_idx=layer_idx)
        cache_len = cache["k"].shape[2 if stacked else 1]
        # Masked lanes (pos < 0) scatter out of bounds -> dropped.
        slot = jnp.where(posb >= 0, posb % cache_len, cache_len)
        slot = slot.astype(jnp.int32)                         # [B,S]
        bidx = jnp.arange(b)[:, None]
        kd, vd = cache["k"].dtype, cache["v"].dtype
        if stacked:
            k_st = cache["k"].at[layer_idx, bidx, slot].set(
                k_new.astype(kd), mode="drop")
            v_st = cache["v"].at[layer_idx, bidx, slot].set(
                v_new.astype(vd), mode="drop")
            kpos_st = cache["pos"].at[layer_idx, bidx, slot].set(
                posb, mode="drop")
            new_cache = {"k": k_st, "v": v_st, "pos": kpos_st}
            kk = jax.lax.dynamic_index_in_dim(k_st, layer_idx, 0, False)
            vv = jax.lax.dynamic_index_in_dim(v_st, layer_idx, 0, False)
            kp = jax.lax.dynamic_index_in_dim(kpos_st, layer_idx, 0, False)
        else:
            k = cache["k"].at[bidx, slot].set(k_new.astype(kd), mode="drop")
            v = cache["v"].at[bidx, slot].set(v_new.astype(vd), mode="drop")
            kpos = cache["pos"].at[bidx, slot].set(posb, mode="drop")
            new_cache = {"k": shard(k, "batch", "seq_shard", None, None),
                         "v": shard(v, "batch", "seq_shard", None, None),
                         "pos": kpos}
            kk, vv, kp = new_cache["k"], new_cache["v"], kpos
    else:
        kk, vv, kp = cross_kv
        new_cache = cache
    g = num_heads // num_kv_heads
    s = x.shape[1]
    qr = q.reshape(b, s, num_kv_heads, g, head_dim)
    mask = _causal_mask(posb, kp, window) if cross_kv is None else None
    o = _sdpa(qr, kk.astype(qr.dtype), vv.astype(qr.dtype), mask)
    o = o.reshape(b, s, num_heads * head_dim)
    y = smol.linear_apply(params["wo"], o, qcfg, None)
    return y, new_cache


def _qkv_attn_decode(params, x, cache, posb, k_new, v_new, q, *,
                     num_heads: int, num_kv_heads: int, head_dim: int,
                     qcfg: QuantConfig, window, layer_idx):
    """Quantized-ring decode tail of :func:`attn_decode`: quantize + ring-
    write the new K/V (masked lanes dropped, S>1 chunks, stacked layout —
    all in ``kv_quant.update_qkv_cache``), then run attention over the
    packed codes on the kernel backend's ``qkv_attn_decode`` op."""
    from repro.backend import registry       # lazy: backends import models
    from repro.serve import kv_quant
    b, s = x.shape[:2]
    new_cache = kv_quant.update_qkv_cache(cache, k_new, v_new, posb,
                                          layer_idx=layer_idx)
    if layer_idx is None:
        layer = dict(new_cache)
        layer["k_codes"] = shard(layer["k_codes"], "batch", "seq_shard",
                                 None, None)
        layer["v_codes"] = shard(layer["v_codes"], "batch", "seq_shard",
                                 None, None)
        new_cache = layer
    else:
        layer = {name: jax.lax.dynamic_index_in_dim(leaf, layer_idx, 0,
                                                    False)
                 for name, leaf in new_cache.items()}
    g = num_heads // num_kv_heads
    qr = q.reshape(b, s, num_kv_heads, g, head_dim)
    o = registry.resolve(qcfg.backend_name).qkv_attn_decode(
        qr, layer, posb, window=window)
    o = o.reshape(b, s, num_heads * head_dim).astype(x.dtype)
    return smol.linear_apply(params["wo"], o, qcfg, None), new_cache


def _paged_attn_decode(params, x, cache, posb, k_new, v_new, q, *,
                       num_heads: int, num_kv_heads: int, head_dim: int,
                       qcfg: QuantConfig, window, layer_idx):
    """Paged decode tail of :func:`attn_decode` (serve/kv_pool.py,
    DESIGN.md §13): write the new K/V into the pages the table maps for
    these positions (masked lanes and unmapped holes dropped — the host
    allocator has already made every written page private), then run
    attention through the backend's ``qkv_attn_decode_paged`` op (the
    page-table-walking flash kernel on Pallas for the packed-q4 pool, the
    gather oracle on ``xla_ref`` and for the fp pool)."""
    from repro.backend import registry       # lazy: backends import models
    from repro.serve import kv_pool
    b, s = x.shape[:2]
    new_cache = kv_pool.update_paged_cache(cache, k_new, v_new, posb,
                                           layer_idx=layer_idx)
    if layer_idx is None:
        layer = new_cache
    else:
        layer = {name: jax.lax.dynamic_index_in_dim(leaf, layer_idx, 0,
                                                    False)
                 for name, leaf in new_cache.items()}
    g = num_heads // num_kv_heads
    qr = q.reshape(b, s, num_kv_heads, g, head_dim)
    o = registry.resolve(qcfg.backend_name).qkv_attn_decode_paged(
        qr, layer, posb, window=window)
    o = o.reshape(b, s, num_heads * head_dim).astype(x.dtype)
    return smol.linear_apply(params["wo"], o, qcfg, None), new_cache
