"""Configuration dataclasses for the SONIQ quantization stack.

Terminology (see DESIGN.md §2):
  * group        — 16 consecutive input channels; the minimum precision-control
                   unit (the TPU analog of the paper's 16-bit SIMD lane).
  * block        — 8 groups = 128 channels; one "vector" in the paper's sense
                   (one TPU vreg lane row). A *pattern* assigns each of the 8
                   groups in a block a precision from {1, 2, 4}.
  * segment      — after PatternMatch + channel reordering, the K (input
                   channel) dim of a weight splits into three contiguous runs
                   [K4 | K2 | K1] of uniform precision.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from .phases import Phase, PhaseSpec

GROUP_SIZE = 16          # channels per precision group (paper Obs. 5)
GROUPS_PER_BLOCK = 8     # groups per 128-channel block (paper's 128-bit vector)
BLOCK_SIZE = GROUP_SIZE * GROUPS_PER_BLOCK
ALLOWED_BITS = (1, 2, 4)  # paper Obs. 2


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How SONIQ is applied to the linear layers of a model."""

    # "fp"    : no quantization (full-precision baseline)
    # "noise" : Phase I  — noise-injected precision search (trainable s)
    # "qat"   : Phase II — fixed per-group precisions, STE fine-tuning
    # "serve" : deployment — packed low-bit weights, dequant-in-kernel
    mode: str = "fp"

    group_size: int = GROUP_SIZE
    # Fraction of input-channel groups held at 4 / 2 / 1 bits. Used to size
    # the static [K4|K2|K1] segments for "qat"/"serve" (the trained
    # distribution replaces this at deploy time; the fractions give the
    # dry-run its static shapes). Must sum to 1.
    mix: Tuple[float, float, float] = (0.5, 0.375, 0.125)

    # "none"       : paper-faithful — values live directly on the ±2 SMOL grid
    # "per_group"  : one scale per 16-channel group on K (beyond-paper; needed
    #                for LLM weight distributions)
    scale_mode: str = "per_group"
    # Quantize activations entering each quantized matmul to the same
    # per-group precision (paper Obs. 3 "input-weight consistency").
    quantize_activations: bool = True
    # Dynamic abs-max scaling of activations. "per_tensor" reduces over the
    # whole tensor (training default); "per_token" reduces over the last dim
    # only — row-independent, which the continuous-batching serve engines
    # require (a request's tokens must not depend on batch composition —
    # DESIGN.md §10). Paper-faithful mode ("none") assumes pre-scaled
    # activations in ±2.
    act_scale_mode: str = "per_tensor"

    # Phase-I hyperparameters.
    p_init: int = 4
    lam: float = 1e-7          # λ of the bit-count regularizer

    # Number of hardware-supported patterns (paper's np design knob: 4/8/45).
    num_patterns: int = 4

    # Layers never quantized (paper excludes first/last in practice).
    skip: Tuple[str, ...] = ("embed", "lm_head", "router", "frontend")

    # Kernel backend executing the quantized hot-path ops (packed matmul,
    # quantize+pack, noise inject, fake quant). A registry name
    # ("xla_ref", "pallas_interpret", "pallas_mosaic"), an alias
    # ("pallas" — the best available Pallas flavor for this platform), or
    # None: defer to the SONIQ_BACKEND env var, else negotiate the best
    # available backend for the platform (see repro.backend.registry).
    backend: Optional[str] = None

    # Let a backend that carries a fused activation-quant GEMM prologue
    # (``fused_act_segment_matmul``) use it on the serve path. The fused
    # and two-pass forms are bit-exact (DESIGN.md §11), so this stays on;
    # False forces the two-pass reference form everywhere — benchmarks use
    # it to measure the fusion delta, parity tests to pin the exactness.
    fuse_act_quant: bool = True

    # Self-speculative draft forward (DESIGN.md §14). None = the full
    # packed mix (status quo). An int (2 being the natural SONIQ cut)
    # makes every serve-phase packed matmul read ONLY the segments whose
    # precision is <= this bound — the [K2|K1] slice of the same packed
    # carriers, zero extra weight bytes. The high-bit carriers are simply
    # skipped (no renormalization: it is the same kernel over fewer
    # segments), so the output is a cheap approximation of the full-mix
    # forward at a fraction of the weight traffic. Used by the engine's
    # draft steps; verification always runs the full mix, which is what
    # keeps speculative greedy decode token-identical.
    draft_slice_bits: Optional[int] = None

    # DEPRECATED — legacy boolean knob, superseded by ``backend``.
    # use_pallas=True is interpreted as backend="pallas" when ``backend``
    # is unset.
    use_pallas: bool = False

    # Weights arrive already fake-quantized (set by the hoisted-quantization
    # train path: quantize once per step, not once per microbatch — §Perf).
    prequantized: bool = False

    def __post_init__(self):
        if isinstance(self.mode, PhaseSpec):   # accept QuantConfig(mode=Phase.QAT)
            object.__setattr__(self, "mode", self.mode.name)
        assert self.mode in ("fp", "noise", "qat", "serve"), self.mode
        assert self.scale_mode in ("none", "per_group"), self.scale_mode
        assert self.act_scale_mode in ("none", "per_tensor", "per_token"), \
            self.act_scale_mode
        assert abs(sum(self.mix) - 1.0) < 1e-6, self.mix
        assert self.group_size % 2 == 0
        assert self.backend is None or isinstance(self.backend, str), \
            self.backend  # names are validated by the registry at resolve
        assert self.draft_slice_bits is None \
            or self.draft_slice_bits in ALLOWED_BITS, self.draft_slice_bits

    @property
    def backend_name(self) -> Optional[str]:
        """The backend selector the dispatch registry should resolve:
        ``backend`` if set, the "pallas" alias for the legacy
        ``use_pallas`` flag, else None (env var / auto-negotiation)."""
        if self.backend is not None:
            return self.backend
        return "pallas" if self.use_pallas else None

    # ----------------------------------------------------------- phases ----
    @property
    def phase(self) -> PhaseSpec:
        """The typed lifecycle phase this config selects (Phase.FP/NOISE/
        QAT/SERVE)."""
        return Phase.from_mode(self.mode)

    def with_mode(self, mode) -> "QuantConfig":
        """Copy of this config in another phase (string or Phase object)."""
        return dataclasses.replace(self, mode=Phase.from_mode(mode).name)

    # --------------------------------------------------- group geometry ----
    def eff_group_size(self, k: int) -> int:
        """Effective precision-group size for a K-dim of ``k`` channels: a
        layer narrower than ``group_size`` forms one whole group."""
        return k if k < self.group_size else self.group_size

    def num_groups(self, k: int) -> int:
        g = self.eff_group_size(k)
        assert k % g == 0, f"K={k} not a multiple of group size {g}"
        return k // g

    def group_counts(self, k: int) -> Tuple[int, int, int]:
        """(#4-bit, #2-bit, #1-bit) groups implementing ``mix`` over the
        ``num_groups(k)`` groups of a K-dim (4s first — segment order).
        A layer narrower than ``group_size`` is a single group held at 4
        bits: the sub-byte carriers of the low-precision segments need not
        divide such a k, and a narrow layer is too small to be worth the
        risk of mix-rounding it to 1 bit."""
        if k < self.group_size:
            return 1, 0, 0
        n = self.num_groups(k)
        g4 = min(int(round(self.mix[0] * n)), n)
        g2 = min(int(round(self.mix[1] * n)), n - g4)
        return g4, g2, n - g4 - g2

    def group_pbits(self, k: int) -> np.ndarray:
        """Static per-group precisions implementing ``mix``, sorted 4->2->1
        (segment-contiguous). Replaced by trained precisions after Phase I."""
        g4, g2, g1 = self.group_counts(k)
        return np.array([4] * g4 + [2] * g2 + [1] * g1, np.int8)

    def segments(self, k: int) -> Tuple[int, int, int]:
        """Split ``k`` input channels into (K4, K2, K1) — contiguous runs of
        uniform precision, each a multiple of ``eff_group_size(k)`` (and the
        total exactly ``k``). Mirrors the paper's post-training channel
        reordering. ``k < group_size`` forms a single group and lands
        entirely in one segment (consistent with ``group_pbits``).
        """
        g = self.eff_group_size(k)
        g4, g2, g1 = self.group_counts(k)
        return g4 * g, g2 * g, g1 * g

    def bits_per_param(self, k: Optional[int] = None) -> float:
        """Average bits per parameter implied by the mix (ignoring metadata,
        which is 3 ints per segment — paper Obs. 4)."""
        if k is None:
            f4, f2, f1 = self.mix
            return 4 * f4 + 2 * f2 + 1 * f1
        k4, k2, k1 = self.segments(k)
        return (4 * k4 + 2 * k2 + 1 * k1) / k


# Convenience presets matching the paper's design points (§V-A).
FP32 = QuantConfig(mode="fp")
U4 = QuantConfig(mode="qat", mix=(1.0, 0.0, 0.0))
U2 = QuantConfig(mode="qat", mix=(0.0, 1.0, 0.0))
P4 = QuantConfig(mode="qat", mix=(0.5, 0.375, 0.125), num_patterns=4)
P8 = QuantConfig(mode="qat", mix=(0.5, 0.375, 0.125), num_patterns=8)
P45 = QuantConfig(mode="qat", mix=(0.5, 0.375, 0.125), num_patterns=45)
