"""repro.analysis.jaxpr_checks: the trace-time audits hold on the real
engine, and each check demonstrably catches its injected hazard
(DESIGN.md §15)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_checks as jc
from repro.backend.base import SEGMENT_GEMM_SCOPE


# ----------------------------------------------------- hazard injection ----

def test_narrowing_convert_inside_scope_is_caught():
    """An f16 round-trip inside the segment-GEMM scope is the silent
    parity breaker the dtype audit exists for."""
    def bad(x, w):
        with jax.named_scope(SEGMENT_GEMM_SCOPE):
            h = x.astype(jnp.float16)            # narrowing: flagged
            return h.astype(jnp.float32) @ w
    jaxpr = jax.make_jaxpr(bad)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    issues = jc.check_segment_gemm_dtypes(jaxpr, "t")
    assert any("narrowing float convert" in i.message for i in issues)


def test_same_convert_outside_scope_is_allowed():
    def fine(x, w):
        h = x.astype(jnp.float16).astype(jnp.float32)   # not GEMM code
        with jax.named_scope(SEGMENT_GEMM_SCOPE):
            return x @ w
    jaxpr = jax.make_jaxpr(fine)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    assert jc.check_segment_gemm_dtypes(jaxpr, "t") == []


def test_dequant_and_widening_converts_are_allowed():
    """int->f32 dequant and bf16->f32 widening ARE the design — exact,
    so not flagged."""
    def gemm(codes, scale, x):
        with jax.named_scope(SEGMENT_GEMM_SCOPE):
            w = codes.astype(jnp.float32) * scale
            xw = x.astype(jnp.float32)
            return jax.lax.dot_general(
                xw, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    jaxpr = jax.make_jaxpr(gemm)(
        jnp.ones((8, 8), jnp.int8), jnp.float32(0.5),
        jnp.ones((4, 8), jnp.bfloat16))
    assert jc.check_segment_gemm_dtypes(jaxpr, "t") == []


def test_low_precision_accumulation_is_caught():
    def bad(x, w):
        with jax.named_scope(SEGMENT_GEMM_SCOPE):
            return jax.lax.dot_general(
                x, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.bfloat16)
    jaxpr = jax.make_jaxpr(bad)(jnp.ones((4, 8), jnp.bfloat16),
                                jnp.ones((8, 8), jnp.bfloat16))
    issues = jc.check_segment_gemm_dtypes(jaxpr, "t")
    assert any("accumulate" in i.message for i in issues)


def test_scope_propagates_into_sub_jaxprs():
    """A scan/pjit traced under the scope keeps its body in scope — the
    walker inherits membership into sub-jaxprs."""
    def bad(x):
        with jax.named_scope(SEGMENT_GEMM_SCOPE):
            def body(c, _):
                return c.astype(jnp.float16).astype(jnp.float32), ()
            out, _ = jax.lax.scan(body, x, None, length=2)
            return out
    jaxpr = jax.make_jaxpr(bad)(jnp.ones((4,)))
    issues = jc.check_segment_gemm_dtypes(jaxpr, "t")
    assert any("narrowing" in i.message for i in issues)


def test_callback_in_step_is_caught():
    def bad(x):
        return jax.pure_callback(
            lambda v: np.asarray(v) * 2, jax.ShapeDtypeStruct((4,),
                                                              jnp.float32),
            x)
    jaxpr = jax.make_jaxpr(bad)(jnp.ones((4,)))
    issues = jc.check_no_callbacks(jaxpr, "t")
    assert issues and "host round-trip" in issues[0].message
    clean = jax.make_jaxpr(lambda x: x * 2)(jnp.ones((4,)))
    assert jc.check_no_callbacks(clean, "t") == []


# ----------------------------------------------------- donation report ----

def _entry(fn, donate, args):
    from repro.serve.engine import JitEntry
    e = JitEntry("t", fn, donate_argnums=donate)
    e.jitted = jax.jit(fn, donate_argnums=donate)
    e.abstract_args = tuple(
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)
    return e


def test_donation_report_counts_aliased_inputs():
    cache = jnp.zeros((8, 16))
    e = _entry(lambda p, c: (p, c + 1.0), (1,), (jnp.zeros((4,)), cache))
    report, issues = jc.donation_report(e, "t")
    assert issues == []
    assert report["aliased_inputs"] >= 1


def test_donation_report_flags_undonated_entry():
    e = _entry(lambda c: c + 1.0, (), (jnp.zeros((8,)),))
    _, issues = jc.donation_report(e, "t")
    assert issues and "no donated operands" in issues[0].message


def test_donation_report_flags_dropped_donation():
    # Donated input aliases NO output (shape mismatch) -> markers absent.
    e = _entry(lambda c: c.sum(), (0,), (jnp.zeros((8, 8)),))
    _, issues = jc.donation_report(e, "t")
    assert issues and "silently dropped" in issues[0].message


# ------------------------------------------------- engine-level audits ----

@pytest.mark.parametrize("kwargs", [
    {"kv_layout": "ring"},
    {"kv_layout": "paged", "kv_bits": 4, "spec_tokens": 2},
], ids=["ring_fp", "paged_q4_spec"])
def test_engine_audit_clean_on_reference_backend(kwargs):
    """The tentpole gate: on the committed tree every audited engine
    variant compiles each step once, donates its cache, runs a dtype- and
    callback-clean jaxpr, and the segment scope is present (non-vacuous
    dtype audit)."""
    report, issues = jc.audit_decode_engine("xla_ref", **kwargs)
    assert issues == [], "\n".join(i.format() for i in issues)
    for name, entry in report["entries"].items():
        assert entry["trace_count"] == 1, (name, entry)
        assert entry["aliased_inputs"] >= 1, (name, entry)


def test_train_step_audit_clean():
    report, issues = jc.audit_train_step("xla_ref")
    assert issues == [], "\n".join(i.format() for i in issues)
    assert report["eqns"] > 0


# ----------------------------------------- recompile regression (serve) ----

def test_no_retrace_across_mixed_traffic_waves():
    """Two waves of traffic with different prompt/generation lengths and
    arrival patterns reuse the SAME compiled step functions — the
    fixed-shape contract that keeps serve-step latency flat. A shape leak
    (e.g. admitting a sub-chunk prefill at its natural width) turns every
    new length mix into a recompile; this is the regression gate."""
    from repro.models import lm
    from repro.serve import engine as engine_lib
    from repro.serve.scheduler import Request

    cfg = jc._tiny_arch()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    eng = engine_lib.DecodeEngine(
        params, cfg, engine_lib.EngineConfig(
            max_batch=3, cache_len=64, prefill_chunk=4, backend="xla_ref"))
    rng = np.random.default_rng(0)

    def wave(lens, news, arrivals):
        return [Request(prompt=rng.integers(1, 100, (l,)),
                        max_new_tokens=n, seed=i, arrival_step=a)
                for i, (l, n, a) in enumerate(zip(lens, news, arrivals))]

    list(eng.serve(wave((3, 7, 5, 2, 9), (4, 8, 3, 6, 5), (0,) * 5)))
    counts = {n: e.trace_count for n, e in eng.jit_table.items()
              if e.trace_count}
    assert counts and all(c == 1 for c in counts.values()), counts

    # Second wave: new lengths, staggered arrivals -> zero new traces.
    list(eng.serve(wave((1, 11, 6), (2, 5, 9), (0, 2, 4))))
    after = {n: e.trace_count for n, e in eng.jit_table.items()
             if e.trace_count}
    assert after == counts, (counts, after)
