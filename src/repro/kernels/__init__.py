"""Pallas TPU kernels for the SONIQ hot paths (validated via the
``pallas_interpret`` backend).

packed_matmul — mixed 1/2/4-bit packed GEMM (the paper's vmac_Pn)
quant_pack    — fused SMOL quantize + bit-pack
noise_inject  — fused Phase-I perturbation with in-kernel PRNG

These modules are the *implementations* behind the ``pallas_interpret`` /
``pallas_mosaic`` backends in :mod:`repro.backend`; the hot paths reach
them through the dispatch registry, never directly. The same-named
function re-exports below are the DEPRECATED pre-registry wrappers
(``kernels.ops``) kept for external callers.
"""
from . import ops, prng, ref
from .ops import noise_inject, packed_matmul, packed_segment_matmul, quantize_pack

__all__ = ["ops", "prng", "ref", "noise_inject", "packed_matmul",
           "packed_segment_matmul", "quantize_pack"]
