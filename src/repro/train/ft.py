"""Fault-tolerance runtime: heartbeats, straggler detection, elastic
re-mesh planning.

On a real cluster the coordinator runs these against per-host heartbeat
RPCs; in this container the same logic is driven by the multiprocess
cluster simulator (launch/cluster.py) and unit tests. The policies:

  * HeartbeatMonitor — a host is FAILED if no beat within `timeout`.
  * StragglerMonitor — per-host step-time EWMA; a host is a straggler when
    its EWMA exceeds `ratio` x the fleet median for `patience` consecutive
    steps. Stragglers are excluded at the next elastic re-mesh (and their
    data shards rebalanced), not killed mid-step.
  * plan_remesh — given surviving host count, pick the largest usable
    (pod, data, model) mesh <= survivors, preferring to shrink the data
    axis (gradient accumulation absorbs the lost throughput; TP/model
    degree is topology-constrained so it is preserved).
Recovery = restore latest checkpoint under the new mesh (checkpoint.py
reshards on load) and rescale num_microbatches to keep the global batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class HostState:
    last_beat: float
    ewma_step_time: Optional[float] = None
    slow_streak: int = 0
    failed: bool = False


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[int], timeout: float = 30.0):
        self.timeout = timeout
        now = time.time()
        self.hosts: Dict[int, HostState] = {h: HostState(now) for h in hosts}

    def beat(self, host: int, t: Optional[float] = None):
        self.hosts[host].last_beat = t if t is not None else time.time()

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        out = []
        for h, st in self.hosts.items():
            if now - st.last_beat > self.timeout:
                st.failed = True
            if st.failed:
                out.append(h)
        return out

    def surviving(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed_hosts(now))
        return [h for h in self.hosts if h not in bad]


class StragglerMonitor:
    def __init__(self, hosts: Sequence[int], *, alpha: float = 0.2,
                 ratio: float = 1.5, patience: int = 5):
        self.alpha, self.ratio, self.patience = alpha, ratio, patience
        self.state: Dict[int, HostState] = {
            h: HostState(time.time()) for h in hosts}

    def record(self, host: int, step_time: float):
        st = self.state[host]
        st.ewma_step_time = (step_time if st.ewma_step_time is None else
                             (1 - self.alpha) * st.ewma_step_time
                             + self.alpha * step_time)

    def stragglers(self) -> List[int]:
        ew = {h: s.ewma_step_time for h, s in self.state.items()
              if s.ewma_step_time is not None}
        if len(ew) < 2:
            return []
        med = sorted(ew.values())[len(ew) // 2]
        out = []
        for h, v in ew.items():
            st = self.state[h]
            if v > self.ratio * med:
                st.slow_streak += 1
            else:
                st.slow_streak = 0
            if st.slow_streak >= self.patience:
                out.append(h)
        return out


def plan_remesh(survivors: int, *, model: int = 16,
                chips_per_host: int = 4) -> Tuple[int, int]:
    """(data, model) for the largest mesh fitting `survivors` hosts.

    The model/TP axis is preserved (it maps to ICI topology); the data axis
    shrinks to the largest value such that data*model <= survivors*chips.
    """
    chips = survivors * chips_per_host
    assert chips >= model, "not enough chips for the TP degree"
    data = chips // model
    # keep data a power-of-two-ish divisor for even batch split
    while data > 1 and (data & (data - 1)) != 0:
        data -= 1
    return data, model


def rescale_microbatches(global_batch: int, old_data: int, new_data: int,
                         old_mb: int) -> int:
    """Keep the global batch constant: lost DP degree -> more grad accum."""
    per_dev_old = global_batch // old_data // old_mb
    new_mb = max(1, global_batch // new_data // per_dev_old)
    return new_mb
