"""Paper Fig. 7/8: accuracy / bits-per-parameter / run-time proxy for each
hardware design point: FP32, U4, U2, P4, P8, P45.

Run-time proxy: inference on CPUs/TPUs in this regime is weight-bytes
bound, so relative speedup is reported as bytes(U4)/bytes(config) — the
same memory-roofline argument the paper's GEM5 numbers follow (§V-D); the
dry-run roofline (EXPERIMENTS.md §Roofline) carries the per-arch TPU
version of this.
"""
from __future__ import annotations

import dataclasses

from repro.core.qtypes import FP32, P4, P8, P45, U2, U4
from . import _common

LAM = 2e-2   # benchmark-scale bit-penalty (paper's 1e-7 is epoch-scale)

POINTS = [("fp32", FP32, False), ("u4", U4, False), ("u2", U2, False),
          ("p4", P4, True), ("p8", P8, True), ("p45", P45, True)]


def run(steps=None):
    t = steps or _common.BENCH_STEPS
    rows = []
    for name, qcfg, two_phase in POINTS:
        qcfg = dataclasses.replace(qcfg, lam=LAM)
        r = _common.train_cnn(qcfg, t1=t if two_phase else 0, t2=2 * t)
        rows.append((name, r))
    u4_bpp = dict((n, r["bpp"]) for n, r in rows)["u4"]
    for name, r in rows:
        r["speedup_proxy_vs_u4"] = u4_bpp / r["bpp"] if r["bpp"] else 0.0
    return rows


def main(steps=None):
    rows, us = _common.timed(run, steps)
    for name, r in rows:
        _common.csv_row(
            f"fig7.{name}", us / len(rows),
            f"accuracy={r['accuracy']:.4f}|bpp={r['bpp']:.3f}"
            f"|speedup_vs_u4={r['speedup_proxy_vs_u4']:.2f}")
    return rows


if __name__ == "__main__":
    main()
