"""Pallas kernel contract audit (DESIGN.md §16).

The jaxpr audits (``repro.analysis.jaxpr_checks``) hold the *traced serve
path* to its dtype contract; this module audits the kernels themselves —
the grid/BlockSpec geometry and kernel-body arithmetic of every Pallas
entry the ``pallas_interpret``/``pallas_mosaic`` backends dispatch to —
without compiling or running a single kernel. Three passes:

* **Geometry** — for every kernel entry, over every registered arch's
  shapes x the autotuner's legal block candidates
  (:func:`repro.backend.autotune.candidates_for`), intercept
  ``pl.pallas_call`` at trace time (``jax.eval_shape``; the kernel body
  is stubbed, so a sweep of hundreds of (shape, blocks) cases costs
  milliseconds each) and check every ``BlockSpec`` against its operand:
  rank match, block divides the dim exactly, and the index map is
  statically in-bounds at all ``2^ndim`` grid corners (index maps return
  *block* indices: the last block touched is ``(idx+1)*block <= dim``).
  An off-by-one index map — the classic ring-clobber shape — reads or
  stores one block past the operand on the far corner of the grid, which
  interpret-mode happily wraps and Mosaic silently clamps; neither
  backend turns it into a test failure.
* **Body dtypes** — trace each entry once at a small all-f32 geometry
  (``jax.make_jaxpr``), find the ``pallas_call`` eqn, and walk the
  *kernel body* jaxpr: no f64 anywhere, no narrowing float->float
  ``convert_element_type``, every ``dot_general`` accumulates in fp32,
  and at least one store primitive (a kernel that never stores is a
  kernel whose output block is whatever was in the buffer). The f32
  inputs matter: entries that round-trip through ``x.dtype`` on purpose
  (documented io-dtype preservation) show no narrowing at f32, so only
  *unconditional* narrowing — the parity-breaking kind — is flagged.
* **Mapping** — the kernel<->Backend-op manifest below is held 1:1
  against reality: every manifest op exists in ``backend.base.OPS`` and
  has an ``xla_ref`` parity oracle (the method the gated-equality tests
  diff against); every jit-decorated public function in
  ``repro/kernels/*.py`` is in the manifest AND referenced by
  ``backend/pallas.py`` (an orphan kernel is dead code that silently
  stops being parity-tested); every manifest entry resolves to a real
  function.

Checks run on abstract values only — no weights, no kernel execution —
so the full sweep is safe for the CI ``static-analysis`` leg.
"""
from __future__ import annotations

import ast
import dataclasses
import importlib
import itertools
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_checks import Issue, iter_eqns

_STORE_PRIMS = ("swap", "store", "masked_swap")


# --------------------------------------------------------------------------
# The kernel <-> Backend-op manifest (the contract under audit)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelEntry:
    module: str          # dotted module under repro.kernels
    func: str            # public jit-wrapped entry function
    op: str              # backend.base.OPS name this kernel serves

    @property
    def where(self) -> str:
        return f"{self.module.rsplit('.', 1)[-1]}.{self.func}"


MANIFEST: Tuple[KernelEntry, ...] = (
    KernelEntry("repro.kernels.packed_matmul", "packed_segment_matmul",
                "packed_segment_matmul"),
    KernelEntry("repro.kernels.packed_matmul", "fused_act_segment_matmul",
                "fused_act_segment_matmul"),
    # The single-segment fast path serves the same Backend op as the
    # two-pass fused kernel (dispatched on in_kernel_scale).
    KernelEntry("repro.kernels.packed_matmul", "fused_act_selfscale_matmul",
                "fused_act_segment_matmul"),
    KernelEntry("repro.kernels.quant_pack", "quantize_pack",
                "quantize_pack"),
    KernelEntry("repro.kernels.noise_inject", "noise_inject",
                "noise_inject"),
    KernelEntry("repro.kernels.fake_quant", "fake_quant", "fake_quant"),
    KernelEntry("repro.kernels.attn_decode", "qkv_attn_decode",
                "qkv_attn_decode"),
    KernelEntry("repro.kernels.attn_decode", "qkv_attn_decode_paged",
                "qkv_attn_decode_paged"),
)


def _resolve(entry: KernelEntry):
    """The raw (unjitted) entry function — ``jax.jit`` keeps the original
    under ``__wrapped__``; tracing that directly means the pallas_call
    interception below sees every call (a jit cache would swallow
    repeats) and static kwargs are plain kwargs."""
    mod = importlib.import_module(entry.module)
    fn = getattr(mod, entry.func)
    return getattr(fn, "__wrapped__", fn)


# --------------------------------------------------------------------------
# pallas_call interception
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Capture:
    """One intercepted ``pl.pallas_call``: the grid, and each operand's
    (BlockSpec, concrete shape) pair — inputs then outputs."""
    kernel_name: str
    grid: Tuple[int, ...]
    in_pairs: List[Tuple[object, Tuple[int, ...]]]
    out_pairs: List[Tuple[object, Tuple[int, ...]]]


def _as_list(x):
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


def capture_pallas_calls(fn, args: Sequence, kwargs: Optional[Dict] = None
                         ) -> List[Capture]:
    """Trace ``fn(*args, **kwargs)`` abstractly (``jax.eval_shape``) with
    ``pl.pallas_call`` replaced by a recorder stub that never traces the
    kernel body — it notes the grid/specs/operand shapes and returns
    zeros of ``out_shape``. Returns every capture in call order."""
    import jax.experimental.pallas as pl

    records: List[Capture] = []
    real = pl.pallas_call

    def fake(kernel, out_shape=None, *, grid=(), in_specs=None,
             out_specs=None, **_kw):
        def runner(*operands):
            outs = _as_list(out_shape)
            records.append(Capture(
                kernel_name=getattr(kernel, "func", kernel).__name__
                if hasattr(kernel, "func") or hasattr(kernel, "__name__")
                else str(kernel),
                grid=tuple(grid) if isinstance(grid, (tuple, list))
                else (int(grid),),
                in_pairs=list(zip(_as_list(in_specs),
                                  [tuple(o.shape) for o in operands])),
                out_pairs=list(zip(_as_list(out_specs),
                                   [tuple(s.shape) for s in outs])),
            ))
            zeros = [jnp.zeros(s.shape, s.dtype) for s in outs]
            return tuple(zeros) if isinstance(out_shape, (tuple, list)) \
                else zeros[0]
        return runner

    pl.pallas_call = fake
    try:
        jax.eval_shape(lambda *a: fn(*a, **(kwargs or {})), *args)
    finally:
        pl.pallas_call = real
    return records


# --------------------------------------------------------------------------
# Geometry pass
# --------------------------------------------------------------------------

def _check_pair(spec, shape: Tuple[int, ...], grid: Tuple[int, ...],
                role: str, where: str) -> List[Issue]:
    issues: List[Issue] = []
    if spec is None:                       # whole-array mapping: trivially
        return issues                      # in bounds
    block = tuple(int(b) for b in spec.block_shape)
    if len(block) != len(shape):
        issues.append(Issue(
            "kernel_geometry", where,
            f"{role}: BlockSpec rank {len(block)} != operand rank "
            f"{len(shape)} (block={block}, shape={shape})"))
        return issues
    for d, (b, n) in enumerate(zip(block, shape)):
        if b <= 0 or n % b:
            issues.append(Issue(
                "kernel_geometry", where,
                f"{role}: block dim {d} = {b} does not divide operand "
                f"dim {n} (block={block}, shape={shape}) — the ragged "
                f"tail block reads/writes out of bounds (no masking in "
                f"these kernels)"))
    if any(b <= 0 or n % b for b, n in zip(block, shape)):
        return issues                      # corner math needs clean tiling
    corners = itertools.product(*[(0, g - 1) if g > 1 else (0,)
                                  for g in grid])
    for corner in corners:
        try:
            idx = spec.index_map(*corner)
        except Exception as e:
            issues.append(Issue(
                "kernel_geometry", where,
                f"{role}: index map not statically evaluable at grid "
                f"corner {corner}: {e!r} — the audit cannot prove the "
                f"kernel in-bounds"))
            return issues
        idx = tuple(int(i) for i in (idx if isinstance(idx, tuple)
                                     else (idx,)))
        if len(idx) != len(block):
            issues.append(Issue(
                "kernel_geometry", where,
                f"{role}: index map returns {len(idx)} indices for a "
                f"rank-{len(block)} block at corner {corner}"))
            return issues
        for d, (i, b, n) in enumerate(zip(idx, block, shape)):
            if i < 0 or (i + 1) * b > n:
                issues.append(Issue(
                    "kernel_geometry", where,
                    f"{role}: index map out of bounds at grid corner "
                    f"{corner}: dim {d} block index {i} spans elements "
                    f"[{i * b}, {(i + 1) * b}) of a {n}-wide operand — "
                    f"interpret mode wraps and Mosaic clamps, so this "
                    f"block silently reads/clobbers the wrong data"))
    return issues


def check_capture_geometry(cap: Capture, where: str) -> List[Issue]:
    """Divisibility + static in-bounds for one intercepted pallas_call."""
    issues: List[Issue] = []
    for k, (spec, shape) in enumerate(cap.in_pairs):
        issues.extend(_check_pair(spec, shape, cap.grid,
                                  f"in_specs[{k}]", where))
    for k, (spec, shape) in enumerate(cap.out_pairs):
        issues.extend(_check_pair(spec, shape, cap.grid,
                                  f"out_specs[{k}]", where))
    return issues


# --------------------------------------------------------------------------
# Kernel-body dtype pass
# --------------------------------------------------------------------------

def check_entry_body(fn, args: Sequence, kwargs: Optional[Dict],
                     where: str) -> List[Issue]:
    """Trace ``fn`` (for real — ``jax.make_jaxpr``) and audit every
    pallas_call's *kernel body* jaxpr: fp32 accumulation, no f64, no
    narrowing float converts, at least one store. Call with all-f32
    operands so intentional io-dtype round-trips vanish (module
    docstring)."""
    issues: List[Issue] = []
    f32 = jnp.dtype(jnp.float32)
    f64 = jnp.dtype(jnp.float64)
    try:
        jaxpr = jax.make_jaxpr(lambda *a: fn(*a, **(kwargs or {})))(*args)
    except Exception as e:
        return [Issue("kernel_dtype", where,
                      f"entry failed to trace at the audit geometry: "
                      f"{e!r}")]
    bodies = [eqn.params["jaxpr"] for eqn, _ in iter_eqns(jaxpr)
              if eqn.primitive.name == "pallas_call"]
    if not bodies:
        return [Issue("kernel_dtype", where,
                      "no pallas_call in the traced entry — the kernel "
                      "path silently fell through, so nothing below it "
                      "is audited")]
    for body in bodies:
        stores = 0
        for eqn, _ in iter_eqns(body):
            name = eqn.primitive.name
            if name in _STORE_PRIMS:
                stores += 1
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "dtype", None) == f64:
                    issues.append(Issue(
                        "kernel_dtype", where,
                        f"float64 value produced by `{name}` inside the "
                        f"kernel body — an x64 promotion breaks parity "
                        f"with every fp32 backend"))
            if name == "convert_element_type":
                new = jnp.dtype(eqn.params["new_dtype"])
                olds = [v.aval.dtype for v in eqn.invars
                        if hasattr(getattr(v, "aval", None), "dtype")]
                old = olds[0] if olds else None
                if old is not None \
                        and jnp.issubdtype(old, jnp.floating) \
                        and jnp.issubdtype(new, jnp.floating) \
                        and new.itemsize < jnp.dtype(old).itemsize:
                    issues.append(Issue(
                        "kernel_dtype", where,
                        f"narrowing float convert {old}->{new} inside "
                        f"the kernel body at f32 io — unconditional "
                        f"precision loss in the quantized arithmetic"))
            elif name == "dot_general":
                pref = eqn.params.get("preferred_element_type")
                outs = [v.aval.dtype for v in eqn.outvars
                        if hasattr(getattr(v, "aval", None), "dtype")]
                bad_out = any(jnp.issubdtype(d, jnp.floating) and d != f32
                              for d in outs)
                if (pref is not None and jnp.dtype(pref) != f32) or bad_out:
                    issues.append(Issue(
                        "kernel_dtype", where,
                        f"kernel dot_general does not accumulate in fp32 "
                        f"(preferred_element_type={pref}, out={outs})"))
        if stores == 0:
            issues.append(Issue(
                "kernel_dtype", where,
                "kernel body contains no store primitive — the output "
                "block is never written"))
    return issues


# --------------------------------------------------------------------------
# Mapping pass
# --------------------------------------------------------------------------

def _jit_decorated_public_functions(path: Path) -> List[str]:
    """Module-level public ``def``s carrying a jit decorator (plain
    ``@jax.jit`` or ``@functools.partial(jax.jit, ...)``)."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out = []
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef) \
                or node.name.startswith("_"):
            continue
        for deco in node.decorator_list:
            if any(isinstance(n, (ast.Attribute, ast.Name))
                   and (getattr(n, "attr", None) == "jit"
                        or getattr(n, "id", None) == "jit")
                   for n in ast.walk(deco)):
                out.append(node.name)
                break
    return out


def check_kernel_mapping(root: Optional[Path] = None) -> List[Issue]:
    """Hold MANIFEST 1:1 against backend.base.OPS, the xla_ref parity
    oracle, the kernels package on disk, and backend/pallas.py."""
    from repro.backend import base as backend_base
    from repro.backend.xla_ref import XLA_REF

    if root is None:
        root = Path(__file__).resolve().parents[1]   # src/repro
    issues: List[Issue] = []
    pallas_src = (root / "backend" / "pallas.py").read_text()

    for entry in MANIFEST:
        where = entry.where
        if entry.op not in backend_base.OPS:
            issues.append(Issue(
                "kernel_mapping", where,
                f"manifest op '{entry.op}' is not in backend.base.OPS — "
                f"the kernel serves an op no Backend declares"))
        hook = backend_base._OP_IMPL_HOOK.get(entry.op, entry.op)
        if not callable(getattr(XLA_REF, hook, None)):
            issues.append(Issue(
                "kernel_mapping", where,
                f"op '{entry.op}' has no xla_ref parity oracle "
                f"(missing method '{hook}') — nothing to gate the "
                f"kernel's numerics against"))
        try:
            fn = getattr(importlib.import_module(entry.module),
                         entry.func, None)
        except ImportError as e:
            fn, err = None, e
            issues.append(Issue("kernel_mapping", where,
                                f"manifest module does not import: {e!r}"))
            continue
        if fn is None:
            issues.append(Issue(
                "kernel_mapping", where,
                "manifest names a function that does not exist"))
        if f".{entry.func}" not in pallas_src:
            issues.append(Issue(
                "kernel_mapping", where,
                "kernel entry is never referenced by backend/pallas.py — "
                "an orphan: it runs in no backend, so the parity gate "
                "never sees it"))

    manifest_funcs = {e.func for e in MANIFEST}
    for path in sorted((root / "kernels").glob("*.py")):
        for name in _jit_decorated_public_functions(path):
            if name not in manifest_funcs:
                issues.append(Issue(
                    "kernel_mapping", f"kernels/{path.name}",
                    f"public jit entry '{name}' is not in the kernel "
                    f"audit manifest — unaudited kernel surface"))
    return issues


# --------------------------------------------------------------------------
# Shape-case sweep over registered archs x autotune candidates
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arch_cases(cfg) -> List[Dict]:
    """Audit cases this arch contributes: one per kernel entry, with the
    operand avals and the autotune (op, shape) key that enumerates its
    block candidates. Degenerate dims (attention-free SSM archs, zero
    d_ff) contribute nothing for the affected entries."""
    from repro.core.qtypes import GROUP_SIZE
    f32, f16 = jnp.float32, jnp.float16
    u8, i32 = jnp.uint8, jnp.int32
    p, m = 4, 16
    kp, n = int(cfg.d_model), int(cfg.d_ff)
    cases: List[Dict] = []
    if kp > 0 and n > 0 and kp % GROUP_SIZE == 0:
        x = _sds((m, kp), f32)
        wp = _sds((kp * p // 8, n), u8)
        sc = _sds((kp // GROUP_SIZE,), f32)
        cases += [
            dict(func="packed_segment_matmul", op="packed_segment_matmul",
                 shape=(m, kp, n), args=(x, wp, sc),
                 static={"p": p, "act_quant": True}),
            dict(func="fused_act_segment_matmul",
                 op="fused_act_segment_matmul", shape=(m, kp, n),
                 args=(x, _sds((m, 1), f32), wp, sc), static={"p": p}),
            dict(func="fused_act_selfscale_matmul",
                 op="fused_act_segment_matmul", shape=(m, kp, n),
                 args=(x, wp, sc), static={"p": p}),
            dict(func="quantize_pack", op="quantize_pack", shape=(kp, n),
                 args=(_sds((kp, n), f32), sc), static={"p": p}),
            dict(func="noise_inject", op="noise_inject", shape=(kp, n),
                 args=(_sds((kp, n), f32), sc, np.uint32(0)), static={}),
            dict(func="fake_quant", op="fake_quant", shape=(m, kp),
                 args=(_sds((m, kp), f32), _sds((kp // GROUP_SIZE,), f32),
                       _sds((m, 1), f32)), static={"row_scale": True}),
            dict(func="fake_quant", op="fake_quant", shape=(m, kp),
                 args=(_sds((m, kp), f32), _sds((kp // GROUP_SIZE,), f32),
                       _sds((kp // GROUP_SIZE,), f32)),
                 static={"row_scale": False}),
        ]
    hk, d = int(cfg.num_kv_heads), int(cfg.head_dim)
    g = int(cfg.num_heads) // hk if hk > 0 else 0
    if hk > 0 and g > 0 and d > 0 and d % 2 == 0:
        b, s, t = 2, 1, 512
        q = _sds((b, s, hk, g, d), f32)
        cases.append(dict(
            func="qkv_attn_decode", op="qkv_attn_decode",
            shape=(b * hk * s * g, t, d),
            args=(q, _sds((b, t, hk, d // 2), u8),
                  _sds((b, t, hk, d // 2), u8), _sds((b, t, hk, 1), f16),
                  _sds((b, t, hk, 1), f16), _sds((b, t), i32),
                  _sds((b, s), i32)),
            static={"window": None}))
        npages, ps, npg = 9, 16, 4
        cases.append(dict(
            func="qkv_attn_decode_paged", op="qkv_attn_decode_paged",
            shape=(b * hk * s * g, npg, ps, d),
            args=(q, _sds((npages, ps, hk, d // 2), u8),
                  _sds((npages, ps, hk, d // 2), u8),
                  _sds((npages, ps, hk, 1), f16),
                  _sds((npages, ps, hk, 1), f16),
                  _sds((npages, ps), i32), _sds((b, npg), i32),
                  _sds((b, s), i32)),
            static={"window": None}))
    return cases


def _spread(seq: List, limit: int) -> List:
    """At most ``limit`` items, evenly spread (endpoints kept) — the
    candidate grid's extremes are where tiling bugs live."""
    if len(seq) <= limit:
        return list(seq)
    if limit == 1:
        return [seq[0]]
    idxs = sorted({round(i * (len(seq) - 1) / (limit - 1))
                   for i in range(limit)})
    return [seq[i] for i in idxs]


# Small all-f32 geometries for the per-entry body dtype pass; block
# kwargs are omitted (the entries' fit_block snapping handles defaults).
def _body_cases() -> List[Dict]:
    f32, f16 = jnp.float32, jnp.float16
    u8, i32 = jnp.uint8, jnp.int32
    m, kp, n, p = 8, 32, 16, 4
    x = _sds((m, kp), f32)
    wp = _sds((kp * p // 8, n), u8)
    sc = _sds((kp // 16,), f32)
    b, s, hk, g, d, t = 1, 1, 1, 2, 8, 16
    q = _sds((b, s, hk, g, d), f32)
    npages, ps, npg = 3, 8, 2
    return [
        dict(func="packed_segment_matmul", args=(x, wp, sc),
             static={"p": p, "act_quant": True}),
        dict(func="fused_act_segment_matmul",
             args=(x, _sds((m, 1), f32), wp, sc), static={"p": p}),
        dict(func="fused_act_selfscale_matmul", args=(x, wp, sc),
             static={"p": p}),
        dict(func="quantize_pack", args=(_sds((kp, n), f32), sc),
             static={"p": p}),
        dict(func="noise_inject",
             args=(_sds((kp, n), f32), sc, np.uint32(0)), static={}),
        dict(func="fake_quant",
             args=(x, _sds((kp // 16,), f32), _sds((m, 1), f32)),
             static={"row_scale": True}),
        dict(func="fake_quant",
             args=(x, _sds((kp // 16,), f32), _sds((kp // 16,), f32)),
             static={"row_scale": False}),
        dict(func="qkv_attn_decode",
             args=(q, _sds((b, t, hk, d // 2), u8),
                   _sds((b, t, hk, d // 2), u8), _sds((b, t, hk, 1), f16),
                   _sds((b, t, hk, 1), f16), _sds((b, t), i32),
                   _sds((b, s), i32)),
             static={"window": None, "block_t": t}),
        dict(func="qkv_attn_decode_paged",
             args=(q, _sds((npages, ps, hk, d // 2), u8),
                   _sds((npages, ps, hk, d // 2), u8),
                   _sds((npages, ps, hk, 1), f16),
                   _sds((npages, ps, hk, 1), f16),
                   _sds((npages, ps), i32), _sds((b, npg), i32),
                   _sds((b, s), i32)),
             static={"window": None, "block_t": ps}),
    ]


def run_kernel_audit(archs: Optional[Iterable[str]] = None, *,
                     max_candidates: int = 6
                     ) -> Tuple[Dict, List[Issue]]:
    """The CI entry point. Geometry-sweeps every manifest kernel over
    every registered arch's shapes x (capped, endpoint-preserving) block
    candidates, body-audits each entry once at a small f32 geometry, and
    checks the kernel<->op mapping. Returns (report, issues)."""
    from repro.backend import autotune
    from repro.configs import registry

    import repro.configs  # noqa: F401  (trigger arch registrations)

    if archs is None:
        archs = registry.list_archs()
    raw = {e.func: _resolve(e) for e in MANIFEST}
    issues: List[Issue] = []
    entries: Dict[str, Dict[str, int]] = {
        e.func: {"cases": 0, "candidates": 0} for e in MANIFEST}
    seen = set()
    truncated = 0
    for name in archs:
        for case in _arch_cases(registry.get_config(name)):
            key = (case["func"], case["shape"],
                   tuple(sorted(case["static"].items())))
            if key in seen:
                continue
            seen.add(key)
            cands = autotune.candidates_for(case["op"], case["shape"])
            kept = _spread(cands, max_candidates)
            truncated += len(cands) - len(kept)
            entries[case["func"]]["cases"] += 1
            for blocks in kept:
                entries[case["func"]]["candidates"] += 1
                where = (f"{case['func']}[shape="
                         f"{'x'.join(map(str, case['shape']))},"
                         f"{','.join(f'{k}={v}' for k, v in sorted(blocks.items()))}]")
                kwargs = {**case["static"], **blocks, "interpret": True}
                try:
                    caps = capture_pallas_calls(raw[case["func"]],
                                                case["args"], kwargs)
                except Exception as e:
                    issues.append(Issue(
                        "kernel_geometry", where,
                        f"entry failed to trace: {e!r}"))
                    continue
                if not caps:
                    issues.append(Issue(
                        "kernel_geometry", where,
                        "no pallas_call captured — the entry silently "
                        "skipped its kernel"))
                for cap in caps:
                    issues.extend(check_capture_geometry(cap, where))
    body_audited = []
    for case in _body_cases():
        where = f"{case['func']}[body]"
        body_audited.append(case["func"])
        issues.extend(check_entry_body(
            raw[case["func"]], case["args"],
            {**case["static"], "interpret": True}, where))
    issues.extend(check_kernel_mapping())
    report = {
        "archs": sorted(archs),
        "cases": sum(e["cases"] for e in entries.values()),
        "candidates": sum(e["candidates"] for e in entries.values()),
        "candidates_truncated": truncated,
        "max_candidates": max_candidates,
        "entries": entries,
        "body_audited": sorted(set(body_audited)),
        "manifest_size": len(MANIFEST),
    }
    return report, issues
