"""ShapeDtypeStruct stand-ins (no allocation) for every model input, state,
and cache — the inputs to the multi-pod dry-run, plus the step functions it
lowers.

SHAPES: the assigned input-shape set. train_* lowers train_step;
prefill_* lowers the forward prefill; decode_*/long_* lower serve_step
(one new token against a KV cache of seq_len — ring-bounded to the window
for SWA archs, O(1) state for SSM).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.phases import Phase
from repro.models import lm
from repro.optim import adamw
from repro.train import state as state_lib

# Archs whose fp32 optimizer state exceeds 16 GiB/chip at 256 chips run the
# reduced-precision-moments configuration (DESIGN.md §4).
LOW_MEM_OPT_THRESHOLD = 200e9


def train_config_for(arch: str, mesh) -> state_lib.TrainConfig:
    cfg = get_config(arch)
    moment_dtype = "bfloat16" if cfg.param_count() > LOW_MEM_OPT_THRESHOLD \
        else "float32"
    return state_lib.TrainConfig(
        num_microbatches=microbatching(arch, mesh),
        adamw=adamw.AdamWConfig(moment_dtype=moment_dtype))

SD = jax.ShapeDtypeStruct

SHAPES: Dict[str, Dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k needs sub-quadratic attention: run for SSM/hybrid/SWA archs,
# skip for pure full-attention archs (DESIGN.md §5).
LONG_OK = {"mamba2-2.7b", "jamba-1.5-large-398b", "h2o-danube-1.8b",
           "mixtral-8x22b"}


def cell_skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in LONG_OK:
        return ("pure full-attention arch: 500k decode cache is "
                "O(seq) with quadratic-history attention — skipped per "
                "task brief (see DESIGN.md §5)")
    return None


def serve_quant(cfg):
    return cfg.with_quant_mode(Phase.SERVE)


def qat_quant(cfg):
    return cfg.with_quant_mode(Phase.QAT)


def batch_specs(arch: str, shape: str) -> Dict[str, SD]:
    """Training / prefill batch inputs."""
    cfg = get_config(arch)
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    specs = {"tokens": SD((b, s), jnp.int32), "labels": SD((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["positions"] = SD((3, b, s), jnp.int32)
    if cfg.family == "audio":
        specs["frames"] = SD((b, s, cfg.frontend_dim), jnp.bfloat16)
    return specs


def decode_specs(arch: str, shape: str) -> Dict:
    """serve_step inputs: packed params (from eval_shape), KV/SSM cache of
    seq_len, one token per sequence."""
    cfg = serve_quant(get_config(arch))
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    cache = lm.init_cache(cfg, b, s, jnp.bfloat16,
                          enc_len=1504 if cfg.family == "audio" else 0,
                          specs=True)
    return {
        "cache": cache,
        "tokens": SD((b,), jnp.int32),
        "pos": SD((b,), jnp.int32),
    }


def param_specs(arch: str, *, serve: bool):
    cfg = serve_quant(get_config(arch)) if serve else qat_quant(
        get_config(arch))
    return jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg)), cfg


def train_state_specs(arch: str, tcfg: state_lib.TrainConfig):
    cfg = qat_quant(get_config(arch))

    def build():
        return state_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg)

    return jax.eval_shape(build), cfg


def microbatching(arch: str, mesh) -> int:
    """Grad-accum depth for train_4k: per-device microbatch of 1 for the
    big archs, 2 mid, 4 small."""
    cfg = get_config(arch)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    per_dev = SHAPES["train_4k"]["batch"] // dp
    mb_size = 1 if cfg.d_model >= 6144 else (2 if cfg.d_model >= 2048 else 4)
    return max(1, per_dev // min(mb_size, per_dev))


# ------------------------------------------------------ step functions ----
def make_train_step(cfg, tcfg: state_lib.TrainConfig):
    def step(state, batch, rng):
        return state_lib.train_step(state, batch, cfg, tcfg, rng)
    return step


def make_prefill_step(cfg):
    """Inference prefill: forward over the full prompt with serve-mode
    (packed) weights; returns last-position logits."""
    def step(params, batch):
        hidden, _ = lm.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), frames=batch.get("frames"),
            positions=batch.get("positions"))
        return lm.logits(params, cfg, hidden[:, -1])
    return step


def make_serve_step(cfg):
    def step(params, cache, tokens, pos):
        return lm.decode_step(params, cfg, cache, tokens, pos)
    return step
