"""Trace-time audits of the jitted serve/train hot paths (DESIGN.md §15).

The AST lint (``repro.analysis.lint``) catches hazard classes you can see
in source; these audits catch the ones you can only see after tracing.
They build a tiny ``DecodeEngine`` per registered backend, drive a
mixed-length traffic trace through it, and then:

* **Recompile guard** — every jitted step function (the engine's
  :class:`repro.serve.engine.JitEntry` table) must have compiled exactly
  once across the whole trace. The engine's fixed-shape contract (padded
  admission sets, fixed prefill chunk, fixed speculative width) is what
  makes host-latency-bound decode viable; a shape leak that retraces per
  occupancy pattern is a silent 100x serve-step regression.
* **Segment-GEMM dtype contract** — walk each step's ClosedJaxpr
  (recursively through pjit/scan/cond/custom-vjp/pallas sub-jaxprs) and,
  inside the ``soniq_segment_gemm`` name scope the shared driver tags
  (``repro.backend.base.SEGMENT_GEMM_SCOPE``), reject narrowing
  float→float ``convert_element_type`` (an f16 round-trip inside the
  packed GEMM is exactly the silent precision change that breaks
  cross-backend token parity), any float64, and any ``dot_general`` that
  does not accumulate in fp32. Integer→float converts are the dequant
  itself and fp16/bf16→fp32 widenings are the documented accumulate
  promotion — both exact, both allowed.
* **No host callbacks** — ``pure_callback``/``io_callback``/
  ``debug_callback`` inside a serve step is a per-step host round-trip
  (and a nondeterminism hole); banned outright.
* **Donation coverage** — every traced step function must donate its
  cache-sized operand (declared ``donate_argnums`` non-empty AND the
  lowered module actually carries input/output aliasing markers), so the
  KV cache never double-buffers.

All audits run on abstract values — no weights are trained, traffic is a
few dozen tiny-model tokens per engine (interpret-mode Pallas included).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend.base import SEGMENT_GEMM_SCOPE

_CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback",
                        "infeed", "outfeed")


@dataclasses.dataclass(frozen=True)
class Issue:
    check: str                   # "recompile" | "segment_dtype" | ...
    where: str                   # "<backend>/<engine>/<fn>" context
    message: str

    def format(self) -> str:
        return f"[{self.check}] {self.where}: {self.message}"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# Jaxpr walking
# --------------------------------------------------------------------------

def _sub_jaxprs(value) -> Iterator:
    """Jaxpr objects nested in an eqn param value (ClosedJaxpr, Jaxpr,
    or containers of them) — covers pjit, scan, while, cond branches,
    custom-vjp and pallas_call without naming their param keys."""
    if hasattr(value, "jaxpr"):              # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):             # Jaxpr
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr, in_segment: bool = False
              ) -> Iterator[Tuple[object, bool]]:
    """Yield ``(eqn, in_segment_gemm_scope)`` over the whole jaxpr tree.
    Scope membership comes from the eqn's source-info name stack and is
    inherited by sub-jaxprs (a pallas_call traced under the scope keeps
    its kernel body in scope even though the inner eqns' stacks reset)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)   # accept ClosedJaxpr
    for eqn in jaxpr.eqns:
        scoped = in_segment or (
            SEGMENT_GEMM_SCOPE in str(eqn.source_info.name_stack))
        yield eqn, scoped
        for p in eqn.params.values():
            for sub in _sub_jaxprs(p):
                yield from iter_eqns(sub, scoped)


def _avals(vars_) -> Iterator:
    for v in vars_:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            yield aval


def check_segment_gemm_dtypes(closed_jaxpr, where: str) -> List[Issue]:
    """The quantized-GEMM dtype contract (module docstring)."""
    issues: List[Issue] = []
    f64 = jnp.dtype(jnp.float64)
    f32 = jnp.dtype(jnp.float32)
    for eqn, scoped in iter_eqns(closed_jaxpr):
        for aval in _avals(eqn.outvars):
            if aval.dtype == f64:
                issues.append(Issue(
                    "segment_dtype", where,
                    f"float64 value produced by `{eqn.primitive.name}` — "
                    f"an x64 promotion in the serve path breaks parity "
                    f"with every fp32 backend"))
                break
        if not scoped:
            continue
        name = eqn.primitive.name
        if name == "convert_element_type":
            new = jnp.dtype(eqn.params["new_dtype"])
            olds = [a.dtype for a in _avals(eqn.invars)]
            old = olds[0] if olds else None
            if old is not None and \
                    jnp.issubdtype(old, jnp.floating) and \
                    jnp.issubdtype(new, jnp.floating) and \
                    new.itemsize < jnp.dtype(old).itemsize:
                issues.append(Issue(
                    "segment_dtype", where,
                    f"narrowing float convert {old}->{new} inside the "
                    f"segment-GEMM scope — silent precision loss in the "
                    f"quantized arithmetic (the parity contract requires "
                    f"the deployed GEMM to run the exact trained grid)"))
        elif name == "dot_general":
            pref = eqn.params.get("preferred_element_type")
            outs = [a.dtype for a in _avals(eqn.outvars)]
            out_ok = all(d == f32 for d in outs if
                         jnp.issubdtype(d, jnp.floating))
            if (pref is not None and jnp.dtype(pref) != f32) or not out_ok:
                issues.append(Issue(
                    "segment_dtype", where,
                    f"segment GEMM dot_general does not accumulate in "
                    f"fp32 (preferred_element_type={pref}, out={outs})"))
    return issues


def check_no_callbacks(closed_jaxpr, where: str) -> List[Issue]:
    issues = []
    for eqn, _ in iter_eqns(closed_jaxpr):
        if any(eqn.primitive.name.startswith(c)
               for c in _CALLBACK_PRIMITIVES):
            issues.append(Issue(
                "callback", where,
                f"`{eqn.primitive.name}` inside a jitted serve/train "
                f"step — a host round-trip (and nondeterminism hole) on "
                f"the hot path"))
    return issues


# --------------------------------------------------------------------------
# Donation coverage
# --------------------------------------------------------------------------

def donation_report(entry, where: str) -> Tuple[Dict, List[Issue]]:
    """Lower one engine :class:`~repro.serve.engine.JitEntry` at its
    recorded abstract shapes and cross-check the declared donation against
    the module's input/output aliasing markers."""
    issues: List[Issue] = []
    n_args = len(jax.tree_util.tree_leaves(entry.abstract_args))
    aliased = donors = -1
    try:
        txt = entry.jitted.lower(*entry.abstract_args).as_text()
        aliased = txt.count("tf.aliasing_output")
        donors = txt.count("jax.buffer_donor")
    except Exception as e:                       # pragma: no cover
        issues.append(Issue("donation", where, f"lowering failed: {e!r}"))
    report = {"n_args": n_args, "donate_argnums": list(entry.donate_argnums),
              "aliased_inputs": aliased, "buffer_donors": donors}
    if not entry.donate_argnums:
        issues.append(Issue(
            "donation", where,
            "jitted step declares no donated operands — cache-sized "
            "buffers double-buffer every step (SQ004)"))
    elif aliased == 0 and donors == 0:
        issues.append(Issue(
            "donation", where,
            "donate_argnums declared but the lowered module carries no "
            "aliasing/donor markers — donation silently dropped "
            "(dtype/shape mismatch between the donated input and every "
            "output?)"))
    return report, issues


# --------------------------------------------------------------------------
# Engine traffic audit
# --------------------------------------------------------------------------

def _tiny_arch(**kw):
    from repro.configs.base import ArchConfig
    from repro.core.qtypes import QuantConfig
    kw.setdefault("quant", QuantConfig(mode="qat"))
    return ArchConfig(
        name="analysis-tiny", family="dense", num_layers=2, d_model=64,
        num_heads=2, num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32, **kw)


def _mixed_requests(seed: int = 0):
    """Mixed prompt lengths, generation lengths and arrival order: over a
    max_batch-3 engine this varies batch occupancy, chunk widths and slot
    reuse — the traffic shapes that historically triggered retraces."""
    from repro.serve.scheduler import Request
    rng = np.random.default_rng(seed)
    lens = (3, 7, 5, 2, 9, 4)
    news = (4, 8, 3, 6, 5, 2)
    return [Request(prompt=rng.integers(1, 100, (l,)), max_new_tokens=n,
                    seed=i)
            for i, (l, n) in enumerate(zip(lens, news))]


# Step functions whose jaxpr runs packed segment GEMMs (serve forwards).
_GEMM_ENTRIES = ("step", "decode", "prefill", "draft", "verify")


def audit_decode_engine(backend: str, *, kv_layout: str = "ring",
                        kv_bits: Optional[int] = None, spec_tokens: int = 0,
                        seed: int = 0) -> Tuple[Dict, List[Issue]]:
    """Build a tiny packed-checkpoint ``DecodeEngine`` on ``backend``,
    serve a mixed traffic trace, then run every audit over its jit table.
    Returns (report, issues)."""
    from repro.models import lm
    from repro.serve import engine as engine_lib

    where_root = f"{backend}/DecodeEngine[{kv_layout}" \
                 f"{',q4' if kv_bits else ''}" \
                 f"{f',spec{spec_tokens}' if spec_tokens else ''}]"
    cfg = _tiny_arch()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(seed), cfg))
    ecfg = engine_lib.EngineConfig(
        max_batch=3, cache_len=64, prefill_chunk=4, backend=backend,
        kv_bits=kv_bits, kv_layout=kv_layout, page_size=8,
        spec_tokens=spec_tokens)
    eng = engine_lib.DecodeEngine(params, cfg, ecfg)
    completions = list(eng.serve(_mixed_requests(seed)))
    issues: List[Issue] = []
    if len(completions) != len(_mixed_requests(seed)):
        issues.append(Issue("traffic", where_root,
                            f"traffic trace lost completions "
                            f"({len(completions)})"))

    # Snapshot trace counts BEFORE any lowering below re-traces.
    counts = {n: e.trace_count for n, e in eng.jit_table.items()}
    report: Dict = {"backend": backend, "kv_layout": kv_layout,
                    "kv_bits": kv_bits, "spec_tokens": spec_tokens,
                    "entries": {}}
    must_trace = {"verify"} if spec_tokens else {"decode", "prefill"}
    traced = {n for n, c in counts.items() if c}
    for missing in sorted(must_trace - traced):
        issues.append(Issue(
            "recompile", f"{where_root}/{missing}",
            "step function never compiled — the traffic trace no longer "
            "exercises it, so the audits above it prove nothing"))
    for name, entry in eng.jit_table.items():
        c = counts[name]
        if c == 0:
            continue
        where = f"{where_root}/{name}"
        if c != 1:
            issues.append(Issue(
                "recompile", where,
                f"compiled {c}x across one fixed-shape traffic trace — "
                f"a shape leak retraces the serve step under real "
                f"traffic (every admission pattern would compile anew)"))
        jaxpr = jax.make_jaxpr(entry.fn)(*entry.abstract_args)
        issues.extend(check_no_callbacks(jaxpr, where))
        if name in _GEMM_ENTRIES:
            issues.extend(check_segment_gemm_dtypes(jaxpr, where))
            if not any(s for _, s in iter_eqns(jaxpr)):
                issues.append(Issue(
                    "segment_dtype", where,
                    "no eqn carries the segment-GEMM scope — the driver "
                    "tag (backend.base.SEGMENT_GEMM_SCOPE) went missing, "
                    "so the dtype audit is vacuous"))
        dreport, dissues = donation_report(entry, where)
        issues.extend(dissues)
        report["entries"][name] = {"trace_count": c, **dreport}
    return report, issues


def audit_train_step(backend: str, seed: int = 0) -> Tuple[Dict, List[Issue]]:
    """Trace one QAT train step on ``backend`` and hold its jaxpr to the
    no-callback / no-f64 contract (the packed segment scope only exists in
    serve mode; QAT forwards run fake-quant, not packed GEMMs)."""
    import dataclasses as dc

    from repro.train import state as state_lib

    where = f"{backend}/train_step"
    cfg = _tiny_arch()
    cfg = dc.replace(cfg, quant=dc.replace(cfg.quant, backend=backend))
    tcfg = state_lib.TrainConfig(num_microbatches=2, t1=2, t2=4, warmup=1)
    state = state_lib.init_state(jax.random.PRNGKey(seed), cfg, tcfg)
    batch = {"tokens": jnp.ones((4, 8), jnp.int32),
             "labels": jnp.ones((4, 8), jnp.int32)}
    rng = jax.random.PRNGKey(seed + 1)
    jaxpr = jax.make_jaxpr(
        lambda s, b, r: state_lib.train_step(s, b, cfg, tcfg, r))(
            state, batch, rng)
    issues = check_no_callbacks(jaxpr, where)
    issues.extend(check_segment_gemm_dtypes(jaxpr, where))
    return {"backend": backend, "eqns": len(jaxpr.jaxpr.eqns)}, issues


def run_audits(backends: Iterable[str], *, train: bool = True
               ) -> Tuple[Dict, List[Issue]]:
    """The CI entry point: per backend, audit the ring-fp, ring-q4 and
    paged-q4+speculative engine variants plus (optionally) the train
    step. Variants were chosen so every Backend op (packed/fused GEMMs,
    qkv ring + paged attention, the draft low-slice driver) appears in at
    least one audited jaxpr."""
    issues: List[Issue] = []
    report: Dict = {"engines": [], "train": []}
    for b in backends:
        for kwargs in ({"kv_layout": "ring"},
                       {"kv_layout": "ring", "kv_bits": 4},
                       {"kv_layout": "paged", "kv_bits": 4,
                        "spec_tokens": 2}):
            r, i = audit_decode_engine(b, **kwargs)
            report["engines"].append(r)
            issues.extend(i)
        if train:
            r, i = audit_train_step(b)
            report["train"].append(r)
            issues.extend(i)
    return report, issues
