"""Explicit-state model checker for the host ``PagePool`` state machine
(DESIGN.md §16).

The fuzz harness in tests/test_kv_pool.py samples random operation
interleavings; this module replaces "the fuzzer found nothing" with
"exhaustively impossible at this scope": a breadth-first search over
*all* interleavings of the engine-visible allocator operations on a
small pool, asserting the shared invariants (``kv_pool.
invariant_violations`` / ``kv_pool.step_ops_violations``) in every
reachable state. Because the search is breadth-first over canonicalized
states, the first violation found carries a *minimal* operation trace —
the shortest engine history that corrupts the pool.

Operation alphabet (mirrors ``DecodeEngine``'s use of the pool):

    submit(p)             note_submit + admissible reservation for
                          prompt ``p`` (one pending submission per
                          prompt keeps the state space canonical: the
                          request id IS the prompt index)
    cancel(p)             forget_submit of a pending submission
    admit(p)              admit the pending submission into a free slot
                          (shared-prefix pages map here)
    feed(slot, w)         prepare ``w`` tokens (allocation + COW), as
                          chunked prefill / decode does
    rollback(slot)        un-commit the last fed token (speculative
                          verify rejection, DESIGN.md §14)
    note_filled(slot)     register finished prompt pages in the prefix
                          map
    evict(slot)           the engine cancel path: note_filled + release
    release(slot)         drop every page reference
    release_feed(a, b, w) release slot ``a`` then feed slot ``b`` with
                          ONE shared StepOps batch — the engine's
                          evict-then-admit step shape, the only
                          sequence that can re-allocate a page freed in
                          the same batch (the poison-cancel contract)

Each operation clones the pool (``copy.deepcopy`` — mutant subclasses
used by the tests survive the clone), applies the call(s) with a fresh
``StepOps``, and checks both invariant sets immediately. States are
canonicalized into hashable keys that EXCLUDE the observability-only
counters (``lookups``/``hits``/``peak_resident``) but keep everything
behavior-relevant, including free-list and LRU *order* (both determine
future allocation/eviction choices).
"""
from __future__ import annotations

import copy
import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve import kv_pool
from repro.serve.scheduler import Request

# --------------------------------------------------------------------------
# Configuration and results
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MCConfig:
    """A deliberately tiny pool: 3 usable pages, 2 slots, 2-token pages,
    2-page tables, and two prompts that share their first page (so the
    prefix-sharing / COW arm of the state machine is exercised). Small
    enough that the BFS closes; big enough that every operation in the
    alphabet is enabled somewhere."""
    num_pages: int = 4               # page 0 is the reserved null page
    page_size: int = 2
    pages_per_seq: int = 2
    max_batch: int = 2
    poison: bool = True              # poison path is a strict superset
    prompts: Tuple[Tuple[int, ...], ...] = ((1, 2, 3), (1, 2))
    feed_widths: Tuple[int, ...] = (1, 2)

    @property
    def ring_tokens(self) -> int:
        return self.pages_per_seq * self.page_size


@dataclasses.dataclass(frozen=True)
class MCViolation:
    trace: Tuple[str, ...]           # minimal operation trace
    messages: Tuple[str, ...]        # invariant violation strings

    def format(self) -> str:
        steps = "\n".join(f"  {i + 1}. {op}"
                          for i, op in enumerate(self.trace))
        msgs = "\n".join(f"  - {m}" for m in self.messages)
        return (f"PagePool invariant violation after "
                f"{len(self.trace)} op(s):\n{steps}\nviolated:\n{msgs}")


@dataclasses.dataclass
class MCResult:
    violation: Optional[MCViolation]
    states_explored: int
    depth_reached: int
    config: MCConfig

    @property
    def ok(self) -> bool:
        return self.violation is None

    def to_json(self) -> Dict:
        return {
            "ok": self.ok,
            "states_explored": self.states_explored,
            "depth_reached": self.depth_reached,
            "trace": list(self.violation.trace) if self.violation else [],
            "messages": (list(self.violation.messages)
                         if self.violation else []),
        }


# --------------------------------------------------------------------------
# Harness state (the engine-side bookkeeping the pool does not own)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Harness:
    # slot -> [prompt index, tokens fed]
    slots: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    pending: Tuple[int, ...] = ()    # prompt indices with a live submit

    def clone(self) -> "_Harness":
        return _Harness({s: list(v) for s, v in self.slots.items()},
                        self.pending)


def _prompt(cfg: MCConfig, pi: int) -> np.ndarray:
    return np.asarray(cfg.prompts[pi], np.int32)


def _state_key(pool, h: _Harness) -> Tuple:
    """Canonical hashable key for (pool, harness). Order-sensitive where
    behavior is order-sensitive (free stack, cached LRU); the
    observability counters are excluded so states differing only in
    telemetry merge."""
    return (
        tuple(pool.free),
        tuple(pool.cached.items()),
        pool.table.tobytes(),
        tuple(int(c) for c in pool.refcount),
        tuple(sorted(pool.page_hash.items())),
        tuple(sorted(pool.prefix_map.items())),
        tuple(sorted(pool._pending.items())),
        tuple(sorted(pool._target_pages.items())),
        tuple(sorted((s, tuple(v)) for s, v in pool._slot_hashes.items())),
        tuple(sorted((s, tuple(v)) for s, v in h.slots.items())),
        tuple(sorted(h.pending)),
    )


# --------------------------------------------------------------------------
# Operation application
# --------------------------------------------------------------------------


def _apply(cfg: MCConfig, pool, h: _Harness, op: Tuple) -> List[str]:
    """Mutate (pool, h) in place per ``op``; return invariant violations
    observed immediately after (empty = sound). Engine-impossible calls
    (admission refusals, allocator exhaustion) are modeled as no-ops /
    clean failures exactly as the engine handles them."""
    kind = op[0]
    bad: List[str] = []
    if kind == "submit":
        pi = op[1]
        pool.note_submit(pi, _prompt(cfg, pi))
        req = Request(prompt=_prompt(cfg, pi), max_new_tokens=2,
                      request_id=pi)
        if not pool.admissible(req):
            pool.forget_submit(pi)
        else:
            h.pending = tuple(sorted(set(h.pending) | {pi}))
    elif kind == "cancel":
        pi = op[1]
        pool.forget_submit(pi)
        h.pending = tuple(p for p in h.pending if p != pi)
    elif kind == "admit":
        pi = op[1]
        slot = min(s for s in range(cfg.max_batch) if s not in h.slots)
        req = Request(prompt=_prompt(cfg, pi), max_new_tokens=2,
                      request_id=pi)
        shared = pool.admit(slot, req)
        h.slots[slot] = [pi, shared]
        h.pending = tuple(p for p in h.pending if p != pi)
    elif kind == "feed":
        _, slot, width = op
        ops = kv_pool.StepOps()
        try:
            pool.prepare(slot, h.slots[slot][1], width, ops)
        except RuntimeError:
            return kv_pool.invariant_violations(pool)  # clean exhaustion
        bad += kv_pool.step_ops_violations(pool, ops)
        h.slots[slot][1] += width
    elif kind == "note_filled":
        _, slot = op
        pi, fed = h.slots[slot]
        pool.note_filled(slot, _prompt(cfg, pi), fed)
    elif kind == "rollback":
        _, slot = op
        fed = h.slots[slot][1]
        ops = kv_pool.StepOps()
        pool.rollback(slot, fed - 1, fed, ops)
        bad += kv_pool.step_ops_violations(pool, ops)
        h.slots[slot][1] = fed - 1
    elif kind == "evict":
        _, slot = op
        pi, fed = h.slots[slot]
        ops = kv_pool.StepOps()
        pool.note_filled(slot, _prompt(cfg, pi), fed)
        pool.release(slot, ops)
        bad += kv_pool.step_ops_violations(pool, ops)
        del h.slots[slot]
    elif kind == "release":
        _, slot = op
        ops = kv_pool.StepOps()
        pool.release(slot, ops)
        bad += kv_pool.step_ops_violations(pool, ops)
        del h.slots[slot]
    elif kind == "release_feed":
        # The engine's evict-then-admit step: one StepOps batch spans the
        # release and the next allocation, which is the only way a page
        # freed in this batch can be re-allocated in it — the sequence
        # the poison-cancel contract exists for.
        _, rslot, fslot, width = op
        ops = kv_pool.StepOps()
        pool.release(rslot, ops)
        del h.slots[rslot]
        try:
            pool.prepare(fslot, h.slots[fslot][1], width, ops)
        except RuntimeError:
            return (kv_pool.invariant_violations(pool)
                    + kv_pool.step_ops_violations(pool, ops))
        bad += kv_pool.step_ops_violations(pool, ops)
        h.slots[fslot][1] += width
    else:                            # pragma: no cover - alphabet is closed
        raise AssertionError(f"unknown op {op!r}")
    return bad + kv_pool.invariant_violations(pool)


def _enabled(cfg: MCConfig, pool, h: _Harness) -> List[Tuple]:
    """Deterministically ordered operations enabled in this state."""
    ops: List[Tuple] = []
    in_flight = set(h.pending) | {v[0] for v in h.slots.values()}
    for pi in range(len(cfg.prompts)):
        if pi not in in_flight:
            ops.append(("submit", pi))
    have_free_slot = len(h.slots) < cfg.max_batch
    for pi in h.pending:
        ops.append(("cancel", pi))
        if have_free_slot:
            ops.append(("admit", pi))
    # One page past the ring is enough to exercise the wrap path without
    # letting `fed` grow the state space unboundedly.
    fed_cap = cfg.ring_tokens + cfg.page_size
    for slot in sorted(h.slots):
        _pi, fed = h.slots[slot]
        for w in cfg.feed_widths:
            if fed + w <= fed_cap:
                ops.append(("feed", slot, w))
        if 1 <= fed <= cfg.ring_tokens:
            ops.append(("rollback", slot))
        ops.append(("note_filled", slot))
        ops.append(("evict", slot))
        ops.append(("release", slot))
        for other in sorted(h.slots):
            if other != slot:
                ops.append(("release_feed", slot, other,
                            cfg.feed_widths[0]))
    return ops


def _fmt_op(op: Tuple) -> str:
    return f"{op[0]}({', '.join(str(a) for a in op[1:])})"


# --------------------------------------------------------------------------
# BFS driver
# --------------------------------------------------------------------------


def explore(config: Optional[MCConfig] = None,
            pool_factory: Callable = kv_pool.PagePool,
            max_depth: int = 6,
            max_states: int = 250_000) -> MCResult:
    """BFS all operation interleavings to ``max_depth``. Returns the first
    (hence minimal-trace) invariant violation, or a clean :class:`MCResult`.

    ``pool_factory`` lets the tests run the same exploration against
    seeded-bug ``PagePool`` subclasses (the mutants of DESIGN.md §16);
    deep-copy cloning preserves the subclass. ``max_states`` is a safety
    valve: exceeding it raises, because a truncated search would report
    "exhaustively impossible" over a space it did not finish."""
    cfg = config or MCConfig()
    pool = pool_factory(cfg.num_pages, cfg.page_size, cfg.pages_per_seq,
                        cfg.max_batch, poison=cfg.poison)
    h = _Harness()
    root_bad = kv_pool.invariant_violations(pool)
    if root_bad:
        return MCResult(MCViolation((), tuple(root_bad)), 1, 0, cfg)
    frontier = deque([(pool, h, ())])
    seen = {_state_key(pool, h)}
    explored = 1
    depth_reached = 0
    while frontier:
        pool, h, trace = frontier.popleft()
        if len(trace) >= max_depth:
            continue
        for op in _enabled(cfg, pool, h):
            p2 = copy.deepcopy(pool)
            h2 = h.clone()
            bad = _apply(cfg, p2, h2, op)
            new_trace = trace + (_fmt_op(op),)
            if bad:
                return MCResult(MCViolation(new_trace, tuple(bad)),
                                explored, len(new_trace), cfg)
            key = _state_key(p2, h2)
            if key in seen:
                continue
            seen.add(key)
            explored += 1
            depth_reached = max(depth_reached, len(new_trace))
            if explored > max_states:
                raise RuntimeError(
                    f"model checker exceeded max_states={max_states} "
                    f"before closing depth {max_depth} — shrink the "
                    f"MCConfig or raise the valve explicitly")
            frontier.append((p2, h2, new_trace))
    return MCResult(None, explored, depth_reached, cfg)
