"""Logical-axis sharding annotations.

Model code tags tensors with *logical* axis names; the launcher installs a
rules table mapping logical names to mesh axes. Outside a mesh context (CPU
smoke tests) the annotations are no-ops, so the same model code runs
everywhere.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisName = Union[None, str, Tuple[str, ...]]

_RULES: contextvars.ContextVar[Optional[Dict[str, AxisName]]] = \
    contextvars.ContextVar("soniq_shard_rules", default=None)

# Default production rules (see DESIGN.md §4). "fsdp" axes shard parameters;
# "batch" shards data; "model" is the tensor-parallel axis.
DEFAULT_RULES: Dict[str, AxisName] = {
    "batch": ("pod", "data"),
    "seq": None,                 # activations: seq replicated by default
    "seq_shard": "model",        # decode KV-cache seq (flash-decoding split)
    "embed": None,               # activation d_model
    "heads": "model",
    "kv_heads": "model",
    "qkv": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "tokens": ("pod", "data"),   # flattened [B*S] token dim in MoE dispatch
    "expert_cap": None,          # capacity dim; dp-sharded when EP is off
    "fsdp": ("pod", "data"),     # parameter sharding (ZeRO-3)
    "ssm_heads": "model",
    "state": None,
    "conv": None,
}


@contextlib.contextmanager
def sharding_rules(rules: Optional[Dict[str, AxisName]]):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def rules_active() -> bool:
    return _RULES.get() is not None


def spec(*names: str) -> P:
    rules = _RULES.get() or {}
    return P(*[rules.get(n) if n is not None else None for n in names])


def shard(x, *names: str):
    """Annotate activation/parameter x with logical axes (no-op without
    rules)."""
    if _RULES.get() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*names))
