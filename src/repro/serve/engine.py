"""Batched decode engine over packed SONIQ weights.

The engine consumes the output of ``soniq.to_serve`` (or converts a trained
QAT tree itself via ``repro.api.transforms.convert_tree``): per-layer
precisions re-budgeted to the static segment mix (scan groups must share
packed shapes — groups that trained 4-bit keep their 4 bits while the
budget allows, ranked by trained precision then weight magnitude), channels
reordered (paper Obs. 4), codes bit-packed. It then runs greedy/temperature
decoding with the ring KV cache; weights move as 1/2/4-bit carriers — the
paper's deployment path.

``rebudget_pbits`` / ``serve_convert`` are deprecation shims kept for
external callers; the implementations moved to ``repro.api.transforms``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import transforms as lifecycle
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig
from repro.models import lm


def rebudget_pbits(pbits: np.ndarray, w: np.ndarray,
                   qcfg: QuantConfig) -> np.ndarray:
    """DEPRECATED — moved to ``repro.api.transforms.rebudget_pbits``."""
    warnings.warn(
        "engine.rebudget_pbits is deprecated; use "
        "repro.api.transforms.rebudget_pbits (soniq.rebudget_pbits)",
        DeprecationWarning, stacklevel=2)
    return lifecycle.rebudget_pbits(pbits, w, qcfg)


def serve_convert(params, qcfg: QuantConfig):
    """DEPRECATED — use ``soniq.to_serve`` (or the pytree-level
    ``repro.api.transforms.convert_tree``)."""
    warnings.warn(
        "engine.serve_convert is deprecated; use soniq.to_serve / "
        "repro.api.transforms.convert_tree",
        DeprecationWarning, stacklevel=2)
    return lifecycle.convert_tree(params, qcfg, rebudget=True)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 256
    temperature: float = 0.0        # 0 = greedy
    cache_dtype: str = "float32"


class DecodeEngine:
    """Minimal batched generation loop (greedy / temperature sampling)."""

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        self.cfg = arch_cfg.with_quant_mode(Phase.SERVE)
        self.ecfg = ecfg
        self.params = params if already_serve else lifecycle.convert_tree(
            params, self.cfg.quant, rebudget=True)
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, self.cfg, c, t, pos))

    def init_cache(self, batch: int):
        return lm.init_cache(self.cfg, batch, self.ecfg.cache_len,
                             jnp.dtype(self.ecfg.cache_dtype))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """prompts [B, S0] int32 -> [B, S0 + max_new] (greedy unless
        temperature > 0)."""
        b, s0 = prompts.shape
        cache = self.init_cache(b)
        toks = jnp.asarray(prompts, jnp.int32)
        out = [toks]
        logits = None
        for t in range(s0):
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = self._step(self.params, cache, toks[:, t], pos)
        cur = self._sample(logits, rng, 0)
        for t in range(max_new_tokens):
            out.append(cur[:, None])
            if t == max_new_tokens - 1:
                break
            pos = jnp.full((b,), s0 + t, jnp.int32)
            logits, cache = self._step(self.params, cache, cur, pos)
            cur = self._sample(logits, rng, t + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _sample(self, logits, rng, t):
        if self.ecfg.temperature <= 0 or rng is None:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        k = jax.random.fold_in(rng, t)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature).astype(jnp.int32)


def packed_model_bytes(serve_params) -> int:
    """Total packed weight bytes (the paper's network-size metric)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(serve_params)[0]:
        if leaf is None:
            continue
        name = str(getattr(path[-1], "key", ""))
        if name in ("w4", "w2", "w1"):
            total += leaf.size
        elif name in ("w", "table", "wscale", "b"):
            total += leaf.size * np.dtype(leaf.dtype).itemsize
    return int(total)
