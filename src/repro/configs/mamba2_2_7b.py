"""Mamba2-2.7B [arXiv:2405.21060; unverified] — attention-free SSD: 64L
d_model=2560, ssm_state=128, vocab=50280."""
from .base import ArchConfig
from .registry import register


@register("mamba2-2.7b")
def mamba2() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2,
        tie_embeddings=True,
        source="arXiv:2405.21060; hf:state-spaces/mamba2-2.7b (unverified)",
    )
