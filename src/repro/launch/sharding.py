"""Partition rules: logical activation axes + per-parameter PartitionSpecs.

Strategy (DESIGN.md §4):
  * DP over ("pod","data") for the batch; FSDP/ZeRO-3 parameter sharding
    over the same axes on a non-contracting weight dim.
  * TP over "model": column-parallel (wq/wk/wv/up/gate/in_proj) shard N;
    row-parallel (wo/down/out_proj) shard K. Quantization metadata
    (s/pbits/scales) stays replicated — it is K/16-sized.
  * EP over "model" when num_experts divides the model axis; otherwise
    experts replicate and the expert-internal FFN dim takes "model".
  * Serve mode: packed uint8 weights shard N over "model" only (decode is
    KV/weight-bytes bound; K-sharding packed carriers hits 8/p-divisibility
    walls for no memory win).
Every rule degrades to None when the dim is not divisible by the axis size
(e.g. starcoder2's 36 heads on a 16-way model axis) — recorded by
`fallbacks()` so EXPERIMENTS.md can report them.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

COL_PARENTS = {"wq", "wk", "wv", "up", "gate", "in_proj"}
ROW_PARENTS = {"wo", "down", "out_proj"}
REPL_PARENTS = {"router", "frontend"}


def _div(n: int, d: int) -> bool:
    return d > 0 and n % d == 0


def activation_rules(cfg, mesh, *, batch: int) -> Dict[str, object]:
    """Logical-axis table for models.shard under this (arch, mesh, batch)."""
    ax = dict(mesh.shape)
    model = ax.get("model", 1)
    dp = tuple(a for a in ("pod", "data") if a in ax)
    dp_size = int(np.prod([ax[a] for a in dp])) if dp else 1
    ep = _div(cfg.num_experts, model)
    rules = {
        "batch": dp if _div(batch, dp_size) and dp_size > 1 else None,
        "seq": None,
        "seq_shard": "model",
        "embed": None,
        "heads": "model" if _div(cfg.num_heads, model) else None,
        "kv_heads": "model" if _div(cfg.num_kv_heads, model) else None,
        "vocab": "model" if _div(cfg.vocab_size, model) else None,
        "ff": "model" if _div(cfg.d_ff, model) else None,
        "experts": "model" if ep else None,
        "expert_ff": None if ep else
                     ("model" if _div(cfg.d_ff, model) else None),
        "fsdp": dp if dp_size > 1 else None,
        "ssm_heads": "model" if _div(cfg.d_inner // 64, model) else None,
        # MoE dispatch intermediates: keep token-indexed tensors DP-sharded;
        # shard the capacity dim over DP only when EP is off. Measured both
        # ways (§Perf A/B): without EP, an unsharded [E, C, D] buffer
        # replicates (all-gather pathology — mixtral 548 s collective term);
        # under EP, dp-sharding the capacity dim makes GSPMD reshard the
        # token->slot scatter across both axes and regresses 4-5x.
        "tokens": dp if dp_size > 1 else None,
        "expert_cap": None if ep else (dp if dp_size > 1 else None),
    }
    return rules


def fallbacks(cfg, mesh, *, batch: int) -> List[str]:
    """Human-readable list of rules that degraded to replication."""
    r = activation_rules(cfg, mesh, batch=batch)
    out = []
    model = mesh.shape.get("model", 1)
    if r["heads"] is None and cfg.num_heads:
        out.append(f"heads {cfg.num_heads} !% model {model} -> replicated "
                   "attention heads (batch-sharded attention)")
    if r["kv_heads"] is None and cfg.num_kv_heads:
        out.append(f"kv_heads {cfg.num_kv_heads} !% model {model} -> "
                   "replicated KV heads")
    if r["vocab"] is None:
        out.append(f"vocab {cfg.vocab_size} !% model {model} -> replicated "
                   "embedding")
    if cfg.num_experts and r["experts"] is None:
        out.append(f"experts {cfg.num_experts} !% model {model} -> "
                   "expert-internal TP instead of EP")
    if r["batch"] is None:
        out.append(f"batch {batch} too small for DP -> replicated batch")
    return out


# ------------------------------------------------------------ params ----
def _pad_lead(spec_dims: Tuple, extra: int) -> P:
    return P(*([None] * extra + list(spec_dims)))


def param_pspec(path_keys: List[str], shape: Tuple[int, ...], cfg, mesh,
                *, serve: bool, rules: Dict) -> P:
    """PartitionSpec for one parameter leaf, identified by its path."""
    name = path_keys[-1]
    parent = path_keys[-2] if len(path_keys) >= 2 else ""
    in_moe = "moe" in path_keys and parent not in REPL_PARENTS \
        and "shared" not in path_keys
    ax = dict(mesh.shape)
    model = ax.get("model", 1)
    fsdp = rules.get("fsdp")
    ep_axis = rules.get("experts")

    def fits(dim_size, axis) -> Optional[object]:
        if axis is None:
            return None
        size = int(np.prod([ax[a] for a in axis])) \
            if isinstance(axis, tuple) else ax[axis]
        return axis if _div(dim_size, size) else None

    if name == "table":                      # embedding [V, D]
        return P(fits(shape[0], rules.get("vocab")), None)

    if name in ("w4", "w2", "w1"):           # packed [*, Kp, N]
        extra = len(shape) - 2
        if in_moe:
            e_ax = fits(shape[extra - 1], ep_axis)
            # EP owns the model axis -> per-expert packed weights replicate
            # within the expert shard; otherwise shard N over model.
            n_ax = None if e_ax is not None else fits(shape[-1], "model")
            return _pad_lead((e_ax, None, n_ax), extra - 1)
        return _pad_lead((None, fits(shape[-1], "model")), extra)

    if name == "w":
        if parent in REPL_PARENTS or parent == "lm_head":
            if parent == "lm_head":          # [D, V]
                return P(fits(shape[-2], fsdp),
                         fits(shape[-1], rules.get("vocab")))
            return P(None, None)
        col = parent in COL_PARENTS
        k_ax = fits(shape[-2], fsdp if col else "model")
        n_ax = fits(shape[-1], "model" if col else fsdp)
        if serve:
            k_ax, n_ax = None, fits(shape[-1], "model")
        extra = len(shape) - 2
        if in_moe and extra >= 1:            # [L, E, K, N] or [E, K, N]
            e_ax = fits(shape[extra - 1], ep_axis)
            if e_ax is not None:             # EP: model is taken by experts
                k_ax = fits(shape[-2], fsdp)
                n_ax = None
            return _pad_lead((e_ax, k_ax, n_ax), extra - 1)
        return _pad_lead((k_ax, n_ax), extra)

    if name == "b":
        col = parent in COL_PARENTS or parent in ("attn",)
        n_ax = fits(shape[-1], "model" if (col or serve) else fsdp)
        if parent in REPL_PARENTS or parent == "lm_head":
            n_ax = None
        extra = len(shape) - 1
        if in_moe and extra >= 1:
            e_ax = fits(shape[extra - 1], ep_axis)
            return _pad_lead((e_ax, n_ax), extra - 1)
        return _pad_lead((n_ax,), extra)

    if name in ("conv_w", "conv_b"):         # [.., K, C] / [.., C]
        c_ax = fits(shape[-1], "model")
        return _pad_lead((c_ax,), len(shape) - 1) if name == "conv_b" \
            else _pad_lead((None, c_ax), len(shape) - 2)

    # s, pbits, pbits_sorted, wscale, perm, norms, A_log, D, dt_bias, ...
    return P(*([None] * len(shape)))


def tree_pspecs(tree, cfg, mesh, *, serve: bool, rules: Dict):
    """Map a pytree of arrays/ShapeDtypeStructs to PartitionSpecs."""
    def one(path, leaf):
        if leaf is None:
            return None
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return param_pspec(keys, tuple(leaf.shape), cfg, mesh, serve=serve,
                           rules=rules)
    return jax.tree_util.tree_map_with_path(one, tree,
                                            is_leaf=lambda x: x is None)


def tree_shardings(tree, cfg, mesh, *, serve: bool, rules: Dict):
    specs = tree_pspecs(tree, cfg, mesh, serve=serve, rules=rules)
    return jax.tree.map(lambda s: None if s is None
                        else NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: x is None or isinstance(x, P))


def validate_pspecs(tree, specs, mesh) -> List[str]:
    """Check every sharded dim divides evenly; returns violations."""
    ax = dict(mesh.shape)
    bad = []

    def one(path, leaf, spec):
        if leaf is None or spec is None:
            return
        for d, s in enumerate(spec):
            if s is None:
                continue
            size = int(np.prod([ax[a] for a in s])) \
                if isinstance(s, tuple) else ax[s]
            if leaf.shape[d] % size:
                bad.append(f"{jax.tree_util.keystr(path)} dim{d} "
                           f"{leaf.shape[d]} !% {size}")

    jax.tree_util.tree_map_with_path(one, tree, specs,
                                     is_leaf=lambda x: x is None)
    return bad
