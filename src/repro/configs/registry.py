"""Registry mapping --arch ids to config constructors."""
from __future__ import annotations

from typing import Callable, Dict

from .base import ArchConfig

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    return sorted(_REGISTRY)
