"""AdamW (in-repo; no optax dependency) with:

  * integer-leaf awareness (pbits / perm buffers get no state, no update),
  * decoupled weight decay with masking (no decay on norms/bias/s),
  * a separate learning-rate group for the Phase-I ``s`` noise logits,
  * global-norm gradient clipping,
  * moments stored fp32 regardless of param dtype.

State is a pytree aligned with params, so it shards identically (ZeRO-3 via
the same FSDP partition specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    s_lr_mult: float = 10.0         # Phase-I s logits move faster (SMOL)
    clip_norm: float = 1.0
    # "float32" default; "bfloat16" halves optimizer-state HBM for the
    # 100B+ configs (math still fp32 after upcast; production would use
    # blockwise-int8 moments — bitsandbytes-style — same sharding).
    moment_dtype: str = "float32"


def _is_float(x) -> bool:
    dt = getattr(x, "dtype", None)
    if dt is None or dt == jax.dtypes.float0:
        return False
    return jnp.issubdtype(dt, jnp.floating)


def _leaf_name(path) -> str:
    return str(path[-1].key) if path and hasattr(path[-1], "key") else ""


def init_state(params, moment_dtype="float32") -> Dict[str, Any]:
    mdt = jnp.dtype(moment_dtype)

    def zero(x):
        return jnp.zeros(jnp.shape(x), mdt) if _is_float(x) else None
    return {
        "mu": jax.tree.map(zero, params),
        "nu": jax.tree.map(zero, params),
        "count": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs, moment_dtype="float32"):
    """Optimizer-state ShapeDtypeStructs/shardings mirroring the params."""
    mdt = jnp.dtype(moment_dtype)

    def like(x):
        if x is None:
            return None
        return jax.ShapeDtypeStruct(x.shape, mdt, sharding=getattr(
            x, "sharding", None)) if jnp.issubdtype(x.dtype, jnp.floating) \
            else None
    return {
        "mu": jax.tree.map(like, param_specs),
        "nu": jax.tree.map(like, param_specs),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(grads) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads) if g is not None and _is_float(g)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale=1.0):
    """One AdamW step. Integer leaves (pbits, perms) pass through; ``s``
    leaves use lr * s_lr_mult and no weight decay."""
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))

    flat_p = jax.tree_util.tree_flatten_with_path(params)
    paths = [p for p, _ in flat_p[0]]
    treedef = flat_p[1]
    p_leaves = [v for _, v in flat_p[0]]
    none_aware = lambda x: x is None  # noqa: E731
    g_leaves = jax.tree.leaves(grads, is_leaf=none_aware)
    mu_leaves = jax.tree.leaves(state["mu"], is_leaf=none_aware)
    nu_leaves = jax.tree.leaves(state["nu"], is_leaf=none_aware)
    assert len(p_leaves) == len(g_leaves) == len(mu_leaves) == len(nu_leaves), \
        (len(p_leaves), len(g_leaves), len(mu_leaves), len(nu_leaves))

    new_p, new_mu, new_nu = [], [], []
    for path, p, g, mu, nu in zip(paths, p_leaves, g_leaves, mu_leaves,
                                  nu_leaves):
        if mu is None or g is None or not _is_float(g):
            new_p.append(p)
            new_mu.append(mu)
            new_nu.append(nu)
            continue
        name = _leaf_name(path)
        gf = g.astype(jnp.float32) * scale
        mdt = mu.dtype
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * gf
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(gf)
        update = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        mu, nu = mu.astype(mdt), nu.astype(mdt)
        lr = cfg.lr * lr_scale
        if name == "s":
            lr = lr * cfg.s_lr_mult
        elif cfg.weight_decay and name in ("w", "table"):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * update).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    unflatten = jax.tree_util.tree_unflatten
    return (unflatten(treedef, new_p),
            {"mu": unflatten(treedef, new_mu),
             "nu": unflatten(treedef, new_nu),
             "count": count},
            {"grad_norm": gn})
