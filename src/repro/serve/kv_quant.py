"""Quantized KV cache (beyond-paper extension, DESIGN.md §8).

K/V live as SMOL 4-bit codes packed 2-per-byte with one fp16-scale per
(batch, slot, kv-head): cache bytes drop 4x vs bf16 (the decode_32k cells
are KV-read-bound at large batch). Quantization error matches the W4 grid:
round-trip RMS error <= 3% of each head's dynamic range (worst-case
element 3.5% — the half-step bound); on gaussian K/V that is ~10%
norm-relative, which attention outputs inherit. Tests pin these bounds
(`tests/test_kv_quant_cluster.py`).

The packed layout matches kernels/packed_matmul's carrier convention, so a
fused quantized-KV flash-decode Pallas kernel can consume it directly; the
jnp path here is the oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant

P_BITS = 4
GRID_MAX = 2.0 - 2.0 ** (1 - P_BITS)


def quantize_kv(x) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, H, D] -> (codes uint8 [B, S, H, D//2], scale f16 [B,S,H,1])."""
    xf = jnp.asarray(x, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), 1e-6) \
        / GRID_MAX
    u = quant.quantize_to_int(xf / scale, P_BITS).astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)), scale.astype(jnp.float16)


def dequantize_kv(codes, scale, dtype=jnp.bfloat16):
    """(codes, scale) -> [B, S, H, D]."""
    lo = (codes & 0xF).astype(dtype)
    hi = ((codes >> 4) & 0xF).astype(dtype)
    u = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[:-1]
                                             + (codes.shape[-1] * 2,))
    v = (2.0 * u - (2 ** P_BITS - 1)) * (2.0 ** (1 - P_BITS))
    return v * scale.astype(dtype)


def init_qkv_cache(batch: int, cache_len: int, num_kv_heads: int,
                   head_dim: int) -> Dict:
    assert head_dim % 2 == 0
    return {
        "k_codes": jnp.zeros((batch, cache_len, num_kv_heads, head_dim // 2),
                             jnp.uint8),
        "v_codes": jnp.zeros((batch, cache_len, num_kv_heads, head_dim // 2),
                             jnp.uint8),
        "k_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                             jnp.float16),
        "v_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                             jnp.float16),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def update_qkv_cache(cache: Dict, k_new, v_new, pos) -> Dict:
    """Write one token (k_new/v_new [B, 1, H, D]) at pos % cache_len."""
    b = k_new.shape[0]
    cache_len = cache["k_codes"].shape[1]
    posb = pos[:, None] if pos.ndim == 1 else pos
    slot = (posb % cache_len).astype(jnp.int32)
    bidx = jnp.arange(b)[:, None]
    kc, ks = quantize_kv(k_new)
    vc, vs = quantize_kv(v_new)
    return {
        "k_codes": cache["k_codes"].at[bidx, slot].set(kc),
        "v_codes": cache["v_codes"].at[bidx, slot].set(vc),
        "k_scale": cache["k_scale"].at[bidx, slot].set(ks),
        "v_scale": cache["v_scale"].at[bidx, slot].set(vs),
        "pos": cache["pos"].at[bidx, slot].set(posb),
    }


def read_qkv_cache(cache: Dict, dtype=jnp.bfloat16):
    """-> (k [B,S,H,D], v [B,S,H,D], pos [B,S])."""
    k = dequantize_kv(cache["k_codes"], cache["k_scale"], dtype)
    v = dequantize_kv(cache["v_codes"], cache["v_scale"], dtype)
    return k, v, cache["pos"]


def cache_bytes(cache: Dict) -> int:
    return sum(v.size * v.dtype.itemsize for v in cache.values())


# ------------------------------------------------- slot management ----
def reset_slots(cache: Dict, slots) -> Dict:
    """Wipe the cache rows of the given batch slots (continuous-batching
    admission/eviction, DESIGN.md §10): codes/scales zero, ``pos`` -1 so
    every ring entry of the row reads as empty. Rows not listed are
    untouched, and the packed carrier layout is preserved — the fused
    flash-decode kernel never sees a half-valid row."""
    idx = jnp.asarray(slots, jnp.int32)
    out = {k: v.at[idx].set(jnp.zeros((), v.dtype))
           for k, v in cache.items() if k != "pos"}
    out["pos"] = cache["pos"].at[idx].set(-1)
    return out


def evict_slot(cache: Dict, slot: int) -> Dict:
    """Free one slot's row (request completion/cancellation)."""
    return reset_slots(cache, [slot])


def slot_lengths(cache: Dict) -> jax.Array:
    """Number of valid (written, non-evicted) ring entries per slot [B]."""
    return jnp.sum(cache["pos"] >= 0, axis=1).astype(jnp.int32)
