"""The paper's own model family: small CNNs (MobileNet/ShuffleNet-style
blocks) with SONIQ quantization on every conv — used by the Table I /
Fig. 7-9 reproduction benchmarks on synthetic CIFAR-like data.

Conv weights [kh, kw, Cin, Cout] are quantized along Cin — the paper's
input-channel granularity (all weights and the activations they multiply
sharing an input-channel index share one precision, Obs. 3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import noise as noise_lib
from repro.core import pack as pack_lib
from repro.core import quant, smol
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    num_classes: int = 10
    in_channels: int = 3
    channels: Tuple[int, ...] = (16, 32)
    blocks_per_stage: int = 1
    quant: QuantConfig = dataclasses.field(
        default_factory=lambda: QuantConfig(mode="qat"))


def conv_init(key, kh, kw, cin, cout, qcfg: QuantConfig, *,
              quantized=True) -> Dict:
    """Serve-phase conv params come from ``soniq.to_serve`` on a trained
    QAT tree (``repro.api.transforms.pack_conv``), not from init."""
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) \
        * (1.0 / np.sqrt(kh * kw * cin))
    p = {"w": w}
    phase = qcfg.phase
    if quantized and phase is Phase.NOISE:
        p["s"] = noise_lib.init_s(qcfg.num_groups(cin), qcfg.p_init)
    elif quantized and phase is Phase.QAT:
        p["pbits"] = jnp.asarray(qcfg.group_pbits(cin))
    return p


def _quant_w_conv(w, pbits, qcfg, g):
    """fake-quant along Cin of [kh,kw,Cin,Cout]."""
    wt = jnp.moveaxis(w, 2, -1)                       # [kh,kw,Cout,Cin]
    if qcfg.scale_mode == "none":
        sw = 1.0
    else:
        cin = w.shape[2]
        m = jnp.max(jnp.abs(wt.reshape(-1, cin)
                            .reshape(-1, cin // g, g)), axis=(0, 2))
        sw = jax.lax.stop_gradient(
            jnp.maximum(m, 1e-6) / quant._static_grid_max(4))
    wq = smol._backend(qcfg).fake_quant(wt, pbits, sw, g)
    return jnp.moveaxis(wq, -1, 2)


def _serve_conv_weight(params: Dict, qcfg: QuantConfig, cdt):
    """Packed conv buffers ([rows, kh, kw, Cout], see api.transforms
    pack_conv) -> dequantized HWIO kernel in the compute dtype."""
    trailing = params["w4"].shape[1:]           # (kh, kw, Cout)
    cin = (params["w4"].shape[0] * 2 + params["w2"].shape[0] * 4
           + params["w1"].shape[0] * 8)
    wd = pack_lib.dequant_packed_carriers(
        {n: params[n].reshape(params[n].shape[0],      # explicit trailing
             int(np.prod(params[n].shape[1:])))        # size: rows may be 0
         for n in ("w4", "w2", "w1")}, cdt,
        wscale=params.get("wscale"),
        group_size=qcfg.eff_group_size(cin))    # [Cin, kh*kw*Cout]
    return jnp.moveaxis(wd.reshape((cin,) + trailing), 0, 2)


def conv_apply(params: Dict, x, qcfg: QuantConfig, rng=None, *,
               stride=1, groups=1):
    """x [B,H,W,Cin] -> [B,H',W',Cout]; SONIQ along Cin."""
    phase = qcfg.phase
    if "w4" in params:                          # packed deployment leaf
        assert groups == 1, "packed convs are pointwise/full only"
        cdt = x.dtype
        w = _serve_conv_weight(params, qcfg, cdt)
        cin = w.shape[2]
        x = jnp.take(x, params["perm"], axis=-1)   # channel reordering
        if qcfg.quantize_activations:
            sx = quant.abs_max_scale(x) if qcfg.act_scale_mode != "none" \
                else 1.0
            x = quant.fake_quant(x, params["pbits_sorted"].astype(
                jnp.float32), sx, qcfg.eff_group_size(cin))
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=1,
            preferred_element_type=jnp.float32)

    if Phase.FP.owns_leaf(params):
        phase = Phase.FP                        # unquantized / skip conv
    elif phase is Phase.SERVE:
        raise ValueError(
            "serve-phase conv got an unconverted leaf (keys "
            f"{sorted(params)}); run soniq.to_serve / convert_tree first")
    w = params["w"]
    cin = w.shape[2] * groups
    g = qcfg.eff_group_size(w.shape[2])

    if phase is Phase.NOISE:
        k1, k2 = jax.random.split(rng)
        wf = jnp.moveaxis(w, 2, 0).reshape(w.shape[2], -1)
        # abs-max -> 1.0 normalization: keeps the +-(2 - sigma) clip from
        # biting during the search (see smol.linear_apply noise branch).
        swn = jax.lax.stop_gradient(jnp.maximum(jnp.max(jnp.abs(
            wf.reshape(wf.shape[0] // g, g, -1)), axis=(1, 2)), 1e-6))
        sfull = jnp.repeat(swn, g, total_repeat_length=wf.shape[0])[:, None]
        # Same backend-dispatched perturbation as the linear noise rule
        # (counter-hash eps, shared custom VJP) — conv and linear Phase I
        # draw from one generator on every backend.
        seed = jax.random.bits(k1, (), jnp.uint32)
        wn = smol._backend(qcfg).noise_inject(wf / sfull, params["s"],
                                              seed, group_size=g)
        wn = wn * sfull
        w = jnp.moveaxis(wn.reshape(w.shape[2], w.shape[0], w.shape[1],
                                    w.shape[3]), 0, 2)
        if qcfg.quantize_activations and groups == 1:
            sx = quant.abs_max_scale(x) if qcfg.act_scale_mode != "none" \
                else 1.0
            x = noise_lib.inject_act_noise(x, params["s"], k2, sx, g)
    elif phase is Phase.QAT:
        pbits = params["pbits"].astype(jnp.float32)
        w = _quant_w_conv(w, pbits, qcfg, g)
        if qcfg.quantize_activations and groups == 1:
            sx = quant.abs_max_scale(x) if qcfg.act_scale_mode != "none" \
                else 1.0
            x = smol._backend(qcfg).fake_quant(x, pbits, sx, g)

    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32)


def cnn_init(key, cfg: CNNConfig) -> Dict:
    qcfg = cfg.quant
    ks = iter(jax.random.split(key, 64))
    p: Dict = {"stem": conv_init(next(ks), 3, 3, cfg.in_channels,
                                 cfg.channels[0], qcfg, quantized=False)}
    stages = []
    cin = cfg.channels[0]
    for cout in cfg.channels:
        blocks = []
        for _ in range(cfg.blocks_per_stage):
            blocks.append({
                # depthwise 3x3 (paper §III-C territory) + pointwise 1x1
                "dw": conv_init(next(ks), 3, 3, 1, cin, qcfg,
                                quantized=False),
                "pw": conv_init(next(ks), 1, 1, cin, cout, qcfg),
                "bn_g": jnp.ones((cout,)), "bn_b": jnp.zeros((cout,)),
            })
            cin = cout
        stages.append(blocks)
    p["stages"] = stages
    p["head"] = smol.linear_init(next(ks), cin, cfg.num_classes, qcfg,
                                 quantized=False)
    return p


def cnn_apply(params: Dict, x, cfg: CNNConfig, rng=None):
    qcfg = cfg.quant
    r = iter(jax.random.split(rng, 64)) if rng is not None else None

    def nr():
        return next(r) if r is not None else None

    h = jax.nn.relu(conv_apply(params["stem"], x, qcfg, nr()))
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if bi == 0 and si > 0 else 1
            g = h.shape[-1]
            h2 = conv_apply(blk["dw"], h, qcfg, nr(), stride=stride,
                            groups=g)
            h2 = conv_apply(blk["pw"], h2, qcfg, nr())
            mu = jnp.mean(h2, axis=(0, 1, 2))
            var = jnp.var(h2, axis=(0, 1, 2))
            h2 = (h2 - mu) * jax.lax.rsqrt(var + 1e-5) * blk["bn_g"] \
                + blk["bn_b"]
            h = jax.nn.relu(h2)
    pooled = jnp.mean(h, axis=(1, 2))
    return smol.linear_apply(params["head"], pooled, qcfg, nr())


def xent_loss(params, batch, cfg: CNNConfig, rng=None):
    logits = cnn_apply(params, batch["x"], cfg, rng)
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - ll)
    if cfg.quant.phase is Phase.NOISE:
        loss = loss + cfg.quant.lam * smol.bit_penalty_of_params(params)
    return loss, logits


def accuracy(params, x, y, cfg: CNNConfig) -> float:
    logits = cnn_apply(params, x, cfg, None)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))


def bits_per_param(params, qcfg: QuantConfig) -> float:
    """Average bpp over quantized conv/linear weights (paper's Bpp)."""
    tot_bits = tot = 0

    def walk(node):
        nonlocal tot_bits, tot
        if isinstance(node, dict):
            if "w" in node and ("pbits" in node or "s" in node):
                w = node["w"]
                cin = w.shape[-2] if w.ndim == 2 else w.shape[2]
                per = (w.size // cin)
                if "pbits" in node:
                    pb = np.asarray(node["pbits"], np.float64)
                else:
                    from repro.core import patterns
                    s = np.asarray(node["s"])
                    raw = 1 + np.log2(1 + np.exp(-s))
                    pb = np.clip(np.round(raw), 1, 8)
                g = cin // pb.shape[-1]
                tot_bits += float(pb.sum()) * g * per
                tot += w.size
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    return tot_bits / max(tot, 1)
