"""`repro.analysis` — the SONIQ-specific static analyzer (DESIGN.md §15).

SONIQ's parity claim rests on the deployed path executing *exactly* the
discrete arithmetic trained against: one silent fp promotion inside a
packed segment GEMM, one unmasked ring scatter, or one kernel call that
bypasses the ``Backend`` registry breaks that contract without failing any
unit test — until it corrupts tokens under traffic. PRs 2–7 each
hand-fixed another instance of the same few hazard classes; this package
makes those classes *unwritable*:

* :mod:`repro.analysis.lint` — a stdlib-``ast`` linter whose rules
  (SQ001–SQ006) codify the bug classes from CHANGES.md, with inline
  ``# soniq-lint: disable=SQxxx(reason)`` suppressions and a committed
  baseline file for grandfathered violations.
* :mod:`repro.analysis.jaxpr_checks` — trace-time audits: lower the
  jitted ``DecodeEngine`` step family per registered backend and walk the
  ClosedJaxpr (no narrowing/f64 dtype converts inside quantized
  segment-GEMM subtrees, no host callbacks in serve steps), report
  buffer-donation coverage, and assert each engine step function compiles
  exactly once across a mixed-length traffic trace.
* ``python -m repro.analysis`` — the CLI (human + JSON output) that CI's
  static-analysis leg runs with ``--check``.
"""
from __future__ import annotations

from .lint import (  # noqa: F401
    LintResult, Rule, Suppression, Violation, all_rules, lint_file,
    lint_paths, lint_source, load_baseline, match_baseline, rule,
)

__all__ = [
    "LintResult", "Rule", "Suppression", "Violation", "all_rules",
    "lint_file", "lint_paths", "lint_source", "load_baseline",
    "match_baseline", "rule",
]
