"""Phase I of SONIQ: noise-injected precision search (paper Alg. 1/2).

Per (layer, input-channel-group) a trainable logit ``s`` parameterizes the
perturbation scale sigma(s) = 1/(1+e^{-s}).  sigma(s) equals the worst-case
round-off 2^(1-p) of a p-bit SMOL number, so
    bits(s) = 1 + log2(1 + e^{-s})
is a differentiable bit count and the paper's regularizer
    lambda * || log2(1 + e^{-s}) ||_1  ==  lambda * sum(bits(s) - 1).

System-aware variant (Alg. 2): one s per *input-channel group* shared by the
weights and the activations computed against them (Obs. 3), precisions
snapped to {1,2,4} (Obs. 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .qtypes import GROUP_SIZE


def s_init(p_init: int) -> float:
    """s_init = -ln(2^(p-1) - 1); for p=1 the exact value is +inf — we use a
    large finite logit (sigma within 1e-3 of 1)."""
    if p_init <= 1:
        return 8.0
    return float(-np.log(2.0 ** (p_init - 1) - 1.0))


def init_s(num_groups: int, p_init: int = 4) -> jnp.ndarray:
    return jnp.full((num_groups,), s_init(p_init), jnp.float32)


def sigma(s):
    return jax.nn.sigmoid(jnp.asarray(s, jnp.float32))


def bits_soft(s):
    """Differentiable bit count 1 + log2(1 + e^{-s}) = 1 - log2(sigma(s))."""
    s = jnp.asarray(s, jnp.float32)
    # log(1 + e^{-s}) = softplus(-s), numerically stable.
    return 1.0 + jax.nn.softplus(-s) / jnp.log(2.0)


def bit_penalty(s):
    """The paper's L1 regularizer  || log2(1+e^{-s}) ||_1  (per-array sum)."""
    return jnp.sum(bits_soft(jnp.asarray(s)) - 1.0)


def precision_from_s(s):
    """Readout p = 1 + round(log2(1 + e^{-s})) (paper Alg. 1 line 9)."""
    return 1.0 + jnp.round(bits_soft(s) - 1.0)


def snap_124(p):
    """Closest precision in {1, 2, 4}; ties round toward more bits (favors
    accuracy — paper Alg. 2 line 11). Note the paper first rounds the raw
    readout to an integer, so raw p in [2.5, 3) -> 3 -> snaps to 4: the
    effective 4-bit band starts at raw 2.5."""
    p = jnp.asarray(p, jnp.float32)
    return jnp.where(p >= 2.5, 4.0, jnp.where(p >= 1.5, 2.0, 1.0))


# Thresholds on s for the {4, 2, 1}-bit bands (inverse of the round-then-snap
# readout; used by PatternMatch, paper Alg. 3). s < T_4B -> 4 bits;
# s < T_2B -> 2 bits; else 1 bit.
T_4B = float(-np.log(2.0 ** 1.5 - 1.0))  # raw p = 2.5
T_2B = float(-np.log(np.sqrt(2) - 1.0))  # raw p = 1.5
# Representative logits assigned by PatternMatch (s_init of each precision).
S_4B, S_2B, S_1B = s_init(4), s_init(2), s_init(1)


def inject_weight_noise(w, s, key, group_size: int = GROUP_SIZE):
    """w + sigma(s) * eps,  eps ~ U(+-1), sigma broadcast per K-group; then
    clip to +-(2 - sigma(s)) (paper Alg. 1 lines 4-7).

    w: [K, ...] with K = group_size * len(s).
    """
    w = jnp.asarray(w)
    k = w.shape[0]
    sig = jnp.repeat(sigma(s), group_size, total_repeat_length=k)
    sig = sig.reshape((k,) + (1,) * (w.ndim - 1)).astype(w.dtype)
    eps = jax.random.uniform(key, w.shape, w.dtype, -1.0, 1.0)
    w_noisy = w + sig * eps
    lim = (2.0 - sig).astype(w.dtype)
    return jnp.clip(w_noisy, -lim, lim)


def inject_act_noise(x, s, key, scale=1.0, group_size: int = GROUP_SIZE):
    """Same perturbation applied to the activations that multiply those
    channels (paper Alg. 2 line 6), along the last dim of x. ``scale``
    matches the activation quantization scale so the noise magnitude is in
    activation units."""
    x = jnp.asarray(x)
    k = x.shape[-1]
    sig = jnp.repeat(sigma(s), group_size, total_repeat_length=k).astype(x.dtype)
    eps = jax.random.uniform(key, x.shape, x.dtype, -1.0, 1.0)
    return x + jnp.asarray(scale, x.dtype) * sig * eps


def clip_weights(w, s, group_size: int = GROUP_SIZE):
    """Projection step after the optimizer update (paper Alg. 1 line 7):
    clip w to +-(2 - sigma(s))."""
    k = w.shape[0]
    sig = jnp.repeat(sigma(s), group_size, total_repeat_length=k)
    lim = (2.0 - sig).reshape((k,) + (1,) * (w.ndim - 1)).astype(w.dtype)
    return jnp.clip(w, -lim, lim)
