"""Fused clipped-STE fake-quantization forward (QAT hot path).

x [M, K] is quantize-dequantized along K with per-16-channel-group
precisions ``pbits`` [K//16] and either a per-row scale [M, 1] (dynamic
activation scaling) or a per-group scale [K//16] (weight scaling) — the
two shapes ``core.quant.fake_quant`` actually receives from the QAT phase
rules. Grid (M/bm, K/bk); pure VPU (round/clip/multiply), no MXU.

Element-wise arithmetic is kept identical to
``core.quant._fake_quant_fwd_impl`` (branchless in p: h = 2^(1-p),
u = clip(round((x/s/h + 2^p - 1) / 2)), back through (2u - (2^p-1))·h·s,
rounded through the input dtype), so the kernel is bit-exact against the
jnp reference — the backward pass (clipped STE) recomputes the in-range
mask in jnp through the shared custom VJP in ``repro.backend.base``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.qtypes import GROUP_SIZE


def _kernel(x_ref, pb_ref, s_ref, o_ref, *, row_scale: bool):
    x = x_ref[...].astype(jnp.float32)
    p = jnp.repeat(pb_ref[...].astype(jnp.float32), GROUP_SIZE,
                   axis=1)                                  # [1, bk]
    if row_scale:
        s = s_ref[...].astype(jnp.float32)                  # [bm, 1]
    else:
        s = jnp.repeat(s_ref[...].astype(jnp.float32), GROUP_SIZE,
                       axis=1)                              # [1, bk]
    xs = x / s
    h = jnp.exp2(1.0 - p)                 # 2^(1-p): half-step
    two_p = 2.0 / h                       # 2^p
    u = jnp.clip(jnp.round((xs / h + (two_p - 1.0)) / 2.0), 0.0,
                 two_p - 1.0)
    q = (2.0 * u - (two_p - 1.0)) * h
    o_ref[...] = (q * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "row_scale", "block_m", "block_k", "interpret"))
def fake_quant(x, pbits, scale, *, row_scale: bool, block_m: int = 256,
               block_k: int = 256, interpret: bool = True):
    """x [M, K], pbits [K//16] -> quantize-dequantized x (same dtype).

    ``scale`` is [M, 1] when ``row_scale`` (per-token activation scaling)
    else [K//16] (per-group weight scaling).
    """
    from .packed_matmul import fit_block
    m, k = x.shape
    bm = fit_block(m, block_m)
    bk = fit_block(k, block_k, GROUP_SIZE)
    pb2 = jnp.asarray(pbits, jnp.float32).reshape(1, -1)
    if row_scale:
        s_op = jnp.asarray(scale, jnp.float32).reshape(m, 1)
        s_spec = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    else:
        s_op = jnp.asarray(scale, jnp.float32).reshape(1, -1)
        s_spec = pl.BlockSpec((1, bk // GROUP_SIZE), lambda i, j: (0, j))
    kern = functools.partial(_kernel, row_scale=row_scale)
    return pl.pallas_call(
        kern,
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((1, bk // GROUP_SIZE), lambda i, j: (0, j)),
            s_spec,
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), x.dtype),
        interpret=interpret,
    )(x, pb2, s_op)
