"""Mamba2 / SSD (state-space duality, arXiv:2405.21060) block.

Scalar-identity A per head, ngroups=1. Training/prefill uses the chunked
SSD decomposition (intra-chunk quadratic + inter-chunk recurrence via
lax.scan); decode is the O(1) state update. The recurrent state stays fp32
— the SONIQ analog of "the accumulator stays wide" (DESIGN.md §5) — while
in/out projections are SmolLinear-quantized.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smol
from repro.core.qtypes import QuantConfig
from .common import rms_norm
from .shard import shard

CONV_K = 4          # causal depthwise conv width
HEAD_DIM = 64       # SSM head dim P


def mamba2_init(key, d_model: int, d_state: int, qcfg: QuantConfig, *,
                expand: int = 2, dtype=jnp.float32) -> Dict:
    d_inner = expand * d_model
    h = d_inner // HEAD_DIM
    conv_dim = d_inner + 2 * d_state
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * d_state + h       # z, x, B, C, dt
    dt = np.exp(np.random.default_rng(0).uniform(
        np.log(1e-3), np.log(1e-1), h)).astype(np.float32)
    return {
        "in_proj": smol.linear_init(ks[0], d_model, proj_out, qcfg,
                                    dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),      # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(np.log(np.expm1(dt)), jnp.float32),
        "norm_g": jnp.ones((d_inner,), jnp.float32),
        "out_proj": smol.linear_init(ks[3], d_inner, d_model, qcfg,
                                     dtype=dtype),
    }


def _segsum_exp(da):
    """da [..., L] log-decays -> lower-triangular decay matrix
    L[i, j] = exp(sum_{j < t <= i} da_t), 0 for j > i. [..., L, L]."""
    l = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # [..., i, j]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(xdt, da, b_mat, c_mat, chunk: int):
    """Chunked SSD scan.

    xdt   [B, S, H, P]  (x pre-multiplied by dt)
    da    [B, S, H]     (dt * A, negative log-decay per step)
    b_mat [B, S, N], c_mat [B, S, N]   (ngroups=1, broadcast over H)
    Returns y [B, S, H, P] (fp32) and final state [B, H, P, N].
    """
    bsz, s, h, p = xdt.shape
    n = b_mat.shape[-1]
    q = chunk if s % chunk == 0 else int(np.gcd(s, chunk))
    nc = s // q
    xdt = xdt.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    da = da.reshape(bsz, nc, q, h).astype(jnp.float32)
    bm = b_mat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cm = c_mat.reshape(bsz, nc, q, n).astype(jnp.float32)

    da_h = jnp.moveaxis(da, -1, 2)                       # [B, nc, H, Q]
    da_cs = jnp.cumsum(da_h, axis=-1)                    # [B, nc, H, Q]

    # Intra-chunk (quadratic within chunk):
    ell = _segsum_exp(da_h)                              # [B,nc,H,Q,Q]
    cb = jnp.einsum("bcin,bcjn->bcij", cm, bm)           # [B,nc,Q,Q]
    y_diag = jnp.einsum("bcij,bchij,bcjhp->bcihp",
                        cb, ell, xdt)

    # Chunk states: contribution of each chunk to the carried state.
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)      # [B,nc,H,Q]
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", bm, decay_states, xdt)

    # Inter-chunk recurrence (sequential over nc — the only scan).
    chunk_decay = jnp.exp(da_cs[..., -1])                # [B,nc,H]

    def step(hprev, inp):
        st, dec = inp
        return dec[..., None, None] * hprev + st, hprev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # [B,nc,H,P,N]

    # Inter-chunk output: decayed carried state read by C.
    state_decay = jnp.exp(da_cs)                         # [B,nc,H,Q]
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", cm, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, h_last


def ssd_reference(xdt, da, b_mat, c_mat):
    """Naive sequential recurrence (oracle for tests)."""
    bsz, s, h, p = xdt.shape
    n = b_mat.shape[-1]

    def step(hprev, t):
        xt, dat, bt, ct = t
        hnew = jnp.exp(dat)[..., None, None] * hprev \
            + jnp.einsum("bhp,bn->bhpn", xt, bt)
        yt = jnp.einsum("bhpn,bn->bhp", hnew, ct)
        return hnew, yt

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(xdt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(da, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last


def _split_proj(zxbcdt, d_inner: int, d_state: int, h: int):
    z = zxbcdt[..., :d_inner]
    xin = zxbcdt[..., d_inner:2 * d_inner]
    b_mat = zxbcdt[..., 2 * d_inner:2 * d_inner + d_state]
    c_mat = zxbcdt[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * d_state:]
    return z, xin, b_mat, c_mat, dt_raw


def _causal_conv(seq, w, b):
    """Depthwise causal conv. seq [B,S,C]; w [K,C]; left-pad K-1."""
    k = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i:i + seq.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(seq.dtype)


def mamba2_apply(params: Dict, x, qcfg: QuantConfig, rng=None, *,
                 d_state: int, expand: int = 2, chunk: int = 256):
    """Full-sequence forward. x [B, S, D] -> [B, S, D]."""
    bsz, s, d_model = x.shape
    d_inner = expand * d_model
    h = d_inner // HEAD_DIM
    rngs = [None, None] if rng is None else list(jax.random.split(rng))
    zxbcdt = smol.linear_apply(params["in_proj"], x, qcfg, rngs[0])
    z, xin, b_mat, c_mat, dt_raw = _split_proj(zxbcdt, d_inner, d_state, h)
    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin = conv_out[..., :d_inner]
    b_mat = conv_out[..., d_inner:d_inner + d_state]
    c_mat = conv_out[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])            # [B,S,H]
    a = -jnp.exp(params["A_log"])                        # [H]
    xh = xin.reshape(bsz, s, h, HEAD_DIM)
    xh = shard(xh, "batch", "seq", "ssm_heads", None)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    y, _ = ssd_chunked(xdt, dt * a, b_mat, c_mat, chunk)
    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))           # gated
    y = rms_norm({"g": params["norm_g"]}, y)
    return smol.linear_apply(params["out_proj"], y.astype(x.dtype), qcfg,
                             rngs[1])


# ------------------------------------------------------------- decode ----
def init_ssm_cache(batch: int, d_model: int, d_state: int, *,
                   expand: int = 2, dtype=jnp.float32) -> Dict:
    d_inner = expand * d_model
    h = d_inner // HEAD_DIM
    conv_dim = d_inner + 2 * d_state
    return {
        "h": jnp.zeros((batch, h, HEAD_DIM, d_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
    }


def ssm_cache_specs(batch: int, d_model: int, d_state: int, *,
                    expand: int = 2, dtype=jnp.float32) -> Dict:
    d_inner = expand * d_model
    h = d_inner // HEAD_DIM
    sd = jax.ShapeDtypeStruct
    return {"h": sd((batch, h, HEAD_DIM, d_state), jnp.float32),
            "conv": sd((batch, CONV_K - 1, d_inner + 2 * d_state), dtype)}


def mamba2_decode(params: Dict, x, cache: Dict, qcfg: QuantConfig, *,
                  d_state: int, expand: int = 2,
                  layer_idx=None) -> Tuple[jax.Array, Dict]:
    """One-token decode. x [B, 1, D]. With layer_idx, cache leaves are the
    stacked [L, ...] buffers (decode-scan carry; in-place update)."""
    stacked = layer_idx is not None
    full_cache = cache
    if stacked:
        cache = {k: jax.lax.dynamic_index_in_dim(v, layer_idx, 0, False)
                 for k, v in cache.items()}
    bsz, _, d_model = x.shape
    d_inner = expand * d_model
    h = d_inner // HEAD_DIM
    zxbcdt = smol.linear_apply(params["in_proj"], x[:, 0], qcfg, None)
    z, xin, b_mat, c_mat, dt_raw = _split_proj(zxbcdt, d_inner, d_state, h)
    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)  # [B, C]
    window = jnp.concatenate([cache["conv"],
                              conv_in[:, None].astype(cache["conv"].dtype)],
                             axis=1)                          # [B, K, C]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    xin = conv_out[..., :d_inner]
    b_mat = conv_out[..., d_inner:d_inner + d_state]
    c_mat = conv_out[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    xh = xin.reshape(bsz, h, HEAD_DIM)
    hs = jnp.exp(dt * a)[..., None, None] * cache["h"] \
        + jnp.einsum("bhp,bn,bh->bhpn", xh, b_mat, dt)
    y = jnp.einsum("bhpn,bn->bhp", hs, c_mat) \
        + params["D"][None, :, None] * xh
    y = y.reshape(bsz, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm({"g": params["norm_g"]}, y)
    out = smol.linear_apply(params["out_proj"], y.astype(x.dtype), qcfg,
                            None)
    new_cache = {"h": hs, "conv": window[:, 1:]}
    if stacked:
        new_cache = {k: full_cache[k].at[layer_idx].set(v)  # soniq-lint: disable=SQ001(scan layer index < num_layers by construction)
                     for k, v in new_cache.items()}
    return out[:, None], new_cache
