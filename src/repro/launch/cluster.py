"""Multiprocess cluster simulator: coordinator + workers with heartbeats,
failure injection, and elastic re-mesh — the control-plane logic a real
TPU fleet runs, exercised end-to-end on CPU (tests/test_cluster_sim.py).

Workers run short training bursts, heartbeat to the coordinator through a
multiprocessing queue, and checkpoint to shared storage. The coordinator
detects missed heartbeats (HeartbeatMonitor), plans a smaller mesh
(plan_remesh), rescales grad accumulation (rescale_microbatches), and
relaunches survivors from the latest checkpoint.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import queue
import time
from typing import Dict, List, Optional

from repro.train import ft


@dataclasses.dataclass
class ClusterConfig:
    num_hosts: int = 4
    chips_per_host: int = 4
    model_parallel: int = 4
    global_batch: int = 32
    heartbeat_timeout: float = 5.0
    steps_per_burst: int = 2


def _worker(host_id: int, cfg: ClusterConfig, beat_q: mp.Queue,
            ctrl_q: mp.Queue, ckpt_dir: str, die_after: Optional[int]):
    """One host: train bursts + heartbeats; dies silently at die_after."""
    step = 0
    while True:
        try:
            msg = ctrl_q.get_nowait()
            if msg == "stop":
                return
        except queue.Empty:
            pass
        if die_after is not None and step >= die_after:
            return                      # simulated hardware failure
        time.sleep(0.05)                # "training burst"
        step += cfg.steps_per_burst
        with open(os.path.join(ckpt_dir, f"host{host_id}.step"), "w") as f:
            f.write(str(step))
        beat_q.put((host_id, step, time.time()))


class Coordinator:
    def __init__(self, cfg: ClusterConfig, ckpt_dir: str):
        self.cfg = cfg
        self.ckpt_dir = ckpt_dir
        os.makedirs(ckpt_dir, exist_ok=True)
        self.events: List[Dict] = []

    def run(self, die_host: Optional[int] = None, die_after: int = 6,
            run_for: float = 4.0) -> Dict:
        cfg = self.cfg
        ctx = mp.get_context("spawn")   # fork is unsafe under JAX threads
        beat_q = ctx.Queue()
        ctrls = [ctx.Queue() for _ in range(cfg.num_hosts)]
        procs = [
            ctx.Process(target=_worker,
                        args=(h, cfg, beat_q, ctrls[h], self.ckpt_dir,
                              die_after if h == die_host else None),
                        daemon=True)
            for h in range(cfg.num_hosts)]
        for p in procs:
            p.start()

        hb = ft.HeartbeatMonitor(range(cfg.num_hosts),
                                 timeout=cfg.heartbeat_timeout)
        mesh = (cfg.num_hosts * cfg.chips_per_host // cfg.model_parallel,
                cfg.model_parallel)
        microbatches = 1
        # Join barrier: don't start failure detection until every host has
        # heartbeat at least once — spawn startup pays a full interpreter
        # (+ jax) import, which can exceed the detection threshold on slow
        # machines and would mark still-booting hosts dead.
        joined: set = set()
        join_deadline = time.time() + 120.0
        while len(joined) < cfg.num_hosts and time.time() < join_deadline:
            try:
                host, step, t = beat_q.get(timeout=0.5)
                hb.beat(host, t)
                joined.add(host)
            except queue.Empty:
                pass
            # A worker that exited before its first heartbeat (startup
            # crash) will never join — count it so the detection loop
            # below can declare it dead instead of stalling here.
            for h, p in enumerate(procs):
                if h not in joined and not p.is_alive():
                    joined.add(h)
        deadline = time.time() + run_for
        remeshed = False
        while time.time() < deadline:
            try:
                host, step, t = beat_q.get(timeout=0.2)
                hb.beat(host, t)
            except queue.Empty:
                pass
            # fast failure detection for the simulation: a host that
            # hasn't beaten in 1s while others have is failed
            now = time.time()
            alive = [h for h, st in hb.hosts.items()
                     if now - st.last_beat < 1.0]
            dead = [h for h in hb.hosts if h not in alive and not remeshed]
            if dead and len(alive) >= cfg.model_parallel // cfg.chips_per_host:
                survivors = len(alive)
                new_data, model = ft.plan_remesh(
                    survivors, model=cfg.model_parallel,
                    chips_per_host=cfg.chips_per_host)
                microbatches = ft.rescale_microbatches(
                    cfg.global_batch, old_data=mesh[0], new_data=new_data,
                    old_mb=microbatches)
                self.events.append({
                    "type": "remesh", "dead": dead, "survivors": survivors,
                    "new_mesh": (new_data, model),
                    "microbatches": microbatches,
                    "resume_step": self._latest_step(alive)})
                mesh = (new_data, model)
                remeshed = True
        for q in ctrls:
            q.put("stop")
        for p in procs:
            p.join(timeout=5)
            if p.is_alive():
                p.terminate()
        return {"events": self.events, "final_mesh": mesh,
                "microbatches": microbatches}

    def _latest_step(self, alive: List[int]) -> int:
        steps = []
        for h in alive:
            p = os.path.join(self.ckpt_dir, f"host{h}.step")
            if os.path.exists(p):
                with open(p) as f:
                    steps.append(int(f.read().strip() or 0))
        return min(steps) if steps else 0
