"""Mixtral-8x22B [arXiv:2401.04088; hf] — 8 experts top-2, SWA: 56L
d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768."""
from .base import ArchConfig
from .registry import register


@register("mixtral-8x22b")
def mixtral() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, head_dim=128,
        rope_theta=1e6, window=4096, mlp_act="swiglu",
        num_experts=8, top_k=2, tie_embeddings=False,
        source="arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1 "
               "(window per assignment brief)",
    )
