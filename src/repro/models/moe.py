"""Mixture-of-Experts: top-k routing with sort-based static-capacity
dispatch (GSPMD/EP-friendly), shared + fine-grained routed experts
(DeepSeekMoE), Mixtral-style top-2.

Dispatch: flatten (token, k) assignments, rank tokens within each expert
via a stable argsort of expert ids, drop beyond static capacity
C = ceil(T * top_k / E * capacity_factor), gather into [E, C, D], run
batched expert matmuls (einsum 'ecd,edf->ecf' — one grouped GEMM per
projection, which is what shards over the expert axis), scatter back with
gates. The router is kept full-precision (accuracy-critical, tiny);
expert weights are SmolLinear-quantized with per-expert precisions.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smol
from repro.core.qtypes import QuantConfig
from .common import activation
from .shard import shard


def moe_init(key, d_model: int, d_ff: int, num_experts: int, top_k: int,
             qcfg: QuantConfig, *, num_shared: int = 0,
             shared_d_ff: Optional[int] = None, act: str = "swiglu",
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 5)
    e = num_experts

    def expert_bank(k, din, dout):
        sub = jax.random.split(k, e)
        leaves = [smol.linear_init(sk, din, dout, qcfg, dtype=dtype)
                  for sk in sub]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)

    p = {
        "router": smol.linear_init(ks[0], d_model, e, qcfg, quantized=False,
                                   dtype=jnp.float32),
        "up": expert_bank(ks[1], d_model, d_ff),
        "down": expert_bank(ks[2], d_ff, d_model),
    }
    if act == "swiglu":
        p["gate"] = expert_bank(ks[3], d_model, d_ff)
    if num_shared:
        from .mlp import mlp_init
        p["shared"] = mlp_init(ks[4], d_model,
                               (shared_d_ff or d_ff) * num_shared, qcfg,
                               act=act, dtype=dtype)
    return p


def _expert_linear(bank: Dict, x_e, qcfg: QuantConfig, rng):
    """bank: stacked-per-expert SmolLinear params [E, ...]; x_e [E, C, D].

    When EP is off, the weight is explicitly resharded to
    (None, None, expert_ff) at the point of use: the contraction dim K is
    fsdp-sharded at rest, and without this constraint GSPMD resolves the
    K(w)-vs-C(x) conflict by all-gathering the *activations* — 5x more
    bytes than gathering the weights (mixtral train: 10.8 TB vs 0.7 TB per
    step; §Perf B1). Under EP the constraint would erase the dp split of
    the expert compute (measured 4.5x redundant FLOPs) — skip it."""
    from .shard import spec, rules_active
    bank = dict(bank)
    ep_active = rules_active() and spec("experts")[0] is not None
    if "w" in bank and bank["w"].ndim == 3 and not ep_active:
        bank["w"] = shard(bank["w"], "experts", None, "expert_ff")
    e = x_e.shape[0]
    if rng is not None:
        rngs = jax.random.split(rng, e)
        return jax.vmap(lambda p, x, r: smol.linear_apply(p, x, qcfg, r)
                        )(bank, x_e, rngs)
    return jax.vmap(lambda p, x: smol.linear_apply(p, x, qcfg, None)
                    )(bank, x_e)


def moe_apply(params: Dict, x, qcfg: QuantConfig, rng=None, *,
              num_experts: int, top_k: int, act: str = "swiglu",
              capacity_factor: float = 1.25,
              router_norm_topk: bool = True):
    """x [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e = num_experts
    cap = max(1, math.ceil(t * top_k / e * capacity_factor))

    # --- routing (fp32) ---
    logits = smol.linear_apply(params["router"], xt.astype(jnp.float32),
                               qcfg)                        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)     # [T, k]
    if router_norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- sort-based dispatch with static capacity ---
    flat_expert = expert_ids.reshape(-1)                    # [T*k]
    order = jnp.argsort(flat_expert, stable=True)           # group by expert
    sorted_expert = flat_expert[order]
    # rank within expert = running index - start index of that expert's run
    start = jnp.searchsorted(sorted_expert, jnp.arange(e))  # [E]
    rank_sorted = jnp.arange(t * top_k) - start[sorted_expert]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)  # [T*k]  soniq-lint: disable=SQ001(argsort order is a bijection)
    keep = rank < cap                                       # capacity drop
    rank_c = jnp.minimum(rank, cap - 1)

    # Scatter-add straight into the born-sharded [E, C, D] buffer: dropped
    # tokens add zeros into the clamped last slot (no overflow row — its
    # odd size would force the buffer replicated, and GSPMD then implements
    # the scatter as a full-buffer all-reduce; §Perf A1/B1).
    token_idx = jnp.repeat(jnp.arange(t), top_k)
    gathered = shard(xt[token_idx], "tokens", "embed")
    upd = jnp.where(keep[:, None], gathered, jnp.zeros_like(gathered))
    x_e = shard(jnp.zeros((e, cap, d), x.dtype),
                "experts", "expert_cap", "embed")
    x_e = x_e.at[flat_expert, rank_c].add(upd)  # soniq-lint: disable=SQ001(rank_c clamped to cap-1; dropped rows add zeros)
    x_e = shard(x_e, "experts", "expert_cap", "embed")

    # --- expert FFN (grouped GEMMs over the expert axis) ---
    rngs = [None] * 3 if rng is None else list(jax.random.split(rng, 3))
    h = _expert_linear(params["up"], x_e, qcfg, rngs[0])    # [E, C, F]
    h = shard(h, "experts", "expert_cap", "expert_ff")
    if act == "swiglu":
        g = _expert_linear(params["gate"], x_e, qcfg, rngs[1])
        h = jax.nn.silu(g) * h
    else:
        h = activation(act)(h)
    y_e = _expert_linear(params["down"], h, qcfg, rngs[2])  # [E, C, D]
    y_e = shard(y_e, "experts", "expert_cap", "embed")

    # --- combine ---
    y_tok = shard(y_e[flat_expert, rank_c], "tokens", "embed")  # [T*k, D]
    gates = jnp.where(keep, gate_vals.reshape(-1), 0.0).astype(y_tok.dtype)
    y = jax.ops.segment_sum(y_tok * gates[:, None], token_idx, num_segments=t)
    y = shard(y, "tokens", "embed")

    # --- shared experts (DeepSeekMoE): dense, every token ---
    if "shared" in params:
        from .mlp import mlp_apply
        y = y + mlp_apply(params["shared"], xt[None], qcfg,
                          None if rng is None else rngs[0], act=act)[0]

    # load-balancing auxiliary loss (GShard/Switch style)
    me = jnp.mean(probs, axis=0)                             # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(expert_ids, e).sum(1) > 0).astype(jnp.float32),
        axis=0)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux
