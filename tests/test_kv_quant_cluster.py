"""Quantized KV cache numerics + elastic cluster simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import kv_quant


def test_kv_quant_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 4, 64)) * 3.0
    codes, scale = kv_quant.quantize_kv(x)
    assert codes.shape == (2, 16, 4, 32) and codes.dtype == jnp.uint8
    y = kv_quant.dequantize_kv(codes, scale, jnp.float32)
    # per-head absmax scaling at 4 bits: error <= scale * 2^(1-4), with
    # slack for the fp16 scale rounding (~1e-3 relative)
    err = np.abs(np.asarray(y - x))
    bound = np.asarray(scale, np.float32) * 2.0 ** (1 - 4) * 1.02 + 1e-2
    assert (err <= bound).all()
    rel = np.linalg.norm(err) / np.linalg.norm(np.asarray(x))
    # theory: absmax over 64 gaussians ~ 2.7 sigma -> rel err std ~ 0.104
    assert rel < 0.12


def test_kv_cache_update_and_read():
    cache = kv_quant.init_qkv_cache(2, 8, 2, 32)
    key = jax.random.PRNGKey(1)
    for t in range(10):    # wraps the ring at 8
        k_new = jax.random.normal(jax.random.fold_in(key, t), (2, 1, 2, 32))
        v_new = -k_new
        pos = jnp.asarray([t, t], jnp.int32)
        cache = kv_quant.update_qkv_cache(cache, k_new, v_new, pos)
    k, v, pos = kv_quant.read_qkv_cache(cache, jnp.float32)
    assert k.shape == (2, 8, 2, 32)
    # slot for t=9 is 9 % 8 = 1; check it round-trips the t=9 write
    want = jax.random.normal(jax.random.fold_in(key, 9), (2, 1, 2, 32))
    got = k[:, 1]
    rel = float(jnp.linalg.norm(got - want[:, 0]) / jnp.linalg.norm(want))
    assert rel < 0.12      # 4-bit roundtrip of one token
    assert int(pos[0, 1]) == 9
    np.testing.assert_allclose(np.asarray(v), -np.asarray(k), rtol=0.2,
                               atol=0.05)


def test_kv_quant_rms_error_within_3pct_of_range():
    """The DESIGN.md §8 KV claim, pinned quantitatively: on
    attention-scale (unit-gaussian K/V, any magnitude) inputs, the
    quantize->dequantize round-trip RMS error is <= 3% of each
    (batch, pos, head)'s dynamic range, and the worst-case element error
    <= 3.5% of it (the 4-bit half-step bound). Norm-relative error on
    gaussian K/V is ~10-12% — 4 bits cannot do better; the range-relative
    bound is the one the grid actually guarantees."""
    for seed, mag in ((0, 1.0), (1, 3.0), (2, 0.05)):
        x = jax.random.normal(jax.random.PRNGKey(seed), (2, 32, 4, 64)) * mag
        codes, scale = kv_quant.quantize_kv(x)
        y = kv_quant.dequantize_kv(codes, scale, jnp.float32)
        err = np.abs(np.asarray(y - x))
        rng_ = 2 * np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
        assert np.sqrt(np.mean((err / rng_) ** 2)) <= 0.03
        assert np.max(err / rng_) <= 0.035


def test_kv_ring_wraparound_overwrites_oldest():
    """Writing cache_len + k tokens must leave exactly the newest
    cache_len positions resident, each in its pos % cache_len slot."""
    cache_len = 8
    cache = kv_quant.init_qkv_cache(1, cache_len, 2, 16)
    key = jax.random.PRNGKey(3)
    for t in range(13):                     # 5 past the wrap
        k_new = jax.random.normal(jax.random.fold_in(key, t), (1, 1, 2, 16))
        cache = kv_quant.update_qkv_cache(cache, k_new, k_new,
                                          jnp.asarray([t], jnp.int32))
    pos = np.asarray(cache["pos"][0])
    assert sorted(pos.tolist()) == list(range(5, 13))       # newest 8 live
    for t in range(5, 13):
        assert pos[t % cache_len] == t
    assert kv_quant.slot_lengths(cache).tolist() == [cache_len]


def test_kv_slot_eviction_resets_only_target_rows():
    cache = kv_quant.init_qkv_cache(3, 8, 2, 16)
    key = jax.random.PRNGKey(4)
    for t in range(4):
        k_new = jax.random.normal(jax.random.fold_in(key, t), (3, 1, 2, 16))
        cache = kv_quant.update_qkv_cache(
            cache, k_new, k_new, jnp.asarray([t] * 3, jnp.int32))
    before = {k: np.asarray(v) for k, v in cache.items()}
    cache = kv_quant.evict_slot(cache, 1)
    assert np.asarray(cache["pos"][1] == -1).all()
    assert np.asarray(cache["k_codes"][1] == 0).all()
    assert np.asarray(cache["k_scale"][1] == 0).all()
    for row in (0, 2):                                      # untouched
        for name in ("pos", "k_codes", "v_codes", "k_scale", "v_scale"):
            np.testing.assert_array_equal(np.asarray(cache[name][row]),
                                          before[name][row])
    assert kv_quant.slot_lengths(cache).tolist() == [4, 0, 4]
    # an evicted row re-admits cleanly: new writes land and read back
    k_new = jax.random.normal(jax.random.fold_in(key, 99), (3, 1, 2, 16))
    cache = kv_quant.update_qkv_cache(cache, k_new, k_new,
                                      jnp.asarray([0] * 3, jnp.int32))
    assert kv_quant.slot_lengths(cache).tolist() == [4, 1, 4]


def test_kv_cache_4x_smaller():
    q = kv_quant.init_qkv_cache(4, 128, 8, 128)
    qb = kv_quant.cache_bytes(q)
    bf16_bytes = 2 * (4 * 128 * 8 * 128 * 2)      # K and V in bf16
    assert qb < 0.40 * bf16_bytes                  # ~4x (+ scales + pos)


@pytest.mark.parametrize("die", [None, 2])
def test_cluster_sim_elastic_remesh(tmp_path, die):
    from repro.launch import cluster
    cfg = cluster.ClusterConfig(num_hosts=4, chips_per_host=4,
                                model_parallel=4, global_batch=32)
    coord = cluster.Coordinator(cfg, str(tmp_path))
    out = coord.run(die_host=die, die_after=4, run_for=3.0)
    if die is None:
        assert out["events"] == []
        assert out["final_mesh"] == (4, 4)
    else:
        assert len(out["events"]) == 1
        ev = out["events"][0]
        assert ev["type"] == "remesh" and die in ev["dead"]
        # TP degree preserved; data axis shrank; global batch preserved
        # via more grad accumulation
        assert ev["new_mesh"][1] == 4
        assert ev["new_mesh"][0] < 4
        assert ev["microbatches"] >= 2
        assert ev["resume_step"] >= 0
