"""repro.analysis.kernel_audit: the Pallas kernel contract audit flags
planted geometry/dtype mutants (off-by-one index maps, non-dividing
blocks, low-precision accumulation, store-free kernels), passes every
real kernel over the full arch x candidate sweep, and holds the
kernel<->Backend-op manifest 1:1 (DESIGN.md §16)."""
import jax
import jax.numpy as jnp
import pytest

import jax.experimental.pallas as pl

from repro.analysis import kernel_audit as ka


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _entry(in_spec, out_spec, grid, shape, kernel=_copy_kernel):
    def fn(x, *, interpret=True):
        return pl.pallas_call(
            kernel, grid=grid, in_specs=[in_spec], out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
            interpret=interpret)(x)
    return fn


# -------------------------------------------------- geometry mutants ----

def test_off_by_one_index_map_is_flagged():
    """The planted mutant: an index map shifted by one block walks past
    the operand on the far grid corner — the ring-clobber shape."""
    fn = _entry(pl.BlockSpec((8,), lambda i: (i + 1,)),
                pl.BlockSpec((8,), lambda i: (i,)), (2,), (16,))
    caps = ka.capture_pallas_calls(fn, (_f32(16),), {})
    issues = [i for c in caps
              for i in ka.check_capture_geometry(c, "mutant")]
    assert any(i.check == "kernel_geometry"
               and "out of bounds" in i.message for i in issues)
    # The message names the corner and the overrun block.
    msg = next(i.message for i in issues if "out of bounds" in i.message)
    assert "(1,)" in msg and "[16, 24)" in msg


def test_non_dividing_block_is_flagged():
    fn = _entry(pl.BlockSpec((6,), lambda i: (i,)),
                pl.BlockSpec((6,), lambda i: (i,)), (3,), (16,))
    caps = ka.capture_pallas_calls(fn, (_f32(16),), {})
    issues = [i for c in caps
              for i in ka.check_capture_geometry(c, "mutant")]
    assert any("does not divide" in i.message for i in issues)


def test_rank_mismatch_is_flagged():
    fn = _entry(pl.BlockSpec((8, 1), lambda i: (i, 0)),
                pl.BlockSpec((8,), lambda i: (i,)), (2,), (16,))
    caps = ka.capture_pallas_calls(fn, (_f32(16),), {})
    issues = [i for c in caps
              for i in ka.check_capture_geometry(c, "mutant")]
    assert any("rank" in i.message for i in issues)


def test_legal_geometry_is_quiet():
    fn = _entry(pl.BlockSpec((8,), lambda i: (i,)),
                pl.BlockSpec((8,), lambda i: (i,)), (2,), (16,))
    caps = ka.capture_pallas_calls(fn, (_f32(16),), {})
    assert caps and not [i for c in caps
                         for i in ka.check_capture_geometry(c, "ok")]


# ----------------------------------------------------- dtype mutants ----

def test_bf16_accumulation_is_flagged():
    """The planted mutant: a kernel dot that accumulates in bfloat16 —
    the silent precision change that breaks cross-backend parity."""
    def kernel(x_ref, w_ref, o_ref):
        o_ref[...] = jax.lax.dot(
            x_ref[...], w_ref[...],
            preferred_element_type=jnp.bfloat16).astype(jnp.float32)

    def fn(x, w, *, interpret=True):
        spec = pl.BlockSpec((8, 8), lambda i: (0, 0))
        return pl.pallas_call(
            kernel, grid=(1,), in_specs=[spec, spec], out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
            interpret=interpret)(x, w)

    issues = ka.check_entry_body(fn, (_f32(8, 8), _f32(8, 8)), {},
                                 "mutant")
    assert any(i.check == "kernel_dtype"
               and "does not accumulate in fp32" in i.message
               for i in issues)


def test_storeless_kernel_is_flagged():
    def kernel(x_ref, o_ref):
        _ = x_ref[...] * 2.0          # computes, never stores

    fn = _entry(pl.BlockSpec((8,), lambda i: (0,)),
                pl.BlockSpec((8,), lambda i: (0,)), (1,), (8,),
                kernel=kernel)
    issues = ka.check_entry_body(fn, (_f32(8),), {}, "mutant")
    assert any("no store primitive" in i.message for i in issues)


def test_fp32_kernel_body_is_quiet():
    def kernel(x_ref, w_ref, o_ref):
        o_ref[...] = jax.lax.dot(x_ref[...], w_ref[...],
                                 preferred_element_type=jnp.float32)

    def fn(x, w, *, interpret=True):
        spec = pl.BlockSpec((8, 8), lambda i: (0, 0))
        return pl.pallas_call(
            kernel, grid=(1,), in_specs=[spec, spec], out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
            interpret=interpret)(x, w)

    assert ka.check_entry_body(fn, (_f32(8, 8), _f32(8, 8)), {},
                               "ok") == []


# -------------------------------------------------- mapping contract ----

def test_manifest_maps_onto_backend_ops():
    from repro.backend import base
    for entry in ka.MANIFEST:
        assert entry.op in base.OPS, entry


def test_mapping_check_is_clean_on_tree():
    issues = ka.check_kernel_mapping()
    assert issues == [], "\n".join(i.format() for i in issues)


def test_every_manifest_entry_resolves_and_captures():
    """Each manifest kernel actually issues a pallas_call at a small
    legal geometry (the body-audit cases) — no silent fall-through."""
    raw = {e.func: ka._resolve(e) for e in ka.MANIFEST}
    seen = set()
    for case in ka._body_cases():
        caps = ka.capture_pallas_calls(
            raw[case["func"]], case["args"],
            {**case["static"], "interpret": True})
        assert caps, case["func"]
        seen.add(case["func"])
    assert seen == {e.func for e in ka.MANIFEST}


# ------------------------------------------------------- full sweep ----

def test_full_audit_clean_on_one_arch():
    """One representative arch keeps the test fast; CI's --check leg
    sweeps all registered archs."""
    report, issues = ka.run_kernel_audit(archs=["h2o-danube-1.8b"])
    assert issues == [], "\n".join(i.format() for i in issues)
    assert report["cases"] > 0 and report["candidates"] > 0
    # Every manifest kernel contributed at least one geometry case.
    assert all(v["cases"] > 0 for v in report["entries"].values()), report


def test_candidate_truncation_is_reported_not_silent():
    report, _ = ka.run_kernel_audit(archs=["mistral-large-123b"],
                                    max_candidates=2)
    assert report["max_candidates"] == 2
    assert report["candidates_truncated"] > 0
