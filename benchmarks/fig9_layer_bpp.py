"""Paper Fig. 9: per-layer average bits-per-parameter after Phase I +
PatternMatch (later layers quantize lower — more channels, less
per-channel information)."""
from __future__ import annotations

import numpy as np

import dataclasses

from repro.core.qtypes import P45
from . import _common


def run(steps=None):
    t = steps or _common.BENCH_STEPS
    r = _common.train_cnn(dataclasses.replace(P45, lam=2e-2), t1=t, t2=2 * t)
    layers = []
    if r["report"]:
        for i, lay in enumerate(r["report"]["layers"]):
            layers.append((f"layer{i}", lay["bpp"], lay["vectors"]))
    return layers, r


def main(steps=None):
    (layers, r), us = _common.timed(run, steps)
    for name, bpp, vecs in layers:
        _common.csv_row(f"fig9.{name}", us / max(len(layers), 1),
                        f"bpp={bpp:.3f}|vectors={vecs}")
    return layers


if __name__ == "__main__":
    main()
