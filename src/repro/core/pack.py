"""Bit-packing of SMOL integer codes into int8 carrier words.

TPU adaptation of the paper's vector-register data layout: a p-bit code
stream along the K (input-channel) axis is packed little-endian into uint8
bytes (8/p codes per byte). Weights [K, N] pack along K to [K*p//8, N] so the
packed byte stream for one output column is contiguous in the K-minor layout
the matmul kernel consumes.

Mixed precision uses the segment layout [K4 | K2 | K1] (channels already
reordered so same-precision runs are contiguous — paper Obs. 4): three packed
buffers + the (K4, K2, K1) metadata triple (3 ints per layer, paper Obs. 4).
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from . import quant

# The canonical [K4 | K2 | K1] segment order of a packed mixed-precision
# weight: (carrier name, precision bits, codes per carrier byte). Every
# consumer of packed buffers (jnp dequant below, the backend matmul driver,
# the Pallas kernels' wrappers) iterates this single tuple instead of
# re-deriving the layout.
SEGMENTS: Tuple[Tuple[str, int, int], ...] = (("w4", 4, 2), ("w2", 2, 4),
                                              ("w1", 1, 8))


def iter_packed_segments(bufs: Dict, group_size: int = 16
                         ) -> Iterator[Tuple[str, int, int, int, int, int]]:
    """Iterate the non-empty uniform-precision segments of packed carriers
    ``{"w4": [K4*4//8, ...], "w2": ..., "w1": ...}`` in [K4|K2|K1] order.

    Yields ``(name, p, k_off, kp, g_off, ng)`` per segment: the carrier
    name, its precision, the segment's channel offset/length along K, and
    its group offset/count (``kp // group_size`` groups of ``group_size``
    channels). Empty segments are skipped — the single place the
    skip-empty logic lives.
    """
    k_off = g_off = 0
    for name, p, vals_per_byte in SEGMENTS:
        kp = bufs[name].shape[0] * vals_per_byte
        if kp == 0:
            continue
        ng = max(kp // group_size, 1)
        yield name, p, k_off, kp, g_off, ng
        k_off += kp
        g_off += ng


def pack_codes(u, p: int):
    """Pack unsigned p-bit codes along axis 0. u: [K, ...] -> [K*p//8, ...]."""
    assert p in (1, 2, 4), p
    vpb = 8 // p                      # values per byte
    k = u.shape[0]
    assert k % vpb == 0, (k, p)
    u = jnp.asarray(u, jnp.uint8)
    u = u.reshape((k // vpb, vpb) + u.shape[1:])
    out = jnp.zeros(u.shape[:1] + u.shape[2:], jnp.uint8)
    for j in range(vpb):
        out = out | (u[:, j] << (p * j))
    return out


def unpack_codes(b, p: int, k: int):
    """Inverse of pack_codes. b: [K*p//8, ...] -> [K, ...] uint8 codes."""
    assert p in (1, 2, 4), p
    vpb = 8 // p
    b = jnp.asarray(b, jnp.uint8)
    parts = [(b >> (p * j)) & ((1 << p) - 1) for j in range(vpb)]
    u = jnp.stack(parts, axis=1)      # [K//vpb, vpb, ...]
    return u.reshape((k,) + b.shape[1:])


def dequant_packed_carriers(bufs: Dict, cdt, wscale=None,
                            group_size: int = 16):
    """Shared serve-path arithmetic: 2-D packed carriers
    ``{"w4": [K4*4//8, M], "w2": ..., "w1": ...}`` -> dequantized [K, M]
    grid values in the compute dtype ``cdt`` (uint8 loads -> shift/mask
    unpack -> affine dequant ``v = (2u - (2^p - 1)) * 2^(1-p)``), with
    optional per-group ``wscale`` applied. Both ``smol`` (linear) and the
    CNN conv serve forwards route through this — the grid/scale convention
    lives here once."""
    parts = []
    for name, p, _koff, kp, _goff, _ng in iter_packed_segments(
            bufs, group_size):
        u = unpack_codes(bufs[name], p, kp).astype(cdt)
        parts.append((2.0 * u - jnp.asarray(2 ** p - 1, cdt))
                     * jnp.asarray(2.0 ** (1 - p), cdt))
    wd = jnp.concatenate(parts, axis=0)
    if wscale is not None:
        k = wd.shape[0]
        s_full = jnp.repeat(wscale.astype(cdt), group_size,
                            total_repeat_length=k)
        wd = wd * s_full[:, None]
    return wd


def quantize_pack_weight(w, pbits, scale=None, group_size=16) -> Dict:
    """Quantize a [K, N] weight whose K-groups carry precisions ``pbits``
    (values in {1,2,4}, already *sorted descending* / segment-contiguous) and
    bit-pack each uniform-precision segment.

    Returns dict with packed buffers w4/w2/w1 ([Kp*p//8, N] uint8), the
    segment triple, and per-group scales (or None).
    """
    w = jnp.asarray(w, jnp.float32)
    k, n = w.shape
    pbits = np.asarray(pbits)
    assert pbits.ndim == 1 and pbits.shape[0] == k // group_size
    # Verify segment-contiguity (4s, then 2s, then 1s).
    order = {4: 0, 2: 1, 1: 2}
    ranks = np.array([order[int(p)] for p in pbits])
    assert np.all(np.diff(ranks) >= 0), "pbits must be sorted 4 -> 2 -> 1"

    k4 = int((pbits == 4).sum()) * group_size
    k2 = int((pbits == 2).sum()) * group_size
    k1 = int((pbits == 1).sum()) * group_size

    if scale is None:
        s_full = jnp.ones((k,), jnp.float32)
        scales = None
    else:
        scales = jnp.asarray(scale, jnp.float32)
        s_full = jnp.repeat(scales, group_size, total_repeat_length=k)

    ws = w / s_full[:, None]
    out = {"segments": (k4, k2, k1), "scales": scales, "n": n,
           "group_size": group_size}
    off = 0
    for (name, p, _vpb), kp in zip(SEGMENTS, (k4, k2, k1)):
        seg = ws[off:off + kp]
        u = quant.quantize_to_int(seg, p).astype(jnp.uint8)
        out[name] = (pack_codes(u, p) if kp else
                     jnp.zeros((0, n), jnp.uint8))
        off += kp
    return out


def unpack_dequantize_weight(packed: Dict):
    """Reference inverse: reconstruct the dequantized [K, N] fp32 weight."""
    k4, k2, k1 = packed["segments"]
    n = packed["n"]
    parts = []
    for (name, p, _vpb), kp in zip(SEGMENTS, (k4, k2, k1)):
        if kp == 0:
            continue
        u = unpack_codes(packed[name], p, kp)
        parts.append(quant.dequantize_int(u, p))
    w = jnp.concatenate(parts, axis=0) if parts else jnp.zeros((0, n))
    if packed["scales"] is not None:
        g = packed["group_size"]
        s_full = jnp.repeat(packed["scales"], g,
                            total_repeat_length=k4 + k2 + k1)
        w = w * s_full[:, None]
    return w


def packed_nbytes(packed: Dict) -> int:
    """Actual storage bytes of the packed weight (the paper's size metric)."""
    total = sum(int(np.prod(packed[n].shape)) for n in ("w4", "w2", "w1"))
    if packed["scales"] is not None:
        total += int(np.prod(packed["scales"].shape)) * 4
    return total + 3 * 4  # + the 3-int segment metadata (paper Obs. 4)


def bits_per_param(packed: Dict) -> float:
    k4, k2, k1 = packed["segments"]
    n = packed["n"]
    return 8.0 * packed_nbytes(packed) / max((k4 + k2 + k1) * n, 1)
