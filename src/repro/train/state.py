"""TrainState and the jit-able train_step (grad accumulation, two-phase
SONIQ, optional gradient compression)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import smol
from repro.core.phases import Phase
from repro.models import lm
from repro.optim import adamw, grad_compress, schedules


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_microbatches: int = 1
    adamw: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    warmup: int = 100
    t1: int = 350            # Phase I steps (paper T1)
    t2: int = 650            # total steps (paper T2)
    phase2_lr_mult: float = 0.3
    grad_compress: bool = False
    checkpoint_every: int = 100
    ckpt_dir: Optional[str] = None
    seed: int = 0
    # §Perf: fake-quantize weights once per step (outside the microbatch
    # scan) instead of once per microbatch. Numerically identical (weights
    # don't change between microbatches); cuts weight-processing HBM
    # traffic by ~num_microbatches.
    hoist_weight_quant: bool = False


def init_state(key, arch_cfg, tcfg: TrainConfig) -> Dict[str, Any]:
    params = lm.init_params(key, arch_cfg)
    state = {"params": params,
             "opt": adamw.init_state(params, tcfg.adamw.moment_dtype),
             "step": jnp.zeros((), jnp.int32)}
    if tcfg.grad_compress:
        state["err"] = grad_compress.init_error_tree(params)
    return state


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """Split the global batch into n microbatches along the batch axis.
    The M-RoPE "positions" input is [3, B, S] — its batch axis is 1."""
    out = {}
    for k, x in batch.items():
        if not hasattr(x, "shape") or x.ndim < 1:
            out[k] = x
        elif k == "positions" and x.ndim == 3 and x.shape[0] == 3:
            b = x.shape[1]
            assert b % n == 0, (k, x.shape, n)
            out[k] = jnp.moveaxis(
                x.reshape(3, n, b // n, x.shape[2]), 1, 0)
        else:
            assert x.shape[0] % n == 0, (k, x.shape, n)
            out[k] = x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return out


def train_step(state: Dict, batch: Dict, arch_cfg, tcfg: TrainConfig,
               rng) -> tuple:
    """One optimizer step with scanned gradient accumulation over
    tcfg.num_microbatches. Pure function of (state, batch, rng)."""
    params = state["params"]
    n_mb = tcfg.num_microbatches

    hoist = tcfg.hoist_weight_quant and arch_cfg.quant.phase is Phase.QAT
    if hoist:
        import dataclasses as _dc
        from repro.core import smol as _smol
        fwd_cfg = _dc.replace(
            arch_cfg, quant=_dc.replace(arch_cfg.quant, prequantized=True))
        compute_dtype = jnp.dtype(arch_cfg.dtype)
        params_fwd, preq_vjp = jax.vjp(
            lambda p: _smol.prequantize_tree(p, arch_cfg.quant,
                                             compute_dtype), params)
    else:
        fwd_cfg = arch_cfg
        params_fwd, preq_vjp = params, None

    def loss_of(p, mb, r):
        return lm.loss_fn(p, mb, fwd_cfg, r)

    grad_fn = jax.value_and_grad(lambda p, mb, r: loss_of(p, mb, r)[0],
                                 allow_int=True)

    if n_mb == 1:
        loss, grads = grad_fn(params_fwd, batch, rng)
    else:
        mbs = _split_microbatches(batch, n_mb)

        def body(carry, mb_idx):
            acc, loss_acc = carry
            mb = jax.tree.map(lambda x: x[mb_idx] if hasattr(x, "ndim")
                              and x.ndim >= 1 else x, mbs)
            r = jax.random.fold_in(rng, mb_idx)
            l, g = grad_fn(params_fwd, mb, r)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_mb
                if a is not None else None, acc, g,
                is_leaf=lambda x: x is None)
            return (acc, loss_acc + l / n_mb), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.floating) else None, params_fwd)
        (grads, loss), _ = jax.lax.scan(body, (zero, jnp.zeros(())),
                                        jnp.arange(n_mb))

    if hoist:
        # Backprop the accumulated grads through the (single) quantization.
        import numpy as onp

        def cot(p, g):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return onp.zeros(p.shape, jax.dtypes.float0)
            if g is None or getattr(g, "dtype", None) == jax.dtypes.float0:
                return jnp.zeros(p.shape, p.dtype)
            return g.astype(p.dtype)

        cotangents = jax.tree.map(cot, params_fwd, grads,
                                  is_leaf=lambda x: x is None)
        grads = preq_vjp(cotangents)[0]

    new_state = dict(state)
    if tcfg.grad_compress:
        qtree, new_err = grad_compress.compress_tree(grads, state["err"])
        grads = grad_compress.decompress_tree(qtree)
        new_state["err"] = new_err

    lr_scale = schedules.two_phase(
        state["step"], t1=tcfg.t1, warmup=tcfg.warmup, total=tcfg.t2,
        phase2_mult=tcfg.phase2_lr_mult)
    new_params, new_opt, om = adamw.apply_updates(
        params, grads, state["opt"], tcfg.adamw, lr_scale=lr_scale)

    if arch_cfg.quant.phase is Phase.NOISE:
        # Paper Alg. 1 line 7: project weights into +-(2 - sigma(s)).
        new_params = smol.project_noise_weights(new_params, arch_cfg.quant)

    new_state.update(params=new_params, opt=new_opt,
                     step=state["step"] + 1)
    metrics = {"loss": loss, "lr_scale": lr_scale, **om}
    return new_state, metrics
