"""Paper §V-D run-time analog at the kernel level: bytes moved and MXU
FLOPs per GEMM as a function of the precision pattern — the quantities the
TPU roofline converts into time. Uses the real packed layouts (and checks
the Pallas kernel agrees with its oracle on one spot shape).

``--backends`` times the packed-GEMM op on each kernel backend at the
spot shape — plus the full serve driver with the activation-quant fused
prologue on vs the two-pass reference form (the fused-vs-unfused delta) —
and appends the microseconds to ``BENCH_backend.json``; ``--autotune``
additionally runs the block-size autotuner for the Pallas backends at
that shape (persisting the winner in the on-disk autotune cache consulted
by every later dispatch).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import autotune, registry as backend_registry
from repro.core import pack
from repro.core.qtypes import QuantConfig
from repro.kernels import ref

try:                                   # package run (benchmarks.run / -m)
    from . import _common
except ImportError:                    # direct script run
    import _common

M, K, N = 64, 2048, 2048
SPOT_M, SPOT_K, SPOT_N = 8, 256, 128


def gemm_bytes(mix):
    qcfg = QuantConfig(mode="serve", mix=mix)
    k4, k2, k1 = qcfg.segments(K)
    w_bytes = k4 * N // 2 + k2 * N // 4 + k1 * N // 8
    scales = (K // 16) * 4
    act_bytes = M * K * 2          # bf16 activations in
    out_bytes = M * N * 4
    flops = 2 * M * K * N
    return {"w_bytes": w_bytes + scales, "act_bytes": act_bytes,
            "out_bytes": out_bytes, "flops": flops,
            "arith_intensity": flops / (w_bytes + scales + act_bytes
                                        + out_bytes)}


def run():
    rows = []
    bf16 = {"w_bytes": K * N * 2, "act_bytes": M * K * 2,
            "out_bytes": M * N * 4, "flops": 2 * M * K * N}
    bf16["arith_intensity"] = bf16["flops"] / (
        bf16["w_bytes"] + bf16["act_bytes"] + bf16["out_bytes"])
    rows.append(("bf16", bf16))
    for name, mix in [("u4", (1.0, 0, 0)), ("u2", (0, 1.0, 0)),
                      ("u1", (0, 0, 1.0)), ("p4_mix", (0.5, 0.375, 0.125))]:
        rows.append((name, gemm_bytes(mix)))
    base = rows[0][1]["w_bytes"]
    for name, r in rows:
        r["w_compression"] = base / r["w_bytes"]

    # spot-check kernel vs oracle at this shape (correctness anchor)
    x, wp = _spot_operands()
    got = backend_registry.resolve("pallas").packed_segment_matmul(
        x, wp, None, p=4)
    want = ref.packed_segment_matmul_ref(x, wp, None, 4)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append(("kernel_spot_check", {"max_err": err}))
    return rows


def _spot_operands():
    key = jax.random.PRNGKey(0)
    u = jax.random.randint(key, (SPOT_K, SPOT_N), 0, 16).astype(jnp.uint8)
    return jax.random.normal(key, (SPOT_M, SPOT_K)), pack.pack_codes(u, 4)


def _spot_leaf():
    """A mixed-precision packed serve leaf at the spot shape, for timing
    the full driver (perm + act quant + segment GEMMs)."""
    from repro.api import transforms
    from repro.core import smol
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.375, 0.125))
    params = smol.linear_init(jax.random.PRNGKey(0), SPOT_K, SPOT_N, qcfg)
    return transforms.pack_linear(params, qcfg), qcfg.mix


# Quantized-KV decode spot shape: one decode step over a well-filled ring
# (B slots, T ring entries, Hk kv-heads x G grouped queries, head_dim D).
QKV_B, QKV_T, QKV_HK, QKV_G, QKV_D = 8, 1024, 4, 2, 64


def _spot_qkv():
    from repro.serve import kv_quant
    key = jax.random.PRNGKey(2)
    cache = kv_quant.init_qkv_cache(QKV_B, QKV_T, QKV_HK, QKV_D)
    kv = jax.random.normal(key, (QKV_B, QKV_T, QKV_HK, QKV_D))
    pos = jnp.broadcast_to(jnp.arange(QKV_T, dtype=jnp.int32)[None],
                           (QKV_B, QKV_T))
    cache = kv_quant.update_qkv_cache(cache, kv, -kv, pos)
    q = jax.random.normal(jax.random.fold_in(key, 1),
                          (QKV_B, 1, QKV_HK, QKV_G, QKV_D))
    q_pos = jnp.full((QKV_B, 1), QKV_T - 1, jnp.int32)
    return cache, q, q_pos


def backend_sweep(backends, do_autotune: bool) -> dict:
    """Time the packed GEMM per backend at the spot shape; optionally run
    the block autotuner first (Pallas backends only — xla_ref has no block
    knobs)."""
    x, wp = _spot_operands()
    shape = (SPOT_M, SPOT_K, SPOT_N)
    out = {}
    for name in backends:
        b = backend_registry.resolve(name)

        def call(**blocks):
            return b.packed_segment_matmul(x, wp, None, p=4, **blocks)

        entry = {}
        if do_autotune and name.startswith("pallas"):
            entry["autotuned_blocks"] = autotune.autotune_op(
                call, "packed_segment_matmul", shape=shape, p=4,
                dtype=x.dtype, backend=b.name)
        entry["us"] = round(autotune.measure(call), 1)
        err = float(jnp.max(jnp.abs(
            call() - ref.packed_segment_matmul_ref(x, wp, None, 4))))
        entry["max_err_vs_oracle"] = err

        # Driver-level fused-vs-unfused activation-quant delta: the same
        # packed leaf through packed_matmul with the fused prologue
        # allowed vs pinned to the two-pass reference form. Only recorded
        # for backends that actually fuse (xla_ref would measure the same
        # path twice and record noise as a "delta").
        derived = f"max_err={err:.3g}"
        if b.supports("fused_act_segment_matmul"):
            sp, mix = _spot_leaf()
            xa = jax.random.normal(jax.random.PRNGKey(1), (SPOT_M, SPOT_K))
            q_fused = QuantConfig(mode="serve", mix=mix,
                                  act_scale_mode="per_token", backend=name)
            q_two = dataclasses.replace(q_fused, fuse_act_quant=False)
            f_fused = jax.jit(lambda v: b.packed_matmul(sp, v, q_fused))
            f_two = jax.jit(lambda v: b.packed_matmul(sp, v, q_two))
            entry["act_quant_fused_us"] = round(
                autotune.measure(lambda: f_fused(xa)), 1)
            entry["act_quant_two_pass_us"] = round(
                autotune.measure(lambda: f_two(xa)), 1)
            entry["act_quant_fused_speedup"] = round(
                entry["act_quant_two_pass_us"]
                / max(entry["act_quant_fused_us"], 1e-9), 3)
            derived += (f"|fused_vs_two_pass="
                        f"{entry['act_quant_fused_speedup']:.2f}x")

        # Quantized-KV flash-decode spot (DESIGN.md §12): one decode step
        # over a full ring — the fused kernel on Pallas, the dequantize-
        # everything oracle on xla_ref. Autotune covers its block_t knob.
        cache, qq, q_pos = _spot_qkv()
        qkv_shape = (QKV_B * QKV_HK * QKV_G, QKV_T, QKV_D)

        def qkv_call(**blocks):
            return b.qkv_attn_decode(qq, cache, q_pos, **blocks)

        if do_autotune and name.startswith("pallas"):
            entry["qkv_autotuned_blocks"] = autotune.autotune_op(
                qkv_call, "qkv_attn_decode", shape=qkv_shape, p=4,
                dtype=qq.dtype, backend=b.name)
        entry["qkv_attn_decode_us"] = round(autotune.measure(
            lambda: qkv_call()), 1)

        out[name] = entry
        _common.csv_row(f"runtime_proxy.backend.{name}", entry["us"],
                        derived)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", default=None,
                    help="comma-separated kernel backends to time at the "
                         "spot shape (default: all available; '' skips)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the block-size autotuner for the Pallas "
                         "backends (persists to the autotune cache)")
    args = ap.parse_args(argv)

    rows, us = _common.timed(run)
    for name, r in rows:
        _common.csv_row(
            f"runtime_proxy.{name}", us / len(rows),
            "|".join(f"{k}={v:.4g}" for k, v in r.items()))
    names = (backend_registry.available() if args.backends is None
             else [b for b in args.backends.split(",") if b])
    if names:
        sweep = backend_sweep(names, args.autotune)
        _common.record_backend_bench("runtime_proxy", {
            "shape": {"m": SPOT_M, "k": SPOT_K, "n": SPOT_N, "p": 4},
            "backends": sweep})
    return rows


if __name__ == "__main__":
    main()
