"""``soniq`` — the single public façade over the SONIQ lifecycle.

    from repro import soniq

    state = soniq.init(model_cfg, soniq.QuantConfig(mode="noise"), rng=key)
    logits = soniq.apply(state, tokens, rng=rng)       # Phase I forward
    qat, report = soniq.to_qat(state)                  # freeze precisions
    packed = soniq.to_serve(qat)                       # reorder + bit-pack
    y = soniq.apply(packed, tokens)                    # packed forward

Typed phases (``soniq.Phase.FP/NOISE/QAT/SERVE``) replace the old
string-mode branching; the lifecycle transforms are explicit, composable
pytree functions (see ``repro.api.transforms``); serving runs through
``soniq.DecodeEngine``. The quantized hot-path ops execute on a pluggable
kernel backend — ``soniq.QuantConfig(backend="pallas")``, the
``SONIQ_BACKEND`` env var, or a scoped ``soniq.use_backend("...")``
context (see ``repro.backend`` and DESIGN.md §11). DESIGN.md §9 has the
full API reference and the migration table from the legacy entry points.
"""
from repro.backend import (available as available_backends,    # noqa: F401
                           current_backend, use_backend)
from repro.core.noise import bit_penalty                       # noqa: F401
from repro.core.qtypes import (ALLOWED_BITS, BLOCK_SIZE,       # noqa: F401
                               GROUP_SIZE, GROUPS_PER_BLOCK, FP32, P4, P8,
                               P45, U2, U4, QuantConfig)
from repro.core.smol import bit_penalty_of_params              # noqa: F401

from .phases import Phase, PhaseSpec                           # noqa: F401
from .state import LinearSpec, SoniqState                      # noqa: F401
from . import transforms                                       # noqa: F401
from .transforms import (apply, average_bpp, convert_linear,   # noqa: F401
                         convert_tree, freeze_qat, init, init_linear,
                         pack_conv, pack_linear, rebudget_pbits, to_qat,
                         to_serve, tree_map_layers, with_phase)

__all__ = [
    # configs & phases
    "ALLOWED_BITS", "BLOCK_SIZE", "GROUP_SIZE", "GROUPS_PER_BLOCK",
    "FP32", "P4", "P8", "P45", "U2", "U4", "QuantConfig",
    "Phase", "PhaseSpec", "LinearSpec", "SoniqState", "with_phase",
    # lifecycle
    "init", "init_linear", "apply", "to_qat", "to_serve",
    # pytree building blocks
    "freeze_qat", "rebudget_pbits", "pack_linear", "pack_conv",
    "convert_linear", "convert_tree", "tree_map_layers",
    # losses / reports
    "bit_penalty", "bit_penalty_of_params", "average_bpp",
    # kernel backends
    "use_backend", "current_backend", "available_backends",
    # serving (lazy — see __getattr__)
    "DecodeEngine", "LockstepEngine", "EngineConfig", "Request",
    "Completion", "Scheduler", "packed_bytes", "transforms",
]

_SERVE_EXPORTS = {"DecodeEngine": "DecodeEngine",
                  "LockstepEngine": "LockstepEngine",
                  "EngineConfig": "EngineConfig",
                  "Request": "Request",
                  "Completion": "Completion",
                  "Scheduler": "Scheduler",
                  "packed_bytes": "packed_model_bytes"}


def __getattr__(name):
    # The decode engine imports this package for the lifecycle transforms;
    # re-export it lazily to keep the dependency one-way at import time.
    if name in _SERVE_EXPORTS:
        from repro.serve import engine, scheduler
        mod = scheduler if hasattr(scheduler, _SERVE_EXPORTS[name]) \
            else engine
        return getattr(mod, _SERVE_EXPORTS[name])
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")
