"""Paper Table I: SMOL variants under system-aware constraints.

  row 1: original SMOL — per-channel precisions, any of 1..8 bits,
         quantized weights only.
  row 2: {1,2,4} bits + input-weight consistency (system-aware, Alg. 2).

Claim reproduced: the constrained variant loses only a small amount of
accuracy at essentially the same bits-per-parameter.
"""
from __future__ import annotations

import dataclasses

from repro.core.qtypes import QuantConfig
from . import _common


def run(steps=None):
    t = steps or _common.BENCH_STEPS
    t1, t2 = t, 2 * t
    # Original: weights only, free precisions, finest grouping.
    orig = _common.train_cnn(
        QuantConfig(mode="qat", quantize_activations=False, num_patterns=45, lam=2e-2),
        t1=t1, t2=t2, group_size=4, original_freeze=True)
    # System-aware: {1,2,4} + input-weight consistency (act quant on).
    sa = _common.train_cnn(
        QuantConfig(mode="qat", quantize_activations=True, num_patterns=45, lam=2e-2),
        t1=t1, t2=t2)
    rows = [("original_weights_only", orig), ("sysaware_124_iwc", sa)]
    return rows


def main(steps=None):
    rows, us = _common.timed(run, steps)
    for name, r in rows:
        _common.csv_row(f"table1.{name}", us / len(rows),
                        f"accuracy={r['accuracy']:.4f}|bpp={r['bpp']:.3f}")
    return rows


if __name__ == "__main__":
    main()
