"""The kernel-backend protocol (DESIGN.md §11).

A :class:`Backend` owns the implementations of the eight SONIQ hot-path
ops — the operations every lifecycle phase's forward rule is built from:

    packed_segment_matmul   x @ unpack_dequant(wp) for one uniform-p segment
    fused_act_segment_matmul  the same GEMM with the activation fake-quant
                            fused into its prologue (serve fast path)
    packed_matmul           full mixed [K4|K2|K1] serve-mode linear
    quantize_pack           SMOL quantize + bit-pack one uniform-p weight
    noise_inject            Phase-I fused perturbation  clip(w + σ(s)·ε)
    fake_quant              straight-through quantize-dequantize (QAT)
    qkv_attn_decode         decode attention over the packed 4-bit ring-KV
                            cache (serve fast path, DESIGN.md §12)
    qkv_attn_decode_paged   the same attention over the paged block-pool
                            cache (page-table walk, DESIGN.md §13)

Backends register with :mod:`repro.backend.registry`; the phase rules in
``repro.core.smol`` resolve one at trace time (``QuantConfig.backend`` /
``SONIQ_BACKEND`` / ``soniq.use_backend``) and never touch a kernel module
directly — the dependency points from backend implementations *down* into
``repro.kernels``/``repro.core``, not from core up into kernels.

Two template methods keep cross-backend numerics aligned:

* :meth:`Backend.packed_matmul` — the shared mixed-precision driver:
  channel permutation, activation scaling per ``QuantConfig.act_scale_mode``
  (per_token / per_tensor / none), one ``fake_quant`` over the full K, then
  one ``packed_segment_matmul`` per non-empty segment
  (``core.pack.iter_packed_segments``) accumulated in fp32. Backends only
  override the per-segment GEMM, so greedy decode is token-identical
  across backends at fp32 (pinned by ``tests/test_backend_dispatch.py``).
* :meth:`Backend.noise_inject` — wraps the backend's forward kernel in a
  shared ``custom_vjp``: ε is a counter-based hash of (element index, seed)
  (``kernels.prng``), so the backward pass recomputes it in jnp and every
  backend gets exact Phase-I gradients even when its forward is a
  non-differentiable Pallas call.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as pack_lib
from repro.core import quant
from repro.core.qtypes import GROUP_SIZE

# The op vocabulary of the protocol (capability negotiation keys).
OPS: Tuple[str, ...] = ("packed_matmul", "packed_segment_matmul",
                        "fused_act_segment_matmul", "quantize_pack",
                        "noise_inject", "fake_quant", "qkv_attn_decode",
                        "qkv_attn_decode_paged")

# Name-stack tag the shared driver wraps every per-segment GEMM call in
# (``jax.named_scope`` — free at run time, visible in each traced eqn's
# source_info). ``repro.analysis.jaxpr_checks`` walks serve-step jaxprs
# and holds everything under this scope to the quantized-GEMM dtype
# contract: fp32 accumulation, no narrowing float converts, no f64.
SEGMENT_GEMM_SCOPE = "soniq_segment_gemm"

# Where each op's backend-specific implementation actually lives (defaults
# to the op name itself): noise_inject's and fake_quant's public entry
# points are the shared custom-VJP wrappers, so their capability hooks are
# the forward methods.
_OP_IMPL_HOOK = {"noise_inject": "_noise_inject_fwd",
                 "fake_quant": "_fake_quant_fwd"}

# Trace-time dispatch counter for the draft (low-slice) serve forward:
# incremented by the shared ``packed_matmul`` driver whenever
# ``qcfg.draft_slice_bits`` filtered the segment list — CI's speculative
# leg asserts the draft path actually engaged (mirrors the kernel
# counters in ``repro.backend.pallas``, but lives here because the slice
# happens in the driver, identically on every backend).
_DRAFT_MATMUL_CALLS = 0


def draft_matmul_call_count() -> int:
    """How many packed matmuls were traced in draft (low-slice) mode —
    the high-bit carriers skipped per ``QuantConfig.draft_slice_bits``
    (DESIGN.md §14). Counted at trace time, not per executed step."""
    return _DRAFT_MATMUL_CALLS


class BackendUnavailable(RuntimeError):
    """An explicitly selected backend cannot run here (wrong platform,
    missing toolchain). Explicit selection never falls back silently —
    callers that want negotiation pass no name at all."""


# Floor on the dynamic abs-max before it becomes a divisor. A padding /
# freshly-reset batch row is exactly zero, and 0-abs-max would make both
# the shared driver's fake_quant and the fused kernel prologue divide by
# zero (NaN/Inf logits for *every* row once they mix in the matmul).
# tests/test_backend_dispatch.py pins the zero-row regression. The value
# itself lives in ``core.quant`` (the bottom layer — kernels and the serve
# KV quantizer share it without importing this module); this re-export is
# the documented operational name.
ACT_SCALE_EPS = quant.ACT_SCALE_EPS


def act_scale(x, act_scale_mode: str, eps: float = ACT_SCALE_EPS):
    """Dynamic activation scale per the config policy. ``per_token``
    reduces over the last dim only (row-independent — what continuous
    batching requires); ``per_tensor`` over the whole tensor; ``none`` is
    the paper-faithful pre-scaled setting. The abs-max is clamped to
    ``eps`` so all-zero rows yield a tiny finite scale, never a 0
    divisor."""
    if act_scale_mode == "none":
        return jnp.asarray(1.0, jnp.float32)
    if act_scale_mode == "per_token":
        return quant.abs_max_scale(x, axis=-1, eps=eps).astype(jnp.float32)
    return quant.abs_max_scale(x, eps=eps).astype(jnp.float32)


def hash_eps(shape: Tuple[int, ...], seed):
    """The shared Phase-I noise draw: ε ~ U(-1, 1) from the counter-based
    hash of the global element index — identical in every backend (and on
    TPU vs interpret), which is what makes noise_inject backend-exact."""
    from repro.kernels import prng
    k, n = shape
    idx = (jnp.arange(k, dtype=jnp.uint32)[:, None] * jnp.uint32(n)
           + jnp.arange(n, dtype=jnp.uint32)[None, :])
    return prng.uniform_pm1(idx, seed)


# --------------------------------------------------------------------------
# noise_inject: shared custom_vjp over the backend-specific forward.
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 4, 5))
def _noise_inject(backend, w, s, seed, group_size, blocks):
    return backend._noise_inject_fwd(w, s, seed, group_size, dict(blocks))


def _noise_inject_fwd(backend, w, s, seed, group_size, blocks):
    out = backend._noise_inject_fwd(w, s, seed, group_size, dict(blocks))
    return out, (w, s, seed)


def _noise_inject_bwd(backend, group_size, blocks, res, g):
    w, s, seed = res
    k = w.shape[0]
    sig_g = jax.nn.sigmoid(jnp.asarray(s, jnp.float32))
    sig = jnp.repeat(sig_g, group_size, total_repeat_length=k)[:, None]
    eps = hash_eps(w.shape, seed)
    z = jnp.asarray(w, jnp.float32) + sig * eps
    lim = 2.0 - sig
    inside = jnp.abs(z) <= lim
    g32 = jnp.asarray(g, jnp.float32)
    dw = jnp.where(inside, g32, 0.0).astype(w.dtype)
    # ∂out/∂σ: ε inside the clip; at the clamp the limit ±(2-σ) itself
    # moves with σ, d(±(2-σ))/dσ = ∓1. Chain through σ'(s) = σ(1-σ) and
    # sum each group's K×N block.
    dsig_elem = jnp.where(inside, eps, -jnp.sign(z))
    per_k = jnp.sum(g32 * dsig_elem, axis=tuple(range(1, w.ndim)))
    per_group = per_k.reshape(sig_g.shape[0], group_size).sum(axis=1)
    ds = (per_group * sig_g * (1.0 - sig_g)).astype(
        jnp.asarray(s).dtype)
    dseed = np.zeros(np.shape(seed), dtype=jax.dtypes.float0)
    return dw, ds, dseed


_noise_inject.defvjp(_noise_inject_fwd, _noise_inject_bwd)


# --------------------------------------------------------------------------
# fake_quant: shared clipped-STE custom_vjp over the backend forward.
# --------------------------------------------------------------------------
# Same pattern as noise_inject: the public op is one custom_vjp whose
# forward is the backend hook (a fused Pallas kernel on the Pallas
# backends, the jnp reference elsewhere) and whose backward recomputes the
# in-range mask in jnp — so QAT differentiates through every backend with
# gradients identical to core.quant.fake_quant's STE.

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 4))
def _fake_quant(backend, x, pbits, scale, group_size):
    return backend._fake_quant_fwd(x, pbits, scale, group_size)


def _fake_quant_fwd(backend, x, pbits, scale, group_size):
    out = backend._fake_quant_fwd(x, pbits, scale, group_size)
    return out, (x, pbits, scale)


def _fake_quant_bwd(backend, group_size, res, g):
    x, pbits, scale = res
    _, in_range = quant._fake_quant_fwd_impl(x, pbits, scale, group_size)
    return g * in_range, jnp.zeros_like(pbits), jnp.zeros_like(scale)


_fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


# Score mask fill for decode attention — matches models.attention.NEG_INF
# so the oracle and the fp cache path produce identical masked softmaxes.
_ATTN_NEG_INF = -1e30


def qkv_attn_jnp(q, k, v, k_pos, q_pos, window: Optional[int] = None):
    """Masked GQA decode attention in fp32 — the element-exact reference
    the fused quantized-KV flash-decode kernel is gated against.

    q [B,S,Hk,G,D] (RoPE applied), k/v [B,T,Hk,D] (dequantized), k_pos
    [B,T] ring positions (< 0 = empty/evicted entry), q_pos [B,S] (< 0 =
    masked lane). Causal-by-position mask, optional sliding window; scores,
    softmax and the value contraction all run in fp32. Returns
    [B,S,Hk,G,D] fp32.
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", jnp.asarray(q, jnp.float32),
                        jnp.asarray(k, jnp.float32),
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / np.sqrt(dh))
    m = (q_pos[:, :, None] >= k_pos[:, None, :]) \
        & (k_pos[:, None, :] >= 0)                        # [B, S, T]
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    scores = jnp.where(m[:, None, None], scores, _ATTN_NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p,
                      jnp.asarray(v, jnp.float32),
                      preferred_element_type=jnp.float32)


def noise_inject_jnp(w, s, seed, group_size: int = GROUP_SIZE):
    """Reference forward (pure jnp, counter-hash ε): clip(w + σ(s)·ε,
    ±(2-σ)). Matches ``kernels.ref.noise_inject_ref`` bit-for-bit."""
    w32 = jnp.asarray(w, jnp.float32)
    k = w.shape[0]
    sig = jnp.repeat(jax.nn.sigmoid(jnp.asarray(s, jnp.float32)),
                     group_size, total_repeat_length=k)[:, None]
    eps = hash_eps(w.shape, seed)
    out = w32 + sig * eps
    return jnp.clip(out, -(2.0 - sig), 2.0 - sig).astype(w.dtype)


class Backend:
    """Base class / protocol for kernel backends.

    Subclasses set ``name``/``priority``, implement the per-segment ops
    they accelerate, and inherit the shared drivers. ``priority`` orders
    auto-negotiation (highest available wins); ``is_available`` gates it.
    """

    name: str = "abstract"
    priority: int = 0

    # ---------------------------------------------------- availability ----
    def is_available(self) -> bool:
        return True

    def why_unavailable(self) -> str:
        return "available"

    def supports(self, op: str) -> bool:
        """Capability probe: does this backend carry its own implementation
        of ``op``, vs inheriting the shared/reference one? (Ops route
        through template hooks where the shared wrapper must stay — e.g.
        noise_inject's custom VJP — so the probe checks the hook.)"""
        assert op in OPS, op
        attr = _OP_IMPL_HOOK.get(op, op)
        return getattr(type(self), attr, None) is not getattr(
            Backend, attr, None)

    # ------------------------------------------------------ primitive ops --
    def packed_segment_matmul(self, x, wp, scales=None, *, p: int,
                              act_quant: bool = False,
                              group_size: int = GROUP_SIZE, **blocks):
        """x [M, Kp] @ unpack_dequant(wp [Kp*p//8, N]) -> [M, N] f32.
        ``scales``: per-group [Kp//group_size] f32 or None. ``act_quant``
        snaps x (already in scale units) to the p-bit grid first."""
        raise NotImplementedError(self.name)

    def quantize_pack(self, w, scales=None, *, p: int,
                      group_size: int = GROUP_SIZE, **blocks):
        """w [K, N] f32 -> packed uint8 [K*p//8, N] SMOL codes."""
        raise NotImplementedError(self.name)

    def fake_quant(self, x, pbits, scale, group_size: int = GROUP_SIZE):
        """Clipped-STE quantize-dequantize along the last dim with
        per-group precisions. Differentiable in ``x`` on every backend via
        the shared custom VJP (the STE backward recomputes the in-range
        mask in jnp); backends accelerate the forward by overriding
        ``_fake_quant_fwd``, never this entry point."""
        return _fake_quant(self, x, jnp.asarray(pbits, jnp.float32),
                           jnp.asarray(scale, jnp.float32), group_size)

    def _fake_quant_fwd(self, x, pbits, scale, group_size: int):
        """Forward-only quantize-dequantize (wrapped by the custom VJP)."""
        return quant._fake_quant_fwd_impl(x, pbits, scale, group_size)[0]

    def fused_act_segment_matmul(self, x, wp, scales=None, act_scales=None,
                                 *, p: int, group_size: int = GROUP_SIZE,
                                 in_kernel_scale: bool = False, **blocks):
        """``packed_segment_matmul`` with the activation quantization fused
        into its prologue: quantize-dequantize x at the segment's uniform
        ``p`` with per-token scales ``act_scales`` [M, 1] (None = the
        paper-faithful unscaled grid), then the segment GEMM.

        ``in_kernel_scale``: the segment spans the full K row (single-
        segment layer) and the caller asks the kernel to compute the
        per-token abs-max scale itself instead of receiving ``act_scales``
        — the last jnp pass over the activations disappears on backends
        with a self-scale kernel. Only legal with ``act_scales=None`` for
        a whole-row segment under ``per_token`` scaling; the driver gates
        it.

        The base implementation is the two-pass reference composition —
        bit-exact with a fused kernel by construction, since fusion only
        removes the HBM round-trip of the quantized activations (and, for
        the self-scale form, of the [M, 1] reduction), not any arithmetic.
        Backends that carry a real fused kernel override this; the shared
        ``packed_matmul`` driver only takes the fused path when they do
        (``supports("fused_act_segment_matmul")``)."""
        if in_kernel_scale:
            assert act_scales is None, "in_kernel_scale computes the scale"
            act_scales = act_scale(x, "per_token")
        kp = x.shape[-1]
        pb = jnp.full((max(kp // group_size, 1),), float(p), jnp.float32)
        s = jnp.asarray(1.0 if act_scales is None else act_scales,
                        jnp.float32)
        xq = quant.fake_quant(x, pb, s, group_size)
        return self.packed_segment_matmul(xq, wp, scales, p=p,
                                          act_quant=False,
                                          group_size=group_size, **blocks)

    def qkv_attn_decode(self, q, cache: Dict, q_pos, *,
                        window: Optional[int] = None, **blocks):
        """Decode attention over one layer's packed 4-bit ring-KV cache
        (DESIGN.md §12). q [B,S,Hk,G,D] with RoPE applied; ``cache`` is a
        quantized ring dict (``k_codes``/``v_codes`` [B,T,Hk,D//2] uint8,
        ``k_scale``/``v_scale`` [B,T,Hk,1] f16, ``pos`` [B,T]); ``q_pos``
        [B,S] absolute positions (< 0 = masked lane). Returns [B,S,Hk,G,D]
        fp32.

        The base implementation is the jnp oracle — dequantize the whole
        cache (``kv_quant.read_qkv_cache``) then masked SDPA — which is
        what ``xla_ref`` runs. Backends carrying a fused kernel that
        unpacks the 2-per-byte codes and applies the per-(slot, head)
        scales inside the attention inner loop (no materialized
        [B,T,Hk,D] dequant buffer) override this; their numerics must stay
        within the pinned KV parity bound of the oracle
        (tests/test_qkv_decode.py)."""
        del blocks                     # block shapes are a kernel concern
        from repro.serve import kv_quant   # lazy: serve imports backend
        k, v, k_pos = kv_quant.read_qkv_cache(cache, jnp.float32)
        return qkv_attn_jnp(q, k, v, k_pos, q_pos, window)

    def qkv_attn_decode_paged(self, q, cache: Dict, q_pos, *,
                              window: Optional[int] = None, **blocks):
        """Decode attention over one layer's *paged* KV cache (DESIGN.md
        §13). Same contract as :meth:`qkv_attn_decode` except the cache is
        a ``serve/kv_pool.py`` paged dict: pool-shaped payload leaves
        (q4 codes/scales or fp k/v, ``[P, page_size, Hk, ...]``), pool
        ``pos [P, page_size]`` stamps and per-slot ``page_table [B, NP]``
        (-1 / null page 0 = unmapped hole). Returns [B,S,Hk,G,D] fp32.

        The base implementation is the gather oracle — ``jnp.take`` each
        slot's pages into a dense [B, NP*page_size, ...] ring view, then
        the same masked SDPA — which is what ``xla_ref`` runs. Backends
        with a real paged kernel walk the page table tile-by-tile with an
        online softmax instead (no dense gather, no [SG, T] score row);
        parity is the same token-identical-greedy bound as the ring op."""
        del blocks
        from repro.serve import kv_pool    # lazy: serve imports backend
        k, v, k_pos = kv_pool.gather_paged(cache, jnp.float32)
        return qkv_attn_jnp(q, k, v, k_pos, q_pos, window)

    def noise_inject(self, w, s, seed, *, group_size: int = GROUP_SIZE,
                     **blocks):
        """Phase-I fused perturbation, differentiable in (w, s) via the
        shared custom VJP (ε recomputed from the hash in the backward)."""
        return _noise_inject(self, w, s, jnp.asarray(seed, jnp.uint32),
                             group_size, tuple(sorted(blocks.items())))

    def _noise_inject_fwd(self, w, s, seed, group_size: int, blocks: Dict):
        """Forward-only noise kernel (wrapped by the custom VJP)."""
        return noise_inject_jnp(w, s, seed, group_size)

    # ------------------------------------------------- shared drivers ------
    def packed_matmul(self, serve_params: Dict, x, qcfg, **blocks):
        """Full serve-mode SmolLinear over a packed leaf: channel perm,
        activation quantization per ``qcfg.act_scale_mode``, one
        per-segment GEMM per non-empty [K4|K2|K1] segment, fp32
        accumulation, bias, cast back to x.dtype.

        The driver is shared so every backend applies *identical*
        activation scaling (the whole-batch-abs-max magnitude leak the
        old kernel wrapper had cannot reappear per-backend) and identical
        segment/accumulation order. Activation quantization has two
        bit-exact forms (DESIGN.md §11 "Fused activation quantization"):
        the two-pass reference (one whole-K ``fake_quant``, then plain
        segment GEMMs — what ``xla_ref`` always runs) and the fused form
        (the epsilon-clamped per-token scale is still computed here, since
        it spans the full permuted row across segment boundaries, but the
        snap-to-grid moves into the segment kernel's prologue) taken when
        the backend carries ``fused_act_segment_matmul`` and
        ``qcfg.fuse_act_quant`` allows it.

        Draft mode (``qcfg.draft_slice_bits`` — DESIGN.md §14): segments
        whose precision exceeds the bound are skipped, so the GEMM reads
        only the low-bit carriers of the SAME packed buffers — the
        embedded draft model of self-speculative decoding. Nothing is
        renormalized and the activation path is untouched (the per-token
        scale spans the full permuted row either way); a layer holding
        only high-bit segments (e.g. the narrow all-4-bit single-group
        layers) keeps its full mix — it has no cheap slice, and a
        bias-only output would wreck the draft signal downstream of it.
        Skipping happens here, before the in-kernel-scale gate, so a
        filtered single segment that no longer spans K cannot take the
        self-scale path.
        """
        bufs = {name: serve_params[name] for name, _p, _v in
                pack_lib.SEGMENTS}
        k = sum(serve_params[name].shape[0] * v
                for name, _p, v in pack_lib.SEGMENTS)
        g = qcfg.eff_group_size(k)
        segs = list(pack_lib.iter_packed_segments(bufs, g))
        draft_bits = getattr(qcfg, "draft_slice_bits", None)
        if draft_bits is not None:
            global _DRAFT_MATMUL_CALLS
            _DRAFT_MATMUL_CALLS += 1
            low = [s for s in segs if s[1] <= draft_bits]
            if low:
                segs = low
        x = jnp.take(x, serve_params["perm"], axis=-1)
        fused = False
        self_scale = False
        sx = None
        if qcfg.quantize_activations:
            fused = (getattr(qcfg, "fuse_act_quant", True)
                     and self.supports("fused_act_segment_matmul"))
            # Uniform-precision layer (one segment spans the whole K row)
            # under per-token scaling: the [M, K] -> [M, 1] abs-max moves
            # into the fused kernel's prologue too (it no longer crosses a
            # segment boundary). The abs-max is permutation-invariant, so
            # in-kernel reduction over the permuted row is bit-identical
            # to the driver-side scale (DESIGN.md §11).
            self_scale = (fused and qcfg.act_scale_mode == "per_token"
                          and len(segs) == 1 and segs[0][3] == k)
            if not self_scale:
                sx = act_scale(x, qcfg.act_scale_mode)
            if not fused:
                pbits = serve_params.get("pbits_sorted")
                if pbits is None:
                    # Legacy packed dicts may omit the metadata leaf; the
                    # sorted per-group precisions are fully determined by
                    # the carrier shapes.
                    pbits = jnp.asarray(np.concatenate(
                        [np.full(ng, p, np.float32)
                         for _n, p, _o, _kp, _go, ng
                         in pack_lib.iter_packed_segments(bufs, g)]))
                x = self.fake_quant(x, pbits.astype(jnp.float32), sx, g)
        lead = x.shape[:-1]
        x2 = x.reshape(-1, k)
        if fused and not self_scale:
            # One [M, 1] per-token scale operand for every segment kernel
            # (per_tensor / "none" broadcast the same value to each row —
            # bit-identical to the two-pass division by a scalar).
            sx2 = jnp.broadcast_to(
                jnp.asarray(sx, jnp.float32).reshape(-1, 1),
                (x2.shape[0], 1))
        wscale = serve_params.get("wscale")
        n = max(serve_params[name].shape[1]
                for name, _p, _v in pack_lib.SEGMENTS)
        y = jnp.zeros((x2.shape[0], n), jnp.float32)
        for name, p, off, kp, goff, ng in segs:
            seg_scales = None if wscale is None else \
                jax.lax.dynamic_slice_in_dim(wscale, goff, ng)
            # Tag the per-segment GEMM subtree for the static analyzer
            # (repro.analysis.jaxpr_checks): everything traced inside this
            # scope must keep the quantized arithmetic exact — fp32
            # accumulate, no narrowing float converts, no f64.
            with jax.named_scope(SEGMENT_GEMM_SCOPE):
                if self_scale:
                    y = y + self.fused_act_segment_matmul(
                        x2[:, off:off + kp], serve_params[name], seg_scales,
                        None, p=p, group_size=g, in_kernel_scale=True,
                        **blocks)
                elif fused:
                    y = y + self.fused_act_segment_matmul(
                        x2[:, off:off + kp], serve_params[name], seg_scales,
                        sx2, p=p, group_size=g, **blocks)
                else:
                    y = y + self.packed_segment_matmul(
                        x2[:, off:off + kp], serve_params[name], seg_scales,
                        p=p, act_quant=False, group_size=g, **blocks)
        b = serve_params.get("b")
        if b is not None:
            y = y + b.astype(y.dtype)
        return y.reshape(lead + (n,)).astype(x.dtype)

    def quantize_pack_mixed(self, w, pbits, scales=None,
                            group_size: int = GROUP_SIZE) -> Dict:
        """Mixed-precision deploy packing: quantize + bit-pack each
        uniform-precision segment of a [K, N] weight whose sorted
        per-group ``pbits`` define the [K4|K2|K1] split. Same contract as
        ``core.pack.quantize_pack_weight`` (which remains the pure-jnp
        reference); the per-segment packing runs through this backend's
        ``quantize_pack`` op."""
        w = jnp.asarray(w, jnp.float32)
        k, n = w.shape
        pbits = np.asarray(pbits)
        assert pbits.ndim == 1 and pbits.shape[0] * group_size == k, \
            (pbits.shape, k, group_size)
        order = {4: 0, 2: 1, 1: 2}
        ranks = np.array([order[int(p)] for p in pbits])
        assert np.all(np.diff(ranks) >= 0), "pbits must be sorted 4 -> 2 -> 1"
        segs = tuple(int((pbits == p).sum()) * group_size for p in (4, 2, 1))
        if scales is not None:
            scales = jnp.asarray(scales, jnp.float32)
        out = {"segments": segs, "scales": scales, "n": n,
               "group_size": group_size}
        off = goff = 0
        for (name, p, _vpb), kp in zip(pack_lib.SEGMENTS, segs):
            if kp == 0:
                out[name] = jnp.zeros((0, n), jnp.uint8)
                continue
            ng = max(kp // group_size, 1)
            seg_scales = None if scales is None else scales[goff:goff + ng]
            out[name] = self.quantize_pack(w[off:off + kp], seg_scales,
                                           p=p, group_size=group_size)
            off += kp
            goff += ng
        return out

    def __repr__(self) -> str:
        return f"<Backend {self.name}>"
