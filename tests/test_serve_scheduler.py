"""Continuous-batching serve engine: parity, reproducibility, scheduler
invariants, and the strict packed-size metric (DESIGN.md §10)."""
import numpy as np
import pytest

import jax

from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine
from repro.serve.scheduler import Request, Scheduler


def _tiny(**kw):
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode="qat"), **kw)


@pytest.fixture(scope="module")
def served():
    cfg = _tiny()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _mixed_requests(rng, lens=(3, 7, 5, 2, 9), news=(4, 8, 3, 6, 5),
                    seed_offset=0, **kw):
    return [Request(prompt=rng.integers(1, 100, (l,)), max_new_tokens=n,
                    seed=seed_offset + i, **kw)
            for i, (l, n) in enumerate(zip(lens, news))]


# ------------------------------------------------------------- parity ----
def test_temp0_parity_with_lockstep(served):
    """The tentpole contract: at temperature 0 the continuous-batching
    engine emits exactly the lockstep engine's tokens for every request of
    a mixed-length set — slot rows are independent, so batch composition,
    slot reuse and chunked prefill must not leak into the stream."""
    cfg, params = served
    ecfg = engine.EngineConfig(max_batch=3, cache_len=64, prefill_chunk=4)
    lock = engine.LockstepEngine(params, cfg, ecfg)
    cont = engine.DecodeEngine(params, cfg, ecfg)
    reqs = _mixed_requests(np.random.default_rng(0))
    ref = {i: lock.generate(r.prompt[None], r.max_new_tokens)[0]
           for i, r in enumerate(reqs)}
    got = {c.request_id: c.tokens for c in cont.serve(reqs)}
    assert set(got) == set(range(len(reqs)))
    for i in range(len(reqs)):
        np.testing.assert_array_equal(ref[i], got[i])


def test_temp0_parity_batched_lockstep(served):
    """Same-length requests run as one lockstep batch match too (the
    per-token act scale keeps rows independent in BOTH engines)."""
    cfg, params = served
    ecfg = engine.EngineConfig(max_batch=2, cache_len=64)
    lock = engine.LockstepEngine(params, cfg, ecfg)
    cont = engine.DecodeEngine(params, cfg, ecfg)
    rng = np.random.default_rng(1)
    prompts = rng.integers(1, 100, (4, 6)).astype(np.int32)
    ref = lock.generate(prompts, 7)
    reqs = [Request(prompt=p, max_new_tokens=7, seed=i)
            for i, p in enumerate(prompts)]
    got = {c.request_id: c.tokens for c in cont.serve(reqs)}
    base = min(got)
    for i in range(4):
        np.testing.assert_array_equal(ref[i], got[base + i])


def test_parity_without_chunked_prefill(served):
    """prefill_chunk=1 (the SSM/hybrid fallback path) is parity too."""
    cfg, params = served
    lock = engine.LockstepEngine(params, cfg,
                                 engine.EngineConfig(cache_len=64))
    cont = engine.DecodeEngine(
        params, cfg,
        engine.EngineConfig(max_batch=2, cache_len=64, prefill_chunk=1))
    reqs = _mixed_requests(np.random.default_rng(2), lens=(4, 6, 3),
                           news=(5, 3, 6))
    ref = {i: lock.generate(r.prompt[None], r.max_new_tokens)[0]
           for i, r in enumerate(reqs)}
    got = {c.request_id: c.tokens for c in cont.serve(reqs)}
    for i in range(len(reqs)):
        np.testing.assert_array_equal(ref[i], got[i])


def test_scheduling_invariance_of_streams(served):
    """A request's tokens must not depend on max_batch / co-scheduled
    traffic: run the same request set at max_batch 1 and 4."""
    cfg, params = served
    outs = []
    for mb in (1, 4):
        eng = engine.DecodeEngine(
            params, cfg, engine.EngineConfig(max_batch=mb, cache_len=64,
                                             prefill_chunk=4))
        got = {c.request_id: c.tokens for c in
               eng.serve(_mixed_requests(np.random.default_rng(3)))}
        outs.append({k - min(got): v for k, v in got.items()})
    assert set(outs[0]) == set(outs[1])
    for k in outs[0]:
        np.testing.assert_array_equal(outs[0][k], outs[1][k])


def test_temperature_sampling_reproducible(served):
    """temperature > 0: per-request seeded rng makes streams reproducible
    run-to-run (and across engine resets)."""
    cfg, params = served
    eng = engine.DecodeEngine(
        params, cfg, engine.EngineConfig(max_batch=3, cache_len=64,
                                         prefill_chunk=4))

    def run():
        eng.reset()
        got = {c.request_id: c.tokens for c in eng.serve(
            _mixed_requests(np.random.default_rng(4), temperature=0.8))}
        return {k - min(got): v for k, v in got.items()}

    a, b = run(), run()
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # different seeds diverge (sanity that sampling is actually live)
    eng.reset()
    other = {c.request_id: c.tokens for c in eng.serve(
        _mixed_requests(np.random.default_rng(4), temperature=0.8,
                        seed_offset=100))}
    other = {k - min(other): v for k, v in other.items()}
    assert any(not np.array_equal(a[k], other[k]) for k in a)


def test_eos_finishes_early(served):
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg,
                              engine.EngineConfig(max_batch=2, cache_len=64))
    # discover the greedy continuation, then use its first token as eos
    probe = list(eng.serve([Request(prompt=np.asarray([5, 6, 7]),
                                    max_new_tokens=4, seed=0)]))[0]
    eos = int(probe.new_tokens[0])
    eng.reset()
    out = list(eng.serve([Request(prompt=np.asarray([5, 6, 7]),
                                  max_new_tokens=4, seed=0, eos_id=eos)]))[0]
    assert out.finish_reason == "eos"
    assert out.new_tokens.tolist() == [eos]


# ---------------------------------------------------------- scheduler ----
def test_scheduler_admission_order_and_arrival():
    s = Scheduler(max_batch=2)
    r = [Request(prompt=np.asarray([1]), max_new_tokens=1, arrival_step=a)
         for a in (0, 0, 0, 5)]
    for x in r:
        s.submit(x)
    first = s.admit()
    assert [req.request_id for _, req in first] == [0, 1]   # FIFO
    assert s.pending == 2 and s.num_active == 2
    assert s.admit() == []                                  # no free slots
    # finish slot 0's request -> slot frees, next queued request admitted,
    # but the arrival_step=5 request stays queued until step 5
    s.slots[first[0][0]].n_fed = 1
    done = s.advance({first[0][0]: 0}, {first[0][0]: 42})
    assert len(done) == 1 and done[0].new_tokens.tolist() == [42]
    nxt = s.admit()
    assert [req.request_id for _, req in nxt] == [2]
    # drain the two active single-token requests to free both slots
    for slot in list(s.slots):
        s.advance({slot: 1}, {slot: 7})
    assert s.free_slots and s.num_active == 0
    # ...but the arrival_step=5 request still waits for its arrival step
    while s.step_count < 5:
        assert s.admit() == []
        s.advance({}, {})
    assert [req.request_id for _, req in s.admit()] == [3]


def test_scheduler_slot_reuse_and_free_list():
    s = Scheduler(max_batch=1)
    for i in range(3):
        s.submit(Request(prompt=np.asarray([1, 2]), max_new_tokens=1))
    served_slots = []
    while s.has_work():
        for slot, _ in s.admit():
            served_slots.append(slot)
        fed = {slot: 1 for slot in s.slots}
        s.advance(fed, {slot: 9 for slot in fed})
    assert served_slots == [0, 0, 0]    # single slot recycled in order
    assert s.free_slots == [0] and not s.has_work()


def test_submit_rejects_empty_prompt():
    """Satellite fix: an empty prompt can never prefill, so it must be
    rejected at submit() instead of entering the state machine and hanging
    the engine forever."""
    sched = Scheduler(2)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(prompt=np.zeros((0,), np.int32),
                             max_new_tokens=4))
    assert not sched.has_work() and sched.pending == 0


def test_submit_zero_max_tokens_completes_immediately():
    """Satellite fix: max_new_tokens <= 0 means nothing to generate — the
    request completes at submit() with zero new tokens instead of
    occupying a slot it could never leave."""
    sched = Scheduler(2)
    rid = sched.submit(Request(prompt=[3, 4], max_new_tokens=0))
    assert sched.has_work()          # the completion still must be drained
    assert sched.num_active == 0 and sched.pending == 0
    done = sched.advance({}, {})
    assert [c.request_id for c in done] == [rid]
    c = done[0]
    assert c.new_tokens.size == 0 and c.finish_reason == "length"
    assert c.steps == 0
    np.testing.assert_array_equal(c.tokens, [3, 4])
    assert not sched.has_work()


def test_engine_streams_immediate_completion_with_mixed_batch(served):
    """A zero-generation request mixed into live traffic streams out of
    DecodeEngine.serve() without disturbing the other requests' tokens."""
    cfg, params = served
    ecfg = engine.EngineConfig(max_batch=2, cache_len=64, prefill_chunk=4)
    eng = engine.DecodeEngine(params, cfg, ecfg)
    reqs = _mixed_requests(np.random.default_rng(3), lens=(3, 5),
                           news=(4, 3))
    ref = {c.request_id: c.tokens for c in eng.serve(list(reqs))}
    eng.reset()
    zero = Request(prompt=np.asarray([7, 8], np.int32), max_new_tokens=0)
    got = {c.request_id: c for c in eng.serve([reqs[0], zero, reqs[1]])}
    assert got[1].new_tokens.size == 0
    np.testing.assert_array_equal(got[1].tokens, [7, 8])
    np.testing.assert_array_equal(ref[0], got[0].tokens)
    np.testing.assert_array_equal(ref[1], got[2].tokens)


def test_scheduler_resubmit_gets_fresh_id():
    """A Request object re-submitted (e.g. after an engine reset) must not
    keep its stale id and collide with freshly issued ones."""
    s = Scheduler(max_batch=1)
    r = Request(prompt=np.asarray([1]), max_new_tokens=1)
    s.submit(r)
    s2 = Scheduler(max_batch=1)
    fresh = Request(prompt=np.asarray([2]), max_new_tokens=1)
    ids = {s2.submit(fresh), s2.submit(r)}
    assert len(ids) == 2                    # no collision
    assert r.request_id != fresh.request_id


def test_scheduler_evict():
    s = Scheduler(max_batch=2)
    s.submit(Request(prompt=np.asarray([1, 2, 3]), max_new_tokens=8))
    (slot, _), = s.admit()
    c = s.evict(slot)
    assert c.finish_reason == "evicted" and c.new_tokens.size == 0
    assert slot in s.free_slots and s.num_active == 0


def test_evict_steps_matches_advance_steps_accounting():
    """Satellite fix: ``evict`` runs BETWEEN engine steps, when
    ``step_count`` already covers every step the slot ran — the
    unconditional +1 (correct only for finishes inside ``advance``,
    where the current step is not yet counted) inflated evicted
    completions' ``steps`` by one."""
    def run(n_steps, finish_via_advance):
        s = Scheduler(max_batch=1)
        s.submit(Request(prompt=np.asarray([1, 2]),
                         max_new_tokens=n_steps - 1
                         if finish_via_advance else 10))
        (slot, _), = s.admit()
        for i in range(n_steps):
            last = i == n_steps - 1
            if finish_via_advance and last:
                # the terminal sample finishes the request inside advance
                done = s.advance({slot: 1}, {slot: 7})
                return done[0]
            s.advance({slot: 1}, {slot: 7})
        return s.evict(slot)

    # A request occupying a slot for 3 steps reports steps=3 whether it
    # finished inside step 3's advance or was evicted right after it.
    assert run(3, finish_via_advance=True).steps == 3
    assert run(3, finish_via_advance=False).steps == 3


def test_scheduler_cancel_queued_only():
    """``cancel`` removes a still-queued request (zero-generation
    "evicted" completion, steps=0); admitted / unknown ids return None —
    an admitted request must go through the engine, which releases its
    cache resources before evicting."""
    s = Scheduler(max_batch=1)
    r0 = s.submit(Request(prompt=np.asarray([1]), max_new_tokens=2))
    r1 = s.submit(Request(prompt=np.asarray([2]), max_new_tokens=2))
    (slot, _), = s.admit()                   # r0 takes the only slot
    assert s.cancel(r0) is None              # admitted: not cancellable here
    assert s.cancel(12345) is None           # unknown
    c = s.cancel(r1)
    assert c is not None and c.request_id == r1
    assert c.finish_reason == "evicted" and c.new_tokens.size == 0
    assert c.steps == 0 and s.pending == 0
    assert slot in s.slots                   # r0 untouched


def test_advance_commits_multi_token_lists():
    """Speculative rounds commit an ordered token LIST per slot in one
    advance; eos / max_new_tokens truncate the list at the terminal
    token (DESIGN.md §14)."""
    s = Scheduler(max_batch=1)
    s.submit(Request(prompt=np.asarray([1]), max_new_tokens=6, eos_id=9))
    (slot, _), = s.admit()
    assert s.advance({slot: 1}, {slot: [5, 6]}) == []
    assert s.slots[slot].generated == [5, 6]
    done = s.advance({slot: 2}, {slot: [7, 9, 8]})   # eos mid-list
    assert done[0].finish_reason == "eos"
    assert done[0].new_tokens.tolist() == [5, 6, 7, 9]
    # max_new_tokens truncates the same way
    s2 = Scheduler(max_batch=1)
    s2.submit(Request(prompt=np.asarray([1]), max_new_tokens=2))
    (slot, _), = s2.admit()
    done = s2.advance({slot: 1}, {slot: [3, 4, 5]})
    assert done[0].finish_reason == "length"
    assert done[0].new_tokens.tolist() == [3, 4]


def test_reset_cache_slots_wipes_only_target_rows(served):
    cfg, params = served
    cache = lm.init_cache(cfg, 3, 16, np.float32)
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, cfg, c, t, q))
    c = cache
    for t in range(3):
        _, c = step(params, c, np.asarray([t + 1] * 3, np.int32),
                    np.asarray([t] * 3, np.int32))
    c2 = lm.reset_cache_slots(c, [1])
    kv0 = c2["groups"][0]["kv"]
    assert (np.asarray(kv0["pos"][:, 1]) == -1).all()       # wiped row
    assert (np.asarray(kv0["k"][:, 1]) == 0).all()
    for row in (0, 2):                                      # untouched rows
        np.testing.assert_array_equal(np.asarray(kv0["pos"][:, row]),
                                      np.asarray(c["groups"][0]["kv"]["pos"][:, row]))
        np.testing.assert_array_equal(np.asarray(kv0["k"][:, row]),
                                      np.asarray(c["groups"][0]["kv"]["k"][:, row]))


# -------------------------------------------------- packed size metric ----
def test_packed_model_bytes_rejects_unknown_leaf(served):
    """Regression: a renamed carrier leaf must raise, not silently vanish
    from the paper's network-size metric."""
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg, engine.EngineConfig(cache_len=32))
    good = engine.packed_model_bytes(eng.params)
    assert good > 0
    wq = eng.params["groups"][0]["attn"]["wq"]
    renamed = dict(wq)
    renamed["w4_renamed"] = renamed.pop("w4")
    broken = jax.tree_util.tree_map(
        lambda x: x, eng.params)
    broken["groups"][0]["attn"]["wq"] = renamed
    with pytest.raises(ValueError, match="w4_renamed"):
        engine.packed_model_bytes(broken)
    # and the metric counts packed carriers as one byte per element
    assert engine.packed_model_bytes({"w4": np.zeros((4, 8), np.uint8),
                                      "w2": np.zeros((0, 8), np.uint8),
                                      "w1": np.zeros((0, 8), np.uint8)}) == 32
