"""SONIQ core: the paper's contribution as a composable JAX module."""
from .phases import Phase, PhaseSpec
from .qtypes import (ALLOWED_BITS, BLOCK_SIZE, GROUP_SIZE, GROUPS_PER_BLOCK,
                     FP32, P4, P8, P45, U2, U4, QuantConfig)
from . import noise, pack, patterns, phases, quant, schedule, smol

__all__ = [
    "ALLOWED_BITS", "BLOCK_SIZE", "GROUP_SIZE", "GROUPS_PER_BLOCK",
    "FP32", "P4", "P8", "P45", "U2", "U4", "Phase", "PhaseSpec",
    "QuantConfig",
    "noise", "pack", "patterns", "phases", "quant", "schedule", "smol",
]
