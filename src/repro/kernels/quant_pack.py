"""Fused SMOL quantize + bit-pack (deploy-time weight conversion, and
on-the-fly activation packing for the serve path).

w [K, N] f32 (optionally per-group-scaled) -> packed uint8 [K*p//8, N].
Grid (K/bk, N/bn); pure VPU work (no MXU): round to grid codes, then fold
8/p consecutive K rows into one byte with shifts — the inverse of
packed_matmul's in-register unpack.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.qtypes import GROUP_SIZE


def _kernel(w_ref, s_ref, o_ref, *, p: int, bk: int, use_scales: bool):
    w = w_ref[...].astype(jnp.float32)
    if use_scales:
        sig = jnp.repeat(s_ref[...].astype(jnp.float32), GROUP_SIZE, axis=0)
        w = w / sig
    h = float(2.0 ** (1 - p))
    two_p = float(2 ** p)
    u = jnp.clip(jnp.round((w / h + (two_p - 1.0)) / 2.0), 0.0, two_p - 1.0)
    u = u.astype(jnp.uint8)
    vpb = 8 // p
    u = u.reshape(bk // vpb, vpb, w.shape[-1])
    out = jnp.zeros((bk // vpb, w.shape[-1]), jnp.uint8)
    for j in range(vpb):
        out = out | (u[:, j] << np.uint8(p * j))
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=(
    "p", "block_k", "block_n", "interpret"))
def quantize_pack(w, scales, *, p: int, block_k: int = 256,
                  block_n: int = 256, interpret: bool = True):
    """w [K, N] -> uint8 [K*p//8, N] SMOL codes (packed little-endian on K)."""
    from .packed_matmul import fit_block
    k, n = w.shape
    bk = fit_block(k, block_k, GROUP_SIZE)
    bn = fit_block(n, block_n)
    use_scales = scales is not None
    if not use_scales:
        scales = jnp.ones((k // GROUP_SIZE,), jnp.float32)
    s2d = scales.reshape(-1, 1).astype(jnp.float32)
    kern = functools.partial(_kernel, p=p, bk=bk, use_scales=use_scales)
    return pl.pallas_call(
        kern,
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // GROUP_SIZE, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bk * p // 8, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k * p // 8, n), jnp.uint8),
        interpret=interpret,
    )(w, s2d)
