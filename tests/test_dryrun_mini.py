"""Multi-device dry-run smoke in a subprocess (this test process must keep
1 CPU device; the subprocess forces 16 host devices and lowers a reduced
arch on a 4x4 mesh with the production sharding rules)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json, sys, dataclasses
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch import mesh as mesh_lib, sharding as sh, hlo_cost
from repro.models import lm, shard as shard_ctx
from repro.optim import adamw
from repro.train import state as state_lib

arch = sys.argv[1]
cfg = get_config(arch).reduced()
cfg = dataclasses.replace(cfg, quant=dataclasses.replace(cfg.quant, mode="qat"))
mesh = mesh_lib.make_mesh((4, 4), ("data", "model"))
B, S = 8, 32
tcfg = state_lib.TrainConfig(num_microbatches=2)

with mesh_lib.set_mesh(mesh):
    state_specs = jax.eval_shape(
        lambda: state_lib.init_state(jax.random.PRNGKey(0), cfg, tcfg))
    rules = sh.activation_rules(cfg, mesh, batch=B)
    state_sh = sh.tree_shardings(state_specs, cfg, mesh, serve=False,
                                 rules=rules)
    bad = sh.validate_pspecs(state_specs,
                             sh.tree_pspecs(state_specs, cfg, mesh,
                                            serve=False, rules=rules), mesh)
    assert not bad, bad
    bspecs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
              "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        bspecs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        bspecs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                jnp.float32)
        bspecs.pop("tokens")
    if cfg.family == "audio":
        bspecs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                                jnp.float32)
    dp = rules["batch"]
    bsh = {k: NamedSharding(mesh, P(None, dp, None) if k == "positions"
                            else P(dp, *([None] * (len(v.shape) - 1))))
           for k, v in bspecs.items()}
    rng = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    with shard_ctx.sharding_rules(rules):
        lowered = jax.jit(
            lambda s, b, r: state_lib.train_step(s, b, cfg, tcfg, r),
            in_shardings=(state_sh, bsh, NamedSharding(mesh, P())),
            donate_argnums=(0,)).lower(state_specs, bspecs, rng)
        compiled = lowered.compile()
    t = hlo_cost.analyze(compiled.as_text())
    out = {
        "flops": t.dot_flops,
        "bytes": t.bytes_accessed,
        "coll": sum(t.collective_bytes.values()),
        "mem": int(compiled.memory_analysis().temp_size_in_bytes),
    }
    json.dump(out, open(sys.argv[2], "w"))
"""

ARCHS = ["h2o-danube-1.8b", "mixtral-8x22b", "mamba2-2.7b",
         "jamba-1.5-large-398b", "whisper-medium"]


def test_dryrun_mini_subprocess(tmp_path):
    script = str(tmp_path / "mini.py")
    with open(script, "w") as f:
        f.write(SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")
    for arch in ARCHS[:2]:      # two families is enough for CI time
        out = str(tmp_path / f"{arch}.json")
        subprocess.run([sys.executable, script, arch, out], env=env,
                       cwd=os.getcwd(), check=True, timeout=900)
        res = json.load(open(out))
        assert res["flops"] > 0
        assert res["bytes"] > 0
        assert res["coll"] > 0          # the mesh actually communicates


def test_full_dryrun_artifacts_present():
    """The production 40-cell x 2-mesh sweep must exist and be green."""
    d = "results/dryrun"
    if not os.path.isdir(d):
        import pytest
        pytest.skip("run python -m repro.launch.dryrun first")
    cells = [json.load(open(os.path.join(d, f)))
             for f in os.listdir(d) if f.endswith(".json")]
    assert len(cells) == 80
    errors = [c for c in cells if "error" in c]
    assert not errors, [(c["arch"], c["shape"], c["mesh"]) for c in errors]
    ok = [c for c in cells if "skipped" not in c]
    skipped = [c for c in cells if "skipped" in c]
    assert len(ok) == 68 and len(skipped) == 12     # 6 long_500k skips/mesh
    for c in ok:
        assert c["corrected"]["dot_flops"] > 0
        assert c["memory"]["temp_size_in_bytes"] >= 0
