"""Quantized KV cache (beyond-paper extension, DESIGN.md §8/§12).

K/V live as SMOL 4-bit codes packed 2-per-byte with one fp16-scale per
(batch, slot, kv-head): cache payload bytes drop ~4x vs fp16 (the
decode_32k cells are KV-read-bound at large batch; ``cache_payload_bytes``
is the accounting the claim is measured with — ``pos`` bookkeeping is
identical in both cache families and reported separately). Quantization
error matches the W4 grid: round-trip RMS error <= 3% of each head's
dynamic range (worst-case element 3.5% — the half-step bound); on gaussian
K/V that is ~10% norm-relative, which attention outputs inherit. Tests pin
these bounds (`tests/test_kv_quant_cluster.py`).

The packed layout matches kernels/packed_matmul's carrier convention, so
the fused quantized-KV flash-decode kernel (``kernels/attn_decode.py``,
reached through the ``qkv_attn_decode`` backend op — DESIGN.md §12)
consumes it directly; the jnp path here is the oracle.

Ring-write semantics mirror the fp cache in ``models.attention``
(DESIGN.md §10): lanes with ``pos < 0`` (idle batch slots, prefill-chunk
padding) are redirected out of bounds and dropped (``mode="drop"``) so a
masked lane can never clobber a live ring entry, and ``update_qkv_cache``
accepts S > 1 token chunks (chunked prefill) plus the stacked ``[L, ...]``
scan-carry layout via ``layer_idx``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.quant import ACT_SCALE_EPS

P_BITS = 4
GRID_MAX = 2.0 - 2.0 ** (1 - P_BITS)
_SCALE_MAX = float(np.finfo(np.float16).max)   # fp16 scale saturation


def quantize_kv(x) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, H, D] -> (codes uint8 [B, S, H, D//2], scale f16 [B,S,H,1]).

    The abs-max clamp is the shared ``ACT_SCALE_EPS`` floor from
    ``repro.backend.base`` — the single place the all-zero-row guarantee
    (a freshly reset slot must never produce a 0 divisor) is pinned.

    Codes are computed against the *stored* scale — clamped into fp16
    range (heads with abs-max beyond ~1.2e5 saturate to the top of the
    grid instead of decoding to inf) and rounded through fp16 — so the
    round-trip error is bounded by the stored scale's half-step, not by a
    scale the reader never sees.
    """
    xf = jnp.asarray(x, jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True),
                        ACT_SCALE_EPS) / GRID_MAX
    scale = jnp.minimum(scale, _SCALE_MAX).astype(jnp.float16)
    u = quant.quantize_to_int(xf / scale.astype(jnp.float32), P_BITS)
    u = u.astype(jnp.uint8)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return (lo | (hi << 4)), scale


def dequantize_kv(codes, scale, dtype=jnp.bfloat16):
    """(codes, scale) -> [B, S, H, D]."""
    lo = (codes & 0xF).astype(dtype)
    hi = ((codes >> 4) & 0xF).astype(dtype)
    u = jnp.stack([lo, hi], axis=-1).reshape(codes.shape[:-1]
                                             + (codes.shape[-1] * 2,))
    v = (2.0 * u - (2 ** P_BITS - 1)) * (2.0 ** (1 - P_BITS))
    return v * scale.astype(dtype)


def init_qkv_cache(batch: int, cache_len: int, num_kv_heads: int,
                   head_dim: int) -> Dict:
    assert head_dim % 2 == 0
    return {
        "k_codes": jnp.zeros((batch, cache_len, num_kv_heads, head_dim // 2),
                             jnp.uint8),
        "v_codes": jnp.zeros((batch, cache_len, num_kv_heads, head_dim // 2),
                             jnp.uint8),
        "k_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                             jnp.float16),
        "v_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                             jnp.float16),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def qkv_cache_specs(batch: int, cache_len: int, num_kv_heads: int,
                    head_dim: int) -> Dict:
    """ShapeDtypeStructs of :func:`init_qkv_cache` (dry-run, no
    allocation) — the quantized counterpart of
    ``attention.kv_cache_specs``."""
    assert head_dim % 2 == 0
    sd = jax.ShapeDtypeStruct
    return {
        "k_codes": sd((batch, cache_len, num_kv_heads, head_dim // 2),
                      jnp.uint8),
        "v_codes": sd((batch, cache_len, num_kv_heads, head_dim // 2),
                      jnp.uint8),
        "k_scale": sd((batch, cache_len, num_kv_heads, 1), jnp.float16),
        "v_scale": sd((batch, cache_len, num_kv_heads, 1), jnp.float16),
        "pos": sd((batch, cache_len), jnp.int32),
    }


def update_qkv_cache(cache: Dict, k_new, v_new, pos, *,
                     layer_idx: Optional[int] = None) -> Dict:
    """Quantize + ring-write a chunk of new K/V (k_new/v_new [B, S, H, D])
    at slot ``pos % cache_len``.

    ``pos`` is [B] or [B, S] absolute positions; lanes with ``pos < 0``
    (idle batch slot / prefill-chunk padding) are redirected out of bounds
    and dropped (``mode="drop"``) exactly like the fp ring write in
    ``models.attention.attn_decode`` — a masked lane never clobbers a live
    ring entry and never stamps its ``pos`` over a resident one.

    ``layer_idx``: when given, cache leaves are the stacked ``[L, ...]``
    scan-carry buffers and the scatter happens in place at
    ``[layer_idx, b, slot]`` (one token-chunk's bytes).
    """
    b = k_new.shape[0]
    stacked = layer_idx is not None
    cache_len = cache["k_codes"].shape[2 if stacked else 1]
    posb = pos[:, None] if pos.ndim == 1 else pos            # [B, S]
    # Masked lanes (pos < 0) scatter out of bounds -> dropped.
    slot = jnp.where(posb >= 0, posb % cache_len, cache_len)
    slot = slot.astype(jnp.int32)
    bidx = jnp.arange(b)[:, None]
    kc, ks = quantize_kv(k_new)
    vc, vs = quantize_kv(v_new)
    new = {"k_codes": kc, "v_codes": vc, "k_scale": ks, "v_scale": vs,
           "pos": posb}

    def write(name, val):
        leaf = cache[name]
        val = val.astype(leaf.dtype)
        if stacked:
            return leaf.at[layer_idx, bidx, slot].set(val, mode="drop")
        return leaf.at[bidx, slot].set(val, mode="drop")

    return {name: write(name, val) for name, val in new.items()}


def read_qkv_cache(cache: Dict, dtype=jnp.bfloat16):
    """-> (k [B,S,H,D], v [B,S,H,D], pos [B,S])."""
    k = dequantize_kv(cache["k_codes"], cache["k_scale"], dtype)
    v = dequantize_kv(cache["v_codes"], cache["v_scale"], dtype)
    return k, v, cache["pos"]


# ------------------------------------------------- byte accounting ----
# The "4x cache bytes" claim compares the *ring K/V payload* only: the
# quantized family's codes + scales vs the fp family's k/v buffers.
# ``pos`` is scheduler bookkeeping carried identically by both families;
# SSM state and cross-attention K/V (named k/v too, but under a "cross"
# subtree) never quantize and are excluded from both sides of the ratio.
_KV_PAYLOAD_LEAVES = frozenset({"k", "v", "k_codes", "v_codes",
                                "k_scale", "v_scale"})
# ``page_table`` is the paged layout's (serve/kv_pool.py) logical->physical
# map; like ``pos`` it is bookkeeping, not payload, so it lands in the meta
# bucket and the payload ratio stays an apples-to-apples K/V comparison.
_META_LEAVES = frozenset({"pos", "page_table"})


def _leaf_bytes(v) -> int:
    """Bytes of an array or ShapeDtypeStruct (specs=True dry-run trees)."""
    return int(np.prod(v.shape, dtype=np.int64)) * np.dtype(v.dtype).itemsize


def _ring_kv_leaves(cache, names):
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        if leaf is None:
            continue
        keys = [str(getattr(p, "key", "")) for p in path]
        if keys[-1] in names and "cross" not in keys:
            yield leaf


def cache_payload_bytes(cache) -> int:
    """Ring K/V payload bytes of a cache (py)tree: packed codes + scales
    for the quantized family, k/v buffers for the fp family. Works on a
    single-layer cache dict, the full stacked ``lm.init_cache`` tree
    (SSM/cross-attention leaves are not ring K/V and don't count), and
    ``specs=True`` trees."""
    return sum(_leaf_bytes(v)
               for v in _ring_kv_leaves(cache, _KV_PAYLOAD_LEAVES))


def cache_meta_bytes(cache) -> int:
    """Bytes of the ring ``pos`` metadata (reported separately from the
    payload so the compression claim stays honest)."""
    return sum(_leaf_bytes(v) for v in _ring_kv_leaves(cache, _META_LEAVES))


def cache_bytes(cache) -> int:
    """Total bytes of every leaf in the cache tree (payload, metadata,
    and any non-KV state such as SSM carries or cross-attention K/V)."""
    return sum(
        _leaf_bytes(leaf)
        for _path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]
        if leaf is not None)


# ------------------------------------------------- slot management ----
def reset_slots(cache: Dict, slots) -> Dict:
    """Wipe the cache rows of the given batch slots (continuous-batching
    admission/eviction, DESIGN.md §10): codes/scales zero, ``pos`` -1 so
    every ring entry of the row reads as empty. Rows not listed are
    untouched, and the packed carrier layout is preserved — the fused
    flash-decode kernel never sees a half-valid row."""
    idx = jnp.asarray(slots, jnp.int32)
    out = {k: v.at[idx].set(jnp.zeros((), v.dtype))  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
           for k, v in cache.items() if k != "pos"}
    out["pos"] = cache["pos"].at[idx].set(-1)  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
    return out


def evict_slot(cache: Dict, slot: int) -> Dict:
    """Free one slot's row (request completion/cancellation)."""
    return reset_slots(cache, [slot])


def slot_lengths(cache: Dict) -> jax.Array:
    """Number of valid (written, non-evicted) ring entries per slot [B]."""
    return jnp.sum(cache["pos"] >= 0, axis=1).astype(jnp.int32)
