"""repro.analysis.lint: every SQ rule trips on the bug pattern that
motivated it (CHANGES.md), stays quiet on the fixed form, and honors
inline suppressions + the baseline workflow (DESIGN.md §15)."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"


def _lint(code, path="", codes=None):
    return lint.lint_source(textwrap.dedent(code), path, codes=codes)


def _codes(result):
    return sorted(v.code for v in result.violations)


# ------------------------------------------------------------- SQ001 ----
# PR5's masked-lane bug: evicted lanes wrote ring position 0 through an
# unmasked scatter, clobbering a live request's KV entry.

def test_sq001_trips_on_dynamic_scatter_without_mode():
    r = _lint("""
        def write(cache, idx, kv):
            return cache.at[:, idx].set(kv)
    """)
    assert _codes(r) == ["SQ001"]
    assert "drop" in r.violations[0].message


def test_sq001_trips_on_add_min_max():
    r = _lint("""
        def f(buf, i, x):
            a = buf.at[i].add(x)
            b = buf.at[i].max(x)
            return a, b
    """)
    assert _codes(r) == ["SQ001", "SQ001"]


def test_sq001_quiet_with_mode_drop():
    r = _lint("""
        def write(cache, idx, kv):
            return cache.at[:, idx].set(kv, mode="drop")
    """)
    assert r.ok


def test_sq001_quiet_on_static_index():
    r = _lint("""
        def f(buf, x):
            a = buf.at[0].set(x)
            b = buf.at[1:3].set(x)
            c = buf.at[-1].set(x)
            return a, b, c
    """)
    assert r.ok


def test_sq001_suppressed_with_reason():
    r = _lint("""
        def reset(cache, idx):
            return cache.at[idx].set(0)  # soniq-lint: disable=SQ001(host-validated ids)
    """)
    assert r.ok
    assert [s.code for s in r.suppressed] == ["SQ001"]
    assert r.suppressed[0].reason == "host-validated ids"


def test_suppression_without_reason_is_malformed():
    r = _lint("""
        def reset(cache, idx):
            return cache.at[idx].set(0)  # soniq-lint: disable=SQ001
    """)
    assert "SQ000" in _codes(r)


def test_comment_line_suppression_covers_next_line():
    r = _lint("""
        def reset(cache, idx):
            # soniq-lint: disable=SQ001(host-validated ids)
            return cache.at[idx].set(0)
    """)
    assert r.ok and len(r.suppressed) == 1


# ------------------------------------------------------------- SQ002 ----
# PR4's zero-row divide: an all-pad row has abs-max 0, and x / 0 turns
# the whole activation row NaN before the GEMM.

def test_sq002_trips_on_unclamped_absmax_divide():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x):
            s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
            return x / s
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_trips_on_inline_divide_and_method_form():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x):
            return x / jnp.abs(x).max(axis=-1, keepdims=True)
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_quiet_when_clamped():
    r = _lint("""
        import jax.numpy as jnp
        ACT_SCALE_EPS = 1e-6
        def quantize(x):
            s = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True),
                            ACT_SCALE_EPS)
            return x / s
    """)
    assert r.ok


def test_sq002_trips_on_zero_eps():
    r = _lint("""
        from repro.core.quant import abs_max_scale
        def f(x):
            return abs_max_scale(x, eps=0)
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_trips_on_reciprocal_multiply():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x):
            inv = jnp.reciprocal(jnp.max(jnp.abs(x), axis=-1,
                                         keepdims=True))
            return x * inv
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_trips_on_one_over_scale():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x):
            return x * (1.0 / jnp.abs(x).max(axis=-1, keepdims=True))
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_trips_on_lax_div():
    r = _lint("""
        import jax.numpy as jnp
        from jax import lax
        def quantize(x):
            s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
            return lax.div(x, s)
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_trips_on_jnp_divide():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x):
            return jnp.divide(x, jnp.max(jnp.abs(x)))
    """)
    assert _codes(r) == ["SQ002"]


def test_sq002_quiet_on_clamped_reciprocal():
    r = _lint("""
        import jax.numpy as jnp
        def quantize(x, eps):
            inv = jnp.reciprocal(jnp.maximum(jnp.max(jnp.abs(x)), eps))
            return x * inv
    """)
    assert r.ok


# ------------------------------------------------------------- SQ007 ----
# Stale suppressions: a disable=SQxxx(reason) whose hazard no longer
# exists keeps swallowing the rule when it fires next for a new bug.

def test_sq007_trips_on_stale_suppression():
    r = _lint("""
        def f(buf, x):
            return buf.at[0].set(x)  # soniq-lint: disable=SQ001(stale claim)
    """)
    assert _codes(r) == ["SQ007"]
    assert "SQ001" in r.violations[0].message


def test_sq007_quiet_when_suppression_fires():
    r = _lint("""
        def f(buf, i, x):
            return buf.at[i].set(x)  # soniq-lint: disable=SQ001(host ids)
    """)
    assert r.ok and [s.code for s in r.suppressed] == ["SQ001"]


def test_sq007_only_judges_rules_that_ran():
    # Restricting the run to SQ002 must not flag an unused SQ001
    # suppression — that rule never executed, so staleness is unknown.
    r = _lint("""
        def f(buf, x):
            return buf.at[0].set(x)  # soniq-lint: disable=SQ001(stale claim)
    """, codes=["SQ002", "SQ007"])
    assert r.ok


def test_sq007_suppressible_itself():
    r = _lint("""
        def f(buf, x):
            return buf.at[0].set(x)  # soniq-lint: disable=SQ001(kept), disable=SQ007(transitional)
    """)
    assert r.ok
    assert "SQ007" in [s.code for s in r.suppressed]


# ------------------------------------------------------------- SQ003 ----
# Registry-bypass: calling repro.kernels.* directly skips backend
# negotiation (and the interpret-mode gating CI relies on).

def test_sq003_trips_outside_backend_pkg():
    for src in ("import repro.kernels.flash",
                "from repro.kernels import flash",
                "from repro import kernels",
                "import importlib\n"
                "m = importlib.import_module('repro.kernels.flash')"):
        r = lint.lint_source(src, "src/repro/serve/engine.py")
        assert "SQ003" in _codes(r), src


def test_sq003_allowed_inside_backend_and_kernels():
    for path in ("src/repro/backend/pallas.py",
                 "src/repro/kernels/flash.py"):
        r = lint.lint_source("from repro.kernels import flash", path)
        assert r.ok, path


# ------------------------------------------------------------- SQ004 ----
# Undonated cache-sized jit operands double-buffer the KV cache.

def test_sq004_trips_on_undonated_serve_jit():
    r = lint.lint_source(
        "import jax\n"
        "step = jax.jit(lambda p, c: c)\n",
        "src/repro/serve/engine.py")
    assert _codes(r) == ["SQ004"]


def test_sq004_quiet_with_donation_or_outside_serve():
    r = lint.lint_source(
        "import jax\n"
        "step = jax.jit(lambda p, c: c, donate_argnums=(1,))\n",
        "src/repro/serve/engine.py")
    assert r.ok
    r = lint.lint_source("import jax\nf = jax.jit(lambda x: x)\n",
                         "src/repro/train/state.py")
    assert r.ok


# ------------------------------------------------------------- SQ005 ----
# Host syncs inside engine step loops serialize device and host; the
# budget is one [B]-int transfer per step (DESIGN.md §10).

def test_sq005_trips_in_step_functions():
    r = lint.lint_source(textwrap.dedent("""
        import numpy as np
        class Engine:
            def step(self, out):
                toks = np.asarray(out)
                flag = out.item()
                host = float(out)
                return toks, flag, host
    """), "src/repro/serve/engine.py")
    assert _codes(r) == ["SQ005", "SQ005", "SQ005"]


def test_sq005_quiet_outside_step_and_outside_serve():
    src = ("import numpy as np\n"
           "def summarize(x):\n"
           "    return np.asarray(x)\n")
    assert lint.lint_source(src, "src/repro/serve/engine.py").ok
    step = ("import numpy as np\n"
            "def step(x):\n"
            "    return np.asarray(x)\n")
    assert lint.lint_source(step, "src/repro/eval/harness.py").ok


# ------------------------------------------------------------- SQ006 ----
# Wall-clock / global-RNG calls in traced code bake a trace-time value
# into the compiled step (or silently differ across processes).

def test_sq006_trips_in_jitted_and_kernel_code():
    r = lint.lint_source(textwrap.dedent("""
        import time, random
        import numpy as np
        import jax
        @jax.jit
        def f(x):
            t = time.time()
            r = random.random()
            z = np.random.rand(3)
            return x + t + r + z
    """), "src/repro/train/state.py")
    assert _codes(r) == ["SQ006", "SQ006", "SQ006"]


def test_sq006_allows_seeded_generator():
    r = lint.lint_source(
        "import numpy as np\n"
        "rng = np.random.default_rng(0)\n",
        "src/repro/models/ssm.py")
    assert r.ok


# ----------------------------------------------------------- baseline ----

def test_baseline_grandfathers_then_invalidates_on_edit(tmp_path):
    src = ("def write(cache, idx, kv):\n"
           "    return cache.at[idx].set(kv)\n")
    f = tmp_path / "src" / "repro" / "hot.py"
    f.parent.mkdir(parents=True)
    f.write_text(src)

    first = lint.lint_paths([f], root=tmp_path)
    assert len(first.violations) == 1
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps(lint.baseline_entries(first.violations)))

    again = lint.lint_paths([f], root=tmp_path, baseline=bl)
    assert again.ok and len(again.baselined) == 1

    # Editing the flagged line invalidates the grandfather.
    f.write_text(src.replace(".set(kv)", ".set(kv * 2)"))
    edited = lint.lint_paths([f], root=tmp_path, baseline=bl)
    assert len(edited.violations) == 1 and not edited.baselined


def test_syntax_error_reports_sq000():
    r = lint.lint_source("def broken(:\n")
    assert _codes(r) == ["SQ000"]


# ---------------------------------------------------------- repo-wide ----

def test_rule_registry_complete():
    codes = [r.code for r in lint.all_rules()]
    assert codes == ["SQ001", "SQ002", "SQ003", "SQ004", "SQ005", "SQ006",
                     "SQ007"]
    assert all(r.rationale for r in lint.all_rules())


def test_repo_src_tree_is_clean():
    """The committed tree lints clean against the committed baseline —
    the same gate CI's static-analysis leg enforces."""
    baseline = SRC_ROOT / "repro" / "analysis" / "baseline.json"
    result = lint.lint_paths([SRC_ROOT], baseline=baseline)
    assert result.ok, "\n".join(v.format() for v in result.violations)
    # Every suppression in the tree carries a recorded reason.
    assert all(s.reason for s in result.suppressed)


def test_cli_json_output(tmp_path, capsys):
    from repro.analysis.__main__ import main
    f = tmp_path / "bad.py"
    f.write_text("def f(c, i, x):\n    return c.at[i].set(x)\n")
    rc = main([str(f), "--json", "--no-baseline"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1 and not out["ok"]
    assert [v["code"] for v in out["violations"]] == ["SQ001"]


def test_cli_sarif_output(tmp_path, capsys):
    from repro.analysis.__main__ import main
    f = tmp_path / "bad.py"
    f.write_text("def f(c, i, x):\n    return c.at[i].set(x)\n")
    sarif_file = tmp_path / "out.sarif"
    rc = main([str(f), "--no-baseline", "--sarif", str(sarif_file)])
    capsys.readouterr()
    assert rc == 1
    log = json.loads(sarif_file.read_text())
    assert log["version"] == "2.1.0"
    results = log["runs"][0]["results"]
    assert [r["ruleId"] for r in results] == ["SQ001"]
    loc = results[0]["locations"][0]["physicalLocation"]
    assert loc["region"]["startLine"] == 2
    rules = {r["id"] for r in log["runs"][0]["tool"]["driver"]["rules"]}
    assert "SQ001" in rules


def test_cli_write_baseline_roundtrip(tmp_path, capsys):
    from repro.analysis.__main__ import main
    f = tmp_path / "bad.py"
    f.write_text("def f(c, i, x):\n    return c.at[i].set(x)\n")
    bl = tmp_path / "baseline.json"
    assert main([str(f), "--baseline", str(bl), "--write-baseline"]) == 0
    capsys.readouterr()
    assert main([str(f), "--baseline", str(bl)]) == 0
