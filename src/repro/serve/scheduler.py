"""Request-level continuous-batching scheduler (DESIGN.md §10).

The scheduler owns the *host-side* state machine of the serve engine:

  * an **admission queue** of :class:`Request` objects ordered by
    ``(arrival_step, submit order)``;
  * a **free-list** of the engine's ``max_batch`` batch slots;
  * per-slot :class:`SlotState` tracking where each admitted request is in
    its lifecycle (``PREFILL`` — prompt tokens still being fed into the KV
    cache — then ``DECODE`` — sampling new tokens — then eviction).

It is deliberately jax-free: the engine (``serve/engine.py``) asks the
scheduler *what to feed each slot this step* and tells it *what was
sampled*; all device work (decode step, sampling) stays in the engine.
Invariants (pinned by ``tests/test_serve_scheduler.py``):

  * a request's token stream depends only on its own prompt, seed and
    sampling params — never on batch composition (slot rows are
    independent), so continuous batching is token-parity with the lockstep
    engine at temperature 0;
  * a slot is reset (KV rows wiped, ``pos = -1``) at admission, never
    lazily, so an evicted request can leave garbage behind;
  * admission happens at step start: a slot freed by a completion in step
    ``t`` is reusable in step ``t + 1``;
  * requests are admitted in ``(arrival_step, submit order)`` order — no
    reordering, no starvation.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; ``seed`` drives the per-request
    sampling rng (folded with the generated-token index, so the stream is
    reproducible under any batch schedule); ``arrival_step`` lets synthetic
    workloads model staggered traffic — the scheduler will not admit a
    request before its arrival step.
    """
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    arrival_step: int = 0
    eos_id: Optional[int] = None
    request_id: Optional[int] = None     # (re)assigned at every submit()

    def __post_init__(self):
        # Degenerate requests (empty prompt, max_new_tokens <= 0) are
        # handled at Scheduler.submit() — rejected or completed
        # immediately — not here: a bare Request is a value object, and
        # `assert` validation disappears under `python -O`, which is how
        # they used to slip into the prefill->decode state machine and
        # never finish.
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)


@dataclasses.dataclass
class Completion:
    """A finished request, streamed back by the engine."""
    request_id: int
    request: Request
    tokens: np.ndarray          # [S0 + num generated] prompt + generated
    new_tokens: np.ndarray      # [num generated]
    finish_reason: str          # "length" | "eos" | "evicted"
    finished_step: int          # engine step at which the request finished
    steps: int                  # engine steps the request occupied a slot


@dataclasses.dataclass
class SlotState:
    request: Request
    n_fed: int = 0              # tokens fed into the cache so far
    generated: Optional[List[int]] = None
    admitted_step: int = 0

    def __post_init__(self):
        if self.generated is None:
            self.generated = []

    @property
    def phase(self) -> str:
        return PREFILL if self.n_fed < len(self.request.prompt) else DECODE

    def next_tokens(self, chunk: int) -> np.ndarray:
        """The (up to ``chunk``) tokens this slot feeds next step: remaining
        prompt tokens while prefilling, else the last sampled token."""
        prompt = self.request.prompt
        if self.n_fed < len(prompt):
            return prompt[self.n_fed:self.n_fed + chunk]
        return np.asarray([self.generated[-1]], np.int32)

    @property
    def samples_this_step(self) -> bool:
        """Whether the logits of this slot's last fed token are consumed
        (true once the final prompt token has entered the cache)."""
        return self.n_fed >= len(self.request.prompt)


class Scheduler:
    """Admission queue + slot free-list + per-slot lifecycle state.

    ``can_admit``: optional capacity callback consulted at admission time
    for the request at the head of the queue — a free batch slot alone is
    not always enough (the paged KV engine also needs the page pool to
    cover the prompt's pages, DESIGN.md §13). When it returns False,
    admission stops for this step (head-of-line blocking, preserving
    FIFO) and retries next step once capacity frees up.
    """

    def __init__(self, max_batch: int,
                 can_admit: Optional[Callable[[Request], bool]] = None):
        assert max_batch > 0
        self.max_batch = max_batch
        self.can_admit = can_admit
        self._queue: List[Tuple[int, int, Request]] = []   # heap
        self._ticket = itertools.count()
        self._next_id = itertools.count()
        self.free_slots: List[int] = list(range(max_batch))[::-1]
        self.slots: Dict[int, SlotState] = {}
        self._immediate: List[Completion] = []
        self.step_count = 0

    # ------------------------------------------------------------ queue ----
    def submit(self, request: Request) -> int:
        """Queue a request. Degenerate requests never enter the
        prefill->decode state machine (where they could not finish): an
        empty prompt is rejected with ``ValueError``; ``max_new_tokens <=
        0`` completes immediately with zero generated tokens (the
        completion is delivered by the next ``advance()``)."""
        if request.prompt.size == 0:
            raise ValueError(
                "empty prompt: a request must carry at least one token to "
                "prefill")
        # Always assign a fresh id: a re-submitted Request object (e.g.
        # after an engine reset) must not collide with this scheduler's
        # freshly issued ids.
        request.request_id = next(self._next_id)
        if request.max_new_tokens <= 0:
            self._immediate.append(Completion(
                request_id=request.request_id, request=request,
                tokens=request.prompt.copy(),
                new_tokens=np.zeros((0,), np.int32),
                finish_reason="length", finished_step=self.step_count,
                steps=0))
            return request.request_id
        heapq.heappush(self._queue,
                       (request.arrival_step, next(self._ticket), request))
        return request.request_id

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return len(self.slots)

    def has_work(self) -> bool:
        return bool(self._queue) or bool(self.slots) \
            or bool(self._immediate)

    # -------------------------------------------------------- admission ----
    def admit(self) -> List[Tuple[int, Request]]:
        """Move arrived requests from the queue into free slots (call at
        step start). Returns [(slot, request)] for the engine to reset the
        KV rows of."""
        admitted = []
        while self.free_slots and self._queue \
                and self._queue[0][0] <= self.step_count:
            if self.can_admit is not None \
                    and not self.can_admit(self._queue[0][2]):
                break                      # head-of-line waits for capacity
            _, _, req = heapq.heappop(self._queue)
            slot = self.free_slots.pop()
            self.slots[slot] = SlotState(req, admitted_step=self.step_count)
            admitted.append((slot, req))
        return admitted

    # ------------------------------------------------------- step plan  ----
    def plan(self, prefill_chunk: int) -> Dict[int, np.ndarray]:
        """{slot: tokens to feed this step} (1 token for decoding slots, up
        to ``prefill_chunk`` for prefilling ones)."""
        return {s: st.next_tokens(max(prefill_chunk, 1))
                for s, st in self.slots.items()}

    # ------------------------------------------------------ advancement ----
    def advance(self, fed: Dict[int, int], sampled: Dict[int, object]
                ) -> List[Completion]:
        """Commit one engine step: ``fed[slot]`` tokens entered the cache,
        ``sampled[slot]`` is the token drawn from the slot's last-token
        logits (ignored for slots still mid-prefill) — or, in a
        speculative round (DESIGN.md §14), the ordered LIST of committed
        tokens (accepted drafts + the verify bonus/correction token).
        Each committed token is checked against eos / ``max_new_tokens``
        in order; a terminal token truncates the rest of the list. One
        call is one engine step regardless of how many tokens it commits.
        Returns completions (including any immediately-completed
        zero-generation submissions); their slots go back on the
        free-list (reusable next step)."""
        done: List[Completion] = self._immediate
        self._immediate = []
        for slot, n in fed.items():
            st = self.slots[slot]
            st.n_fed += n
            if not st.samples_this_step:
                continue                       # still prefilling
            req = st.request
            reason = None
            for tok in np.atleast_1d(np.asarray(sampled[slot], np.int64)):
                st.generated.append(int(tok))
                eos = req.eos_id is not None and int(tok) == req.eos_id
                if eos or len(st.generated) >= req.max_new_tokens:
                    reason = "eos" if eos else "length"
                    break
            if reason is not None:
                done.append(self._finish(slot, reason))
        self.step_count += 1
        return done

    def _finish(self, slot: int, reason: str, *,
                in_step: bool = True) -> Completion:
        st = self.slots.pop(slot)
        self.free_slots.append(slot)
        new = np.asarray(st.generated, np.int32)
        # ``steps`` counts the engine steps the slot was occupied for.
        # Finishing DURING a step (advance), step_count has not yet been
        # incremented for the step that just ran — hence the +1. Between
        # steps (evict), step_count already covers every step the slot
        # ran; a +1 there would count a step the slot never ran.
        return Completion(
            request_id=st.request.request_id, request=st.request,
            tokens=np.concatenate([st.request.prompt, new]),
            new_tokens=new, finish_reason=reason,
            finished_step=self.step_count,
            steps=self.step_count - st.admitted_step + (1 if in_step else 0))

    def evict(self, slot: int) -> Completion:
        """Force-finish a slot (admin path: cancellation / preemption).
        Called BETWEEN engine steps — never from inside ``advance``."""
        return self._finish(slot, "evicted", in_step=False)

    def cancel(self, request_id: int) -> Optional[Completion]:
        """Remove a still-QUEUED request (never admitted): its "evicted"
        zero-generation Completion, or None when the id is not in the
        queue (already admitted, finished, or unknown — an admitted
        request is cancelled through the engine, which must release the
        slot's cache resources before calling :meth:`evict`)."""
        for i, (_, _, req) in enumerate(self._queue):
            if req.request_id == request_id:
                self._queue.pop(i)
                heapq.heapify(self._queue)
                return Completion(
                    request_id=request_id, request=req,
                    tokens=req.prompt.copy(),
                    new_tokens=np.zeros((0,), np.int32),
                    finish_reason="evicted",
                    finished_step=self.step_count, steps=0)
        return None
