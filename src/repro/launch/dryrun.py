import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count at first init.
# This file is the ONLY place the flag is set — smoke tests/benches see 1
# CPU device.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED, get_config          # noqa: E402
from repro.launch import hlo_cost                       # noqa: E402
from repro.launch import mesh as mesh_lib               # noqa: E402
from repro.launch import sharding as sh                 # noqa: E402
from repro.launch import specs as specs_lib             # noqa: E402
from repro.models import shard as shard_ctx             # noqa: E402
from repro.train import state as state_lib              # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by collectives: sum of output-tuple sizes of
    every collective op in the scheduled HLO (post-SPMD = per-partition)."""
    per_kind = {}
    counts = {}
    for m in _COLL_RE.finditer(hlo_text):
        ty, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(ty):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return per_kind, counts


def _shardings_for_batch(batch_specs, mesh, rules):
    dp = rules["batch"]

    def one(path, s):
        name = str(path[-1].key)
        if name in ("tokens", "labels"):
            return NamedSharding(mesh, P(dp, None))
        if name in ("embeds", "frames"):
            return NamedSharding(mesh, P(dp, None, None))
        if name == "positions":
            return NamedSharding(mesh, P(None, dp, None))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(one, batch_specs)


def _cache_pspec(path_keys, shape, mesh, rules):
    ax = dict(mesh.shape)
    model = ax.get("model", 1)
    dp = rules["batch"]
    name = path_keys[-1]

    def seq_ax(sz):
        return "model" if sz % model == 0 else None
    if name in ("k", "v"):              # [L,B,S,hk,dh]
        return P(None, dp, seq_ax(shape[2]), None, None)
    if name == "pos":                   # [L,B,S]
        return P(None, dp, seq_ax(shape[2]))
    if name == "h":                     # [L,B,H,P,N]
        return P(None, dp, "model" if shape[2] % model == 0 else None,
                 None, None)
    if name == "conv":                  # [L,B,K,C]
        return P(None, dp, None, "model" if shape[3] % model == 0 else None)
    return P(*([None] * len(shape)))


def _shardings_for_cache(cache_specs, mesh, rules):
    def one(path, s):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return NamedSharding(mesh, _cache_pspec(keys, s.shape, mesh, rules))
    return jax.tree_util.tree_map_with_path(one, cache_specs)


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")}
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
        return {"flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
                "transcendentals": float(ca.get("transcendentals", -1))}
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}


def run_cell(arch: str, shape: str, multi_pod: bool, *,
             save_hlo: str = "", hoist: bool = False) -> dict:
    skip = specs_lib.cell_skip_reason(arch, shape)
    if skip:
        return {"arch": arch, "shape": shape,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "skipped": skip}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    info = specs_lib.SHAPES[shape]
    rules = None
    t0 = time.time()

    with mesh_lib.set_mesh(mesh):
        if info["kind"] == "train":
            tcfg = specs_lib.train_config_for(arch, mesh)
            if hoist:
                import dataclasses as _dc
                tcfg = _dc.replace(tcfg, hoist_weight_quant=True)
            state_specs, cfg = specs_lib.train_state_specs(arch, tcfg)
            rules = sh.activation_rules(cfg, mesh, batch=info["batch"])
            state_sh = sh.tree_shardings(state_specs, cfg, mesh,
                                         serve=False, rules=rules)
            bspecs = specs_lib.batch_specs(arch, shape)
            if cfg.family == "vlm":
                bspecs = dict(bspecs)
                bspecs.pop("tokens")
                bspecs["embeds"] = jax.ShapeDtypeStruct(
                    (info["batch"], info["seq"], cfg.d_model), jnp.bfloat16)
            batch_sh = _shardings_for_batch(bspecs, mesh, rules)
            rng_spec = jax.eval_shape(lambda: jax.random.PRNGKey(0))
            step = specs_lib.make_train_step(cfg, tcfg)
            with shard_ctx.sharding_rules(rules):
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, batch_sh,
                                  NamedSharding(mesh, P())),
                    donate_argnums=(0,),
                ).lower(state_specs, bspecs, rng_spec)
        elif info["kind"] == "prefill":
            params_specs, cfg = specs_lib.param_specs(arch, serve=True)
            rules = sh.activation_rules(cfg, mesh, batch=info["batch"])
            p_sh = sh.tree_shardings(params_specs, cfg, mesh, serve=True,
                                     rules=rules)
            bspecs = specs_lib.batch_specs(arch, shape)
            if cfg.family == "vlm":
                bspecs = dict(bspecs)
                bspecs.pop("tokens")
                bspecs["embeds"] = jax.ShapeDtypeStruct(
                    (info["batch"], info["seq"], cfg.d_model), jnp.bfloat16)
            bspecs.pop("labels")
            batch_sh = _shardings_for_batch(bspecs, mesh, rules)
            step = specs_lib.make_prefill_step(cfg)
            with shard_ctx.sharding_rules(rules):
                lowered = jax.jit(
                    step, in_shardings=(p_sh, batch_sh),
                ).lower(params_specs, bspecs)
        else:  # decode
            params_specs, cfg = specs_lib.param_specs(arch, serve=True)
            rules = sh.activation_rules(cfg, mesh, batch=info["batch"])
            p_sh = sh.tree_shardings(params_specs, cfg, mesh, serve=True,
                                     rules=rules)
            dspecs = specs_lib.decode_specs(arch, shape)
            cache_sh = _shardings_for_cache(dspecs["cache"], mesh, rules)
            dp = rules["batch"]
            tok_sh = NamedSharding(mesh, P(dp))
            step = specs_lib.make_serve_step(cfg)
            with shard_ctx.sharding_rules(rules):
                lowered = jax.jit(
                    step,
                    in_shardings=(p_sh, cache_sh, tok_sh, tok_sh),
                    donate_argnums=(1,),
                ).lower(params_specs, dspecs["cache"], dspecs["tokens"],
                        dspecs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    coll, coll_counts = collective_bytes(hlo)
    corrected = hlo_cost.analyze(hlo)   # trip-count-corrected per-device
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    out = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": info["kind"],
        "devices": int(np.prod(list(mesh.shape.values()))),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_dict(compiled),
        "cost": _cost_dict(compiled),
        "corrected": {
            "dot_flops": corrected.dot_flops,
            "bytes_accessed": corrected.bytes_accessed,
            "collective_bytes": corrected.collective_bytes,
            "collective_counts": corrected.collective_counts,
            "warnings": corrected.warnings[:10],
        },
        "collective_bytes": coll,
        "collective_counts": coll_counts,
        "fallbacks": sh.fallbacks(get_config(arch), mesh,
                                  batch=info["batch"]),
        "model_params": get_config(arch).param_count(),
        "active_params": get_config(arch).active_param_count(),
        "tokens_per_step": (specs_lib.SHAPES[shape]["batch"]
                            * specs_lib.SHAPES[shape]["seq"]
                            if info["kind"] == "train"
                            else specs_lib.SHAPES[shape]["batch"]),
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(specs_lib.SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--hoist", action="store_true",
                    help="hoist weight fake-quant out of the microbatch "
                         "scan (perf experiment)")
    ap.add_argument("--tag", default="", help="suffix for result files")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(specs_lib.SHAPES) if args.shape == "all" \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if multi else '16x16'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    n_ok += 1
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    res = run_cell(arch, shape, multi, hoist=args.hoist,
                                   save_hlo=args.save_hlo and
                                   os.path.join(args.save_hlo, tag + ".hlo"))
                    if "skipped" in res:
                        n_skip += 1
                        print(f"[dryrun] {tag}: SKIP ({res['skipped'][:60]})",
                              flush=True)
                    else:
                        n_ok += 1
                        m = res["memory"]
                        print(f"[dryrun] {tag}: OK compile={res['compile_s']}s"
                              f" arg={m.get('argument_size_in_bytes', 0)/2**30:.2f}GiB"
                              f" temp={m.get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
                              f" flops={res['cost'].get('flops', 0):.3g}",
                              flush=True)
                except Exception:
                    n_fail += 1
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "error": traceback.format_exc()}
                    print(f"[dryrun] {tag}: FAIL\n{res['error']}",
                          flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed",
          flush=True)
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
