"""Block-size autotuner for the Pallas backends.

Cache model
-----------
A JSON file mapping a string key

    "<op>|shape=MxKxN|p=<bits>|dtype=<name>|platform=<jax backend>"

to ``{"blocks": {"block_m": ..., ...}, "us": <best measured microseconds>,
"candidates": <n tried>}``. Lookup (:func:`lookup`) is a pure dict read —
safe at jit-trace time, where timing is impossible — and returns ``{}`` on
a miss so callers fall back to the kernels' static defaults.

Measurement (:func:`autotune_op`) is explicit and happens *outside* any
trace: benchmarks (``runtime_proxy.py --autotune``) or an operator's
one-off script time each candidate with ``block_until_ready`` and persist
the winner. The cache location is ``$SONIQ_AUTOTUNE_CACHE`` (a file path)
or ``~/.cache/soniq/autotune.json``; nothing is ever written unless a
measurement runs.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

ENV_CACHE = "SONIQ_AUTOTUNE_CACHE"

# The tunable-op vocabulary: which ops have block knobs, and the
# documented values mirroring each kernel signature's defaults (see
# kernels/*.py headers for the VMEM budget math). The dispatch path does
# NOT read these — a cache miss returns {} and the kernel signature
# defaults apply — they exist for operators/tests enumerating what can be
# tuned (tests assert the keys stay a subset of backend OPS).
DEFAULT_BLOCKS: Dict[str, Dict[str, int]] = {
    "packed_segment_matmul": {"block_m": 256, "block_n": 128,
                              "block_k": 256},
    "fused_act_segment_matmul": {"block_m": 256, "block_n": 128,
                                 "block_k": 256},
    "quantize_pack": {"block_k": 256, "block_n": 256},
    "noise_inject": {"block_k": 256, "block_n": 256},
    "fake_quant": {"block_m": 256, "block_k": 256},
    # Quantized-KV flash decode: shape key is (query rows B*Hk*S*G, ring
    # length T, head_dim D); block_t tiles the ring inner loop.
    "qkv_attn_decode": {"block_t": 256},
    # Paged flash decode: shape key is (query rows B*Hk*S*G, table length
    # NP, page_size, head_dim D); block_t tiles *within* a page, so it is
    # snapped to a divisor of page_size.
    "qkv_attn_decode_paged": {"block_t": 128},
}

_CACHE: Optional[Dict[str, Dict]] = None
_CACHE_FILE: Optional[str] = None


def cache_path() -> Path:
    return Path(os.environ.get(ENV_CACHE)
                or Path.home() / ".cache" / "soniq" / "autotune.json")


def cache_key(op: str, shape: Sequence[int], p: int, dtype,
              platform: Optional[str] = None, backend: str = "") -> str:
    """``backend`` is the backend *name* (pallas_interpret vs
    pallas_mosaic time very differently yet share a jax platform — they
    must not share cache entries)."""
    if platform is None:
        import jax
        platform = jax.default_backend()
    dims = "x".join(str(int(d)) for d in shape)
    key = f"{op}|shape={dims}|p={int(p)}|dtype={dtype}|platform={platform}"
    return f"{key}|backend={backend}" if backend else key


def _read_file(path) -> Dict[str, Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _load() -> Dict[str, Dict]:
    global _CACHE, _CACHE_FILE
    path = str(cache_path())
    if _CACHE is None or _CACHE_FILE != path:
        _CACHE_FILE = path
        _CACHE = _read_file(path)
    return _CACHE


def invalidate() -> None:
    """Drop the in-memory cache (next lookup re-reads the file)."""
    global _CACHE
    _CACHE = None


def lookup(op: str, *, shape: Sequence[int], p: int, dtype,
           platform: Optional[str] = None,
           backend: str = "") -> Dict[str, int]:
    """Cached block config for this (op, shape, dtype, platform, backend),
    or ``{}`` (use kernel defaults). Trace-time safe."""
    entry = _load().get(cache_key(op, shape, p, dtype, platform, backend))
    if not entry:
        return {}
    return {k: int(v) for k, v in entry["blocks"].items()}


def save_entry(key: str, blocks: Dict[str, int], us: float,
               candidates: int) -> None:
    """Persist one tuned entry with a read-merge-save cycle.

    Concurrent sweeps (e.g. two ``runtime_proxy.py --autotune`` processes
    covering different ``--backends``) share the cache file: each save
    re-reads the *live* file — never the possibly stale in-memory snapshot
    — merges its one entry in, and publishes atomically via a
    uniquely-named temp file + ``os.replace``. The worst interleaving
    loses one entry to a later merge, never the whole file to a torn or
    shared-temp-file write."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    cache = _read_file(path)
    cache[key] = {"blocks": blocks, "us": round(float(us), 2),
                  "candidates": int(candidates)}
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    invalidate()


def _divisor_candidates(total: int, multiple: int,
                        wants: Sequence[int]) -> List[int]:
    from repro.kernels.packed_matmul import fit_block
    out: List[int] = []
    for w in wants:
        d = fit_block(total, w, multiple)
        if d not in out:
            out.append(d)
    return out


def candidates_for(op: str, shape: Sequence[int]) -> List[Dict[str, int]]:
    """A small grid of legal block configs for ``op`` at ``shape``
    (divisor-snapped, so every candidate tiles exactly)."""
    from repro.core.qtypes import GROUP_SIZE
    if op in ("packed_segment_matmul", "fused_act_segment_matmul"):
        m, kp, n = shape
        return [{"block_m": bm, "block_n": bn, "block_k": bk}
                for bm in _divisor_candidates(m, 1, (64, 128, 256, 512))
                for bn in _divisor_candidates(n, 1, (128, 256))
                for bk in _divisor_candidates(kp, GROUP_SIZE,
                                              (128, 256, 512))]
    if op == "fake_quant":
        m, k = shape
        return [{"block_m": bm, "block_k": bk}
                for bm in _divisor_candidates(m, 1, (64, 128, 256, 512))
                for bk in _divisor_candidates(k, GROUP_SIZE,
                                              (128, 256, 512))]
    if op == "qkv_attn_decode":
        _m, t, _d = shape
        return [{"block_t": bt}
                for bt in _divisor_candidates(t, 1, (128, 256, 512, 1024))]
    if op == "qkv_attn_decode_paged":
        _m, _np, ps, _d = shape
        return [{"block_t": bt}
                for bt in _divisor_candidates(ps, 1, (8, 16, 32, 64, 128))]
    k, n = shape
    return [{"block_k": bk, "block_n": bn}
            for bk in _divisor_candidates(k, GROUP_SIZE, (128, 256, 512))
            for bn in _divisor_candidates(n, 1, (128, 256, 512))]


def measure(fn, iters: int = 3) -> float:
    """Best-of-``iters`` wall time of ``fn()`` in microseconds (first call
    excluded — it compiles)."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def autotune_op(call, op: str, *, shape: Sequence[int], p: int, dtype,
                candidates: Optional[List[Dict[str, int]]] = None,
                iters: int = 3, backend: str = "") -> Dict[str, int]:
    """Time ``call(**blocks)`` over the candidate grid, persist the winner
    under this (op, shape, dtype, platform, backend) key, and return its
    blocks.

    ``call`` must run the real op at the real shape (closures over the
    operands); it is invoked outside any trace.
    """
    cands = candidates if candidates is not None \
        else candidates_for(op, shape)
    if not cands:
        return {}
    best_blocks, best_us, last_err = None, float("inf"), None
    for blocks in cands:
        try:
            us = measure(lambda: call(**blocks), iters=iters)
        except Exception as e:         # illegal tiling for this shape
            last_err = e
            continue
        if us < best_us:
            best_blocks, best_us = blocks, us
    if best_blocks is None:
        # Every candidate failing means the kernel itself is broken at
        # this shape, not a tiling quirk — don't pretend tuning succeeded.
        print(f"[autotune] {op} shape={tuple(shape)}: all {len(cands)} "
              f"candidates failed (last: {last_err!r}); using defaults",
              file=sys.stderr)
        return {}
    save_entry(cache_key(op, shape, p, dtype, backend=backend),
               best_blocks, best_us, len(cands))
    return best_blocks
