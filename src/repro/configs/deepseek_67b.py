"""DeepSeek-67B [arXiv:2401.02954; hf] — llama-arch dense: 95L d_model=8192
64H (GQA kv=8) d_ff=22016 vocab=102400."""
from .base import ArchConfig
from .registry import register


@register("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b", family="dense",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=102400, head_dim=128,
        rope_theta=1e4, mlp_act="swiglu", tie_embeddings=False,
        source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
    )
