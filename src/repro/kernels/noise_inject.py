"""Fused Phase-I perturbation kernel:  w <- clip(w + sigma(s)*eps, +-(2-sigma)).

eps ~ U(-1, 1) is generated *inside* the kernel from a counter-based hash of
the global element index (kernels/prng.py) — no HBM round-trip for the noise
tensor, which is what makes Phase I's extra memory traffic ~zero vs. plain
training (the GPU-paper analogue materializes eps; this is the TPU-native
fusion). Grid (K/bk, N/bn); pure VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.qtypes import GROUP_SIZE
from . import prng


def _kernel(seed_ref, w_ref, s_ref, o_ref, *, bk: int, bn: int, n_total: int):
    i, j = pl.program_id(0), pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)
    sig = jax.nn.sigmoid(s_ref[...].astype(jnp.float32))      # [bk//16, 1]
    sig = jnp.repeat(sig, GROUP_SIZE, axis=0)                 # [bk, 1]
    rows = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 0) \
        + jnp.uint32(i * bk)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bk, bn), 1) \
        + jnp.uint32(j * bn)
    idx = rows * jnp.uint32(n_total) + cols                   # global index
    eps = prng.uniform_pm1(idx, seed_ref[0])
    out = w + sig * eps
    lim = 2.0 - sig
    o_ref[...] = jnp.clip(out, -lim, lim).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "block_n",
                                             "interpret"))
def noise_inject(w, s, seed, *, block_k: int = 256, block_n: int = 256,
                 interpret: bool = True):
    """w [K, N], s [K//16] -> perturbed + clipped w (same dtype as w)."""
    from .packed_matmul import fit_block
    k, n = w.shape
    bk = fit_block(k, block_k, GROUP_SIZE)
    bn = fit_block(n, block_n)
    s2d = jnp.asarray(s, jnp.float32).reshape(-1, 1)
    seed_arr = jnp.asarray([seed], jnp.uint32)
    kern = functools.partial(_kernel, bk=bk, bn=bn, n_total=n)
    return pl.pallas_call(
        kern,
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),   # seed (SMEM-sized)
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // GROUP_SIZE, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), w.dtype),
        interpret=interpret,
    )(seed_arr, w, s2d)
