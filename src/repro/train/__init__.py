from . import checkpoint, ft, loop, state
