"""Quantized-KV decode (DESIGN.md §12): ring-write semantics, the
``qkv_attn_decode`` backend op, engine/backend parity at ``kv_bits=4``,
and the payload-byte accounting behind the "4x cache bytes" claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import base as backend_base
from repro.backend import pallas as pallas_backend
from repro.backend import registry
from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine, kv_quant
from repro.serve.scheduler import Request


def _fill_ring(cache, key, batch, heads, dim, positions):
    for t in positions:
        k_new = jax.random.normal(jax.random.fold_in(key, t),
                                  (batch, 1, heads, dim))
        cache = kv_quant.update_qkv_cache(
            cache, k_new, -k_new, jnp.asarray([t] * batch, jnp.int32))
    return cache


# ------------------------------------------------- masked-lane writes ----
def test_masked_lane_does_not_clobber_full_ring():
    """THE regression (satellite 1): with a full ring, a pos=-1 lane used
    to wrap to slot cache_len-1 (-1 % cache_len), overwriting that entry's
    codes and stamping its pos to -1 — a silent eviction of the oldest
    resident token. Masked lanes must drop, exactly like the fp ring
    write."""
    cache_len, h, d = 8, 2, 16
    key = jax.random.PRNGKey(0)
    cache = _fill_ring(kv_quant.init_qkv_cache(2, cache_len, h, d),
                       key, 2, h, d, range(cache_len))       # ring full
    before = {k: np.asarray(v) for k, v in cache.items()}
    # row 0 decodes position 8 (wraps to slot 0); row 1 is an idle lane
    k_new = jax.random.normal(jax.random.fold_in(key, 99), (2, 1, h, d))
    cache = kv_quant.update_qkv_cache(cache, k_new, -k_new,
                                      jnp.asarray([8, -1], jnp.int32))
    for name in cache:                       # row 1 bitwise untouched
        np.testing.assert_array_equal(np.asarray(cache[name][1]),
                                      before[name][1], err_msg=name)
    assert kv_quant.slot_lengths(cache).tolist() == [cache_len, cache_len]
    assert int(cache["pos"][0, 0]) == 8      # row 0's wrap write landed
    assert int(cache["pos"][1, cache_len - 1]) == cache_len - 1


def test_masked_lane_chunk_padding_drops():
    """S>1 chunks: padding lanes (pos=-1) inside a prefill chunk must not
    write; real lanes of the same chunk must land in their slots."""
    cache = kv_quant.init_qkv_cache(1, 8, 2, 16)
    key = jax.random.PRNGKey(1)
    k_new = jax.random.normal(key, (1, 4, 2, 16))
    pos = jnp.asarray([[0, 1, 2, -1]], jnp.int32)    # 3 real + 1 padding
    cache = kv_quant.update_qkv_cache(cache, k_new, -k_new, pos)
    assert np.asarray(cache["pos"][0]).tolist() == \
        [0, 1, 2, -1, -1, -1, -1, -1]
    assert kv_quant.slot_lengths(cache).tolist() == [3]


def test_chunked_write_equals_token_by_token():
    """A [B, S, H, D] chunk write must land byte-identically to S
    single-token writes (the fp ring's S>1 contract)."""
    key = jax.random.PRNGKey(2)
    kv = jax.random.normal(key, (2, 5, 2, 16))
    pos = jnp.asarray([[3, 4, 5, 6, 7], [0, 1, 2, -1, -1]], jnp.int32)
    chunked = kv_quant.update_qkv_cache(
        kv_quant.init_qkv_cache(2, 8, 2, 16), kv, -kv, pos)
    stepped = kv_quant.init_qkv_cache(2, 8, 2, 16)
    for s in range(5):
        stepped = kv_quant.update_qkv_cache(stepped, kv[:, s:s + 1],
                                            -kv[:, s:s + 1], pos[:, s:s + 1])
    for name in chunked:
        np.testing.assert_array_equal(np.asarray(chunked[name]),
                                      np.asarray(stepped[name]),
                                      err_msg=name)


def test_stacked_layer_write_touches_one_layer():
    """layer_idx: stacked [L, ...] leaves are scattered in place at
    [layer_idx, b, slot]; other layers stay bitwise untouched and the
    written layer matches the non-stacked write."""
    L = 3
    flat = kv_quant.init_qkv_cache(2, 8, 2, 16)
    stacked = {k: jnp.repeat(v[None], L, axis=0) for k, v in flat.items()}
    key = jax.random.PRNGKey(3)
    k_new = jax.random.normal(key, (2, 1, 2, 16))
    pos = jnp.asarray([0, -1], jnp.int32)            # one masked lane too
    got = kv_quant.update_qkv_cache(stacked, k_new, -k_new, pos,
                                    layer_idx=1)
    want_layer = kv_quant.update_qkv_cache(flat, k_new, -k_new, pos)
    for name in got:
        np.testing.assert_array_equal(np.asarray(got[name][1]),
                                      np.asarray(want_layer[name]),
                                      err_msg=name)
        for l in (0, 2):
            np.testing.assert_array_equal(np.asarray(got[name][l]),
                                          np.asarray(stacked[name][l]),
                                          err_msg=f"{name}[{l}]")


# ------------------------------------------------- backend op parity ----
def _toy_cache_and_q(seed=0, b=2, t=16, hk=2, d=32, g=2, s=3):
    key = jax.random.PRNGKey(seed)
    cache = _fill_ring(kv_quant.init_qkv_cache(b, t, hk, d), key, b, hk, d,
                       range(10))
    q = jax.random.normal(jax.random.fold_in(key, 77), (b, s, hk, g, d))
    q_pos = jnp.asarray([[7, 8, 9], [5, -1, 6]], jnp.int32)
    return cache, q, q_pos


@pytest.mark.parametrize("window", [None, 4])
def test_qkv_attn_kernel_matches_oracle(window):
    """The Pallas flash-decode kernel (in-loop unpack + per-(slot, head)
    scales) must match the dequantize-everything jnp oracle to fp32
    tolerance, masked lanes and sliding window included — and must
    actually dispatch (trace-time counter)."""
    cache, q, q_pos = _toy_cache_and_q()
    ref = registry.get("xla_ref").qkv_attn_decode(q, cache, q_pos,
                                                  window=window)
    before = pallas_backend.qkv_attn_call_count()
    got = registry.get("pallas_interpret").qkv_attn_decode(
        q, cache, q_pos, window=window)
    assert pallas_backend.qkv_attn_call_count() == before + 1
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    assert np.isfinite(np.asarray(got)).all()


def test_qkv_attn_oracle_matches_fp_attention_closely():
    """Sanity on the numerics: the q4 attention output stays within the
    documented KV round-trip error of full-precision attention."""
    b, t, hk, d, g = 1, 8, 2, 32, 2
    key = jax.random.PRNGKey(5)
    kv = jax.random.normal(key, (b, t, hk, d))
    cache = kv_quant.init_qkv_cache(b, t, hk, d)
    cache = kv_quant.update_qkv_cache(
        cache, kv, -kv, jnp.arange(t, dtype=jnp.int32)[None])
    q = jax.random.normal(jax.random.fold_in(key, 9), (b, 1, hk, g, d))
    q_pos = jnp.full((b, 1), t - 1, jnp.int32)
    got = registry.get("xla_ref").qkv_attn_decode(q, cache, q_pos)
    want = backend_base.qkv_attn_jnp(
        q, kv, -kv, jnp.arange(t, dtype=jnp.int32)[None], q_pos)
    rel = np.linalg.norm(np.asarray(got - want)) / \
        np.linalg.norm(np.asarray(want))
    assert rel < 0.15                        # ~10% norm-relative at 4 bits


# ---------------------------------------------------- engine parity ----
def _tiny_cfg():
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode="qat"))


@pytest.fixture(scope="module")
def served():
    cfg = _tiny_cfg()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _mixed_requests(rng, lens=(3, 7, 5, 2), news=(4, 8, 3, 6)):
    return [Request(prompt=rng.integers(1, 100, (l,)), max_new_tokens=n,
                    seed=i) for i, (l, n) in enumerate(zip(lens, news))]


def test_kv4_engine_parity_with_lockstep(served):
    """kv_bits=4 acceptance: DecodeEngine greedy tokens identical to
    LockstepEngine on the same packed checkpoint (runs on whichever
    backend the SONIQ_BACKEND CI matrix pins)."""
    cfg, params = served
    ecfg = engine.EngineConfig(max_batch=3, cache_len=64, prefill_chunk=4,
                               kv_bits=4)
    lock = engine.LockstepEngine(params, cfg, ecfg)
    cont = engine.DecodeEngine(params, cfg, ecfg)
    reqs = _mixed_requests(np.random.default_rng(0))
    ref = {i: lock.generate(r.prompt[None], r.max_new_tokens)[0]
           for i, r in enumerate(reqs)}
    got = {c.request_id: c.tokens for c in cont.serve(reqs)}
    for i in range(len(reqs)):
        np.testing.assert_array_equal(ref[i], got[i])


def test_kv4_cross_backend_token_identity(served):
    """kv_bits=4 acceptance: xla_ref (jnp oracle) and pallas_interpret
    (fused flash-decode kernel) agree token-for-token at temperature 0,
    and the kernel — not the fallback — served the Pallas leg."""
    cfg, params = served
    outs = {}
    for name in ("xla_ref", "pallas_interpret"):
        ecfg = engine.EngineConfig(max_batch=2, cache_len=64,
                                   prefill_chunk=4, backend=name,
                                   kv_bits=4)
        eng = engine.DecodeEngine(params, cfg, ecfg)
        before = pallas_backend.qkv_attn_call_count()
        got = {c.request_id: c.tokens
               for c in eng.serve(_mixed_requests(np.random.default_rng(1)))}
        outs[name] = {k - min(got): v for k, v in got.items()}
        dispatched = pallas_backend.qkv_attn_call_count() - before
        assert dispatched == (0 if name == "xla_ref" else 2), dispatched
    assert set(outs["xla_ref"]) == set(outs["pallas_interpret"])
    for k in outs["xla_ref"]:
        np.testing.assert_array_equal(outs["xla_ref"][k],
                                      outs["pallas_interpret"][k])


def test_kv4_reset_cache_slots_wipes_only_target_rows(served):
    """The continuous-batching admission wipe must cover the quantized
    family too: codes/scales zero, pos -1, other rows untouched."""
    cfg, params = served
    cache = lm.init_cache(cfg, 3, 16, np.float32, kv_bits=4)
    step = jax.jit(lambda p, c, t, q: lm.decode_step(p, cfg, c, t, q))
    c = cache
    for t in range(3):
        _, c = step(params, c, np.asarray([t + 1] * 3, np.int32),
                    np.asarray([t] * 3, np.int32))
    c2 = lm.reset_cache_slots(c, [1])
    kv0 = c2["groups"][0]["kv"]
    assert (np.asarray(kv0["pos"][:, 1]) == -1).all()
    for leaf in ("k_codes", "v_codes", "k_scale", "v_scale"):
        assert (np.asarray(kv0[leaf][:, 1]) == 0).all(), leaf
    old = c["groups"][0]["kv"]
    for row in (0, 2):
        for leaf in ("pos", "k_codes", "k_scale"):
            np.testing.assert_array_equal(np.asarray(kv0[leaf][:, row]),
                                          np.asarray(old[leaf][:, row]))


# ------------------------------------------------- byte accounting ----
def test_kv4_payload_bytes_at_least_3p5x_smaller(served):
    """The corrected accounting: K/V payload (codes + scales vs fp16 k/v)
    drops >= 3.5x; ``pos`` bookkeeping is identical in both families and
    excluded from the claim."""
    cfg, _ = served
    fp16 = lm.init_cache(cfg, 4, 64, jnp.float16, specs=True)
    q4 = lm.init_cache(cfg, 4, 64, jnp.float16, specs=True, kv_bits=4)
    fp_payload = kv_quant.cache_payload_bytes(fp16)
    q4_payload = kv_quant.cache_payload_bytes(q4)
    assert fp_payload / q4_payload >= 3.5
    assert kv_quant.cache_meta_bytes(fp16) == kv_quant.cache_meta_bytes(q4)
    # total = payload + meta, and the single-layer helper agrees
    one = kv_quant.init_qkv_cache(2, 8, 2, 16)
    assert kv_quant.cache_bytes(one) == \
        kv_quant.cache_payload_bytes(one) + kv_quant.cache_meta_bytes(one)


# --------------------------------------------- hypothesis properties ----
# Guarded import (not a module-level importorskip, which would skip the
# ring/write/parity tests above too): CI installs hypothesis and fails
# fast if the property tests would silently vanish from the run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False


if not HAVE_HYPOTHESIS:
    def test_property_tests_require_hypothesis():
        pytest.skip("hypothesis not installed — property tests skipped")
else:
    @st.composite
    def _roundtrip_cases(draw):
        b = draw(st.integers(1, 3))
        t = draw(st.integers(1, 6))
        h = draw(st.integers(1, 3))
        d = 2 * draw(st.integers(1, 32))
        seed = draw(st.integers(0, 2 ** 16))
        mag = draw(st.sampled_from([0.05, 1.0, 3.0, 50.0]))
        read_dtype = draw(st.sampled_from(["float32", "bfloat16"]))
        zero_row = draw(st.booleans())
        outlier_head = draw(st.booleans())
        return b, t, h, d, seed, mag, read_dtype, zero_row, outlier_head


    @settings(max_examples=40, deadline=None)
    @given(_roundtrip_cases())
    def test_quantize_kv_roundtrip_property(case):
        """Round-trip bound, property-tested: elementwise error <= the
        stored scale's half-step (+ read-dtype rounding) for every element
        the fp16 scale can represent, saturation (never inf) beyond it,
        zero rows decode to ~eps-scale noise, and outlier heads do not
        leak error into neighbours (per-head scales)."""
        b, t, h, d, seed, mag, read_dtype, zero_row, outlier_head = case
        x = jax.random.normal(jax.random.PRNGKey(seed), (b, t, h, d)) * mag
        if zero_row:
            x = x.at[0, 0].set(0.0)
        if outlier_head:
            x = x.at[:, :, 0].multiply(1000.0)
        codes, scale = kv_quant.quantize_kv(x)
        assert codes.dtype == jnp.uint8 and codes.shape == (b, t, h, d // 2)
        assert scale.dtype == jnp.float16
        y = np.asarray(kv_quant.dequantize_kv(codes, scale,
                                              jnp.dtype(read_dtype)),
                       np.float32)
        assert np.isfinite(y).all()        # fp16 scale saturates, never inf
        x32 = np.asarray(x, np.float32)
        err = np.abs(y - x32)
        s32 = np.asarray(scale, np.float32)
        # half-step * stored scale, widened for the bf16 read rounding
        slack = 1.06 if read_dtype == "bfloat16" else 1.02
        bound = s32 * 2.0 ** (1 - kv_quant.P_BITS) * slack + 1e-6
        in_range = np.abs(x32) <= kv_quant.GRID_MAX * s32 * 1.001
        assert (err <= bound + 0.01 * np.abs(y))[in_range].all()
        # beyond the representable range (abs-max overflowed the fp16
        # scale) values clip to the top of the stored grid
        assert (np.abs(y) <= kv_quant.GRID_MAX * s32 * 1.01).all()
        if zero_row:                   # eps-clamped scale, not NaN/Inf
            assert (np.abs(y[0, 0]) <= 2 * backend_base.ACT_SCALE_EPS).all()


    @st.composite
    def _ring_programs(draw):
        cache_len = draw(st.sampled_from([2, 4, 8]))
        b = draw(st.integers(1, 3))
        n_ops = draw(st.integers(1, 12))
        ops = []
        for _ in range(n_ops):
            kind = draw(st.sampled_from(["write", "mask_some", "reset",
                                         "evict"]))
            if kind in ("reset", "evict"):
                ops.append((kind, draw(st.integers(0, b - 1))))
            else:
                ops.append((kind, None))
        return cache_len, b, ops


    @settings(max_examples=30, deadline=None)
    @given(_ring_programs())
    def test_ring_wraparound_reset_evict_property(case):
        """Random interleavings of (masked) writes, slot resets and evictions
        against a pure-python model of the ring's pos bookkeeping — the
        quantized cache must track the fp cache's slot semantics exactly."""
        cache_len, b, ops = case
        h, d = 2, 8
        cache = kv_quant.init_qkv_cache(b, cache_len, h, d)
        model = [dict() for _ in range(b)]      # slot -> {ring_idx: pos}
        key = jax.random.PRNGKey(0)
        clock = [0] * b
        for step, (kind, arg) in enumerate(ops):
            if kind == "reset" or kind == "evict":
                cache = (kv_quant.evict_slot(cache, arg) if kind == "evict"
                         else kv_quant.reset_slots(cache, [arg]))
                model[arg] = {}
                clock[arg] = 0
            else:
                pos = []
                for row in range(b):
                    if kind == "mask_some" and (row + step) % 2:
                        pos.append(-1)
                    else:
                        pos.append(clock[row])
                        model[row][clock[row] % cache_len] = clock[row]
                        clock[row] += 1
                k_new = jax.random.normal(jax.random.fold_in(key, step),
                                          (b, 1, h, d))
                cache = kv_quant.update_qkv_cache(
                    cache, k_new, -k_new, jnp.asarray(pos, jnp.int32))
        got = np.asarray(cache["pos"])
        for row in range(b):
            want = np.full((cache_len,), -1, np.int64)
            for ring_idx, p in model[row].items():
                want[ring_idx] = p
            np.testing.assert_array_equal(got[row], want, err_msg=f"row {row}")
        np.testing.assert_array_equal(
            np.asarray(kv_quant.slot_lengths(cache)),
            [len(m) for m in model])
