"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (interpret=True on
CPU; identical semantics on TPU). They intentionally reuse the core
quantization library so kernel tests transitively pin down core semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as pack_lib
from repro.core import quant
from . import prng


def packed_segment_matmul_ref(x, wp, scales, p: int, *, act_quant: bool = False):
    """x [M, Kp] (f32/bf16) @ unpack_dequant(wp [Kp*p//8, N]) -> [M, N] f32.

    scales: per-16-channel-group [Kp//16] f32 or None.
    act_quant: snap x to the p-bit grid first (x must already be in scale
    units — the wrapper divides by the activation scale).
    """
    kp = wp.shape[0] * (8 // p)
    u = pack_lib.unpack_codes(wp, p, kp)
    wd = quant.dequantize_int(u, p)
    if scales is not None:
        s_full = jnp.repeat(scales.astype(jnp.float32), 16,
                            total_repeat_length=kp)
        wd = wd * s_full[:, None]
    xs = jnp.asarray(x, jnp.float32)
    if act_quant:
        xs = quant.snap_to_grid(xs, p)
    return jax.lax.dot_general(
        xs, wd.astype(jnp.float32),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def packed_matmul_ref(x, w4, w2, w1, scales, *, act_quant: bool = False):
    """Full mixed [K4|K2|K1] packed matmul (segments contiguous along K)."""
    k4, k2, k1 = w4.shape[0] * 2, w2.shape[0] * 4, w1.shape[0] * 8
    y = jnp.zeros(x.shape[:-1] + (max(w4.shape[-1], w2.shape[-1],
                                      w1.shape[-1]),), jnp.float32)
    off = 0
    goff = 0
    for wp, p, kp in ((w4, 4, k4), (w2, 2, k2), (w1, 1, k1)):
        if kp == 0:
            continue
        seg_scales = None if scales is None else \
            jax.lax.dynamic_slice_in_dim(scales, goff, kp // 16)
        y = y + packed_segment_matmul_ref(
            x[..., off:off + kp], wp, seg_scales, p, act_quant=act_quant)
        off += kp
        goff += kp // 16
    return y


def quantize_pack_ref(w, p: int, scales=None):
    """w [K, N] f32 -> packed [K*p//8, N] uint8 codes on the SMOL grid."""
    k = w.shape[0]
    ws = jnp.asarray(w, jnp.float32)
    if scales is not None:
        s_full = jnp.repeat(scales.astype(jnp.float32), 16,
                            total_repeat_length=k)
        ws = ws / s_full[:, None]
    u = quant.quantize_to_int(ws, p).astype(jnp.uint8)
    return pack_lib.pack_codes(u, p)


def noise_inject_ref(w, s, seed: int, *, group_size: int = 16):
    """w [K, N] + sigma(s)*eps, clipped to +-(2 - sigma); eps from the same
    counter-based hash the kernel uses -> exact equality with the kernel."""
    w = jnp.asarray(w, jnp.float32)
    k, n = w.shape
    idx = (jnp.arange(k, dtype=jnp.uint32)[:, None] * jnp.uint32(n)
           + jnp.arange(n, dtype=jnp.uint32)[None, :])
    eps = prng.uniform_pm1(idx, seed)
    sig = jnp.repeat(jax.nn.sigmoid(jnp.asarray(s, jnp.float32)), group_size,
                     total_repeat_length=k)[:, None]
    out = w + sig * eps
    return jnp.clip(out, -(2.0 - sig), 2.0 - sig)
