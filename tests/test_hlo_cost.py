"""Validate the trip-count-corrected HLO cost analyzer against unrolled
ground truth and hand-computed collective traffic."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_match_unrolled():
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 512), jnp.float32)

    def scanned(x, w):
        def f(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(f, x, None, length=8)
        return y.sum()

    def unrolled(x, w):
        c = x
        for _ in range(8):
            c = jnp.tanh(c @ w)
        return c.sum()

    t_scan = hlo_cost.analyze(_compile_text(scanned, x, w))
    t_unroll = hlo_cost.analyze(_compile_text(unrolled, x, w))
    analytic = 8 * 2 * 128 * 512 * 512
    assert t_scan.dot_flops == pytest.approx(analytic, rel=0.01)
    assert t_unroll.dot_flops == pytest.approx(analytic, rel=0.01)
    # and the corrected scan bytes should be close to unrolled bytes
    assert t_scan.bytes_accessed > 0.5 * t_unroll.bytes_accessed


def test_nested_scan_multipliers():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def nested(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    t = hlo_cost.analyze(_compile_text(nested, x))
    analytic = 5 * 3 * 2 * 64 * 64 * 64
    assert t.dot_flops == pytest.approx(analytic, rel=0.01)


def test_grad_flops_roughly_3x_forward():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)

    def fwd(x, w):
        def f(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(f, x, None, length=4)
        return (y ** 2).sum()

    t_f = hlo_cost.analyze(_compile_text(fwd, x, w))
    t_g = hlo_cost.analyze(_compile_text(
        lambda x, w: jax.grad(fwd, argnums=1)(x, w), x, w))
    ratio = t_g.dot_flops / t_f.dot_flops
    assert 2.5 < ratio < 3.6      # dL/dx and dL/dw matmuls ~ 3x fwd


def test_dot_flops_with_batch_dims():
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)

    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    t = hlo_cost.analyze(_compile_text(f, a, b))
    assert t.dot_flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_collectives_inside_loops_multiplied():
    # shard_map psum inside a scan: collective bytes must scale by trips.
    import os
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via test_dryrun subprocess)")


def test_parse_computations_smoke():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    txt = _compile_text(lambda x: (x @ x).sum(), x)
    comps, entry = hlo_cost.parse_computations(txt)
    assert entry is not None and entry in comps
    assert any(i.op == "dot" for c in comps.values() for i in c.instrs) or \
        any("dot" in i.op for c in comps.values() for i in c.instrs)
