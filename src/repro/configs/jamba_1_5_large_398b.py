"""Jamba-1.5-Large-398B [arXiv:2403.19887; hf] — hybrid Mamba+attention
1:7 interleave with MoE 16e top-2 every other layer: 72L d_model=8192 64H
(GQA kv=8) d_ff=24576 vocab=65536, ssm_state=128.

Note: Jamba's released checkpoints use Mamba-1 mixers; this framework's SSM
block is Mamba2/SSD (DESIGN.md §5) — same interleave structure.
"""
from .base import ArchConfig
from .registry import register


@register("jamba-1.5-large-398b")
def jamba() -> ArchConfig:
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536, head_dim=128,
        rope_theta=1e4, mlp_act="swiglu",
        num_experts=16, top_k=2, moe_every=2,
        ssm_state=128, ssm_expand=2,
        attn_every=8, attn_offset=3,
        tie_embeddings=False,
        # 398B at 10+ B/param of fp32 state exceeds 16 GiB/chip x 256; the
        # production configuration is bf16 params + reduced-precision Adam
        # moments (see AdamWConfig.moment_dtype) — DESIGN.md §4.
        param_dtype="bfloat16",
        source="arXiv:2403.19887/2408.12570; hf:ai21labs/AI21-Jamba-1.5-Large",
    )
