"""Feed-forward blocks (SwiGLU / GELU), all matmuls SmolLinear."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import smol
from repro.core.qtypes import QuantConfig
from .common import activation
from .shard import shard


def mlp_init(key, d_model: int, d_ff: int, qcfg: QuantConfig, *,
             act: str = "swiglu", use_bias: bool = False,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    p = {"up": smol.linear_init(ks[0], d_model, d_ff, qcfg,
                                use_bias=use_bias, dtype=dtype),
         "down": smol.linear_init(ks[1], d_ff, d_model, qcfg,
                                  use_bias=use_bias, dtype=dtype)}
    if act == "swiglu":
        p["gate"] = smol.linear_init(ks[2], d_model, d_ff, qcfg,
                                     use_bias=use_bias, dtype=dtype)
    return p


def mlp_apply(params: Dict, x, qcfg: QuantConfig, rng=None, *,
              act: str = "swiglu"):
    rngs = [None] * 3 if rng is None else list(jax.random.split(rng, 3))
    h = smol.linear_apply(params["up"], x, qcfg, rngs[0])
    h = shard(h, "batch", "seq", "ff")
    if act == "swiglu":
        g = smol.linear_apply(params["gate"], x, qcfg, rngs[1])
        h = jax.nn.silu(g) * h
    else:
        h = activation(act)(h)
    y = smol.linear_apply(params["down"], h, qcfg, rngs[2])
    return shard(y, "batch", "seq", "embed")
