"""``python -m repro.analysis`` — the analyzer CLI (DESIGN.md §15).

Modes::

    python -m repro.analysis                  # lint src/ against baseline
    python -m repro.analysis --check          # lint + jaxpr audits (CI leg)
    python -m repro.analysis --json           # machine-readable report
    python -m repro.analysis --list-rules     # rule table with rationales
    python -m repro.analysis --write-baseline # grandfather current findings
    python -m repro.analysis path.py other/   # lint specific paths

Exit status: 0 clean, 1 findings, 2 bad invocation. ``--check`` is what
CI's static-analysis leg runs per backend (``--backends`` defaults to the
two-way CPU matrix).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import lint as lint_mod

# src/repro/analysis/__main__.py -> repo root
_REPO_ROOT = Path(__file__).resolve().parents[3]
_DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"
_DEFAULT_BACKENDS = "xla_ref,pallas_interpret"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SONIQ-specific static analyzer: AST lint (SQ rules) "
                    "+ jaxpr dtype/donation/recompile audits.")
    p.add_argument("paths", nargs="*", type=Path,
                   help="files/directories to lint (default: the repo's "
                        "src/ tree)")
    p.add_argument("--check", action="store_true",
                   help="also run the trace-time jaxpr audits (what CI "
                        "runs); exit 1 on any finding")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit one JSON report on stdout")
    p.add_argument("--backends", default=_DEFAULT_BACKENDS,
                   help="comma-separated backend names for the jaxpr "
                        f"audits (default: {_DEFAULT_BACKENDS})")
    p.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE,
                   help="baseline file of grandfathered violations "
                        "(default: the committed repro/analysis/"
                        "baseline.json)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline (report everything)")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline file with the currently "
                        "standing lint violations and exit 0")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table with one-line rationales")
    p.add_argument("--skip-jaxpr", action="store_true",
                   help="with --check: lint only (used by the lint-speed "
                        "CI shard)")
    p.add_argument("--no-train", action="store_true",
                   help="with --check: skip the train-step jaxpr audit")
    return p


def _print_rules() -> None:
    for r in lint_mod.all_rules():
        print(f"{r.code}  {r.name:<24} {r.rationale}")


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        _print_rules()
        return 0

    paths = args.paths or [_REPO_ROOT / "src"]
    for p in paths:
        if not Path(p).exists():
            print(f"error: no such path {p}", file=sys.stderr)
            return 2

    baseline_path = None if args.no_baseline else args.baseline
    result = lint_mod.lint_paths(paths, baseline=baseline_path)

    if args.write_baseline:
        entries = lint_mod.baseline_entries(result.violations
                                            + result.baselined)
        args.baseline.write_text(json.dumps(entries, indent=1,
                                            sort_keys=True) + "\n")
        print(f"wrote {len(entries)} baseline entries to {args.baseline}")
        return 0

    audit_report, audit_issues = None, []
    if args.check and not args.skip_jaxpr:
        from . import jaxpr_checks
        backends = [b.strip() for b in args.backends.split(",") if b.strip()]
        audit_report, audit_issues = jaxpr_checks.run_audits(
            backends, train=not args.no_train)

    findings = len(result.violations) + len(audit_issues)
    if args.as_json:
        out = {
            "ok": findings == 0,
            "violations": [v.to_json() for v in result.violations],
            "suppressed": [s.to_json() for s in result.suppressed],
            "baselined": [v.to_json() for v in result.baselined],
            "audit_issues": [i.to_json() for i in audit_issues],
        }
        if audit_report is not None:
            out["audit_report"] = audit_report
        print(json.dumps(out, indent=1, default=str))
        return 1 if findings else 0

    for v in result.violations:
        print(v.format())
    for i in audit_issues:
        print(i.format())
    tail = (f"{len(result.violations)} violation(s), "
            f"{len(result.suppressed)} suppressed, "
            f"{len(result.baselined)} baselined")
    if args.check and not args.skip_jaxpr:
        tail += f", {len(audit_issues)} audit issue(s)"
    status = "FAILED" if findings else "OK"
    print(f"soniq-analysis {status}: {tail}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
