"""`repro.analysis` — the SONIQ-specific static analyzer (DESIGN.md §15–16).

SONIQ's parity claim rests on the deployed path executing *exactly* the
discrete arithmetic trained against: one silent fp promotion inside a
packed segment GEMM, one unmasked ring scatter, or one kernel call that
bypasses the ``Backend`` registry breaks that contract without failing any
unit test — until it corrupts tokens under traffic. PRs 2–7 each
hand-fixed another instance of the same few hazard classes; this package
makes those classes *unwritable*:

* :mod:`repro.analysis.lint` — a stdlib-``ast`` linter whose rules
  (SQ001–SQ007) codify the bug classes from CHANGES.md, with inline
  ``# soniq-lint: disable=SQxxx(reason)`` suppressions and a committed
  baseline file for grandfathered violations.
* :mod:`repro.analysis.dataflow` — interprocedural scale dataflow
  (SQ008): tags abs-max-produced values as scale-like and propagates
  them across returns, call arguments, pytree packing and closures,
  flagging any divide (or reciprocal-multiply) by a scale that no path
  clamps — the cross-function gap the intraprocedural SQ002 cannot see.
* :mod:`repro.analysis.jaxpr_checks` — trace-time audits: lower the
  jitted ``DecodeEngine`` step family per registered backend and walk the
  ClosedJaxpr (no narrowing/f64 dtype converts inside quantized
  segment-GEMM subtrees, no host callbacks in serve steps), report
  buffer-donation coverage, and assert each engine step function compiles
  exactly once across a mixed-length traffic trace.
* :mod:`repro.analysis.kernel_audit` — Pallas kernel contract audit:
  grid/BlockSpec divisibility and static in-bounds over every registered
  arch x autotune block candidate, kernel-body dtype discipline (fp32
  accumulation, no f64, no narrowing), and a 1:1 kernel↔Backend-op
  mapping with parity oracles and no orphans.
* :mod:`repro.analysis.model_check` — explicit-state BFS model checker
  for the host-side ``PagePool``: every op interleaving on a small pool,
  asserting the shared invariant set (refcounts, partition, no shared
  writes, poison-cancel) and emitting a minimal violating trace.
* ``python -m repro.analysis`` — the CLI (human, JSON and SARIF output)
  that CI's static-analysis leg runs with ``--check``.
"""
from __future__ import annotations

from .dataflow import (  # noqa: F401
    DataflowResult, analyze_paths, analyze_source, analyze_sources,
)
from .lint import (  # noqa: F401
    LintResult, Rule, Suppression, Violation, all_rules, lint_file,
    lint_paths, lint_source, load_baseline, match_baseline, rule,
)

__all__ = [
    "DataflowResult", "LintResult", "Rule", "Suppression", "Violation",
    "all_rules", "analyze_paths", "analyze_source", "analyze_sources",
    "lint_file", "lint_paths", "lint_source", "load_baseline",
    "match_baseline", "rule",
]
