"""Quickstart: the SONIQ lifecycle on one linear layer, end to end — all
through the ``soniq`` façade.

    PYTHONPATH=src python examples/quickstart.py

1. Phase I  — noise-injected precision search (trainable s per 16-channel
              group, bit-count regularizer).           soniq.init_linear
2. Boundary — Problem-1 pattern solve + PatternMatch + precision freeze.
                                                        soniq.to_qat
3. Phase II — STE fine-tuning on the frozen {1,2,4}-bit SMOL grid.
4. Deploy   — channel reorder + bit-pack; packed matmul == QAT matmul.
                                                        soniq.to_serve
"""
import sys

sys.path.insert(0, "src")

import jax                                       # noqa: E402
import jax.numpy as jnp                          # noqa: E402
import numpy as np                               # noqa: E402

from repro import soniq                          # noqa: E402
from repro.core import noise                     # noqa: E402

KEY = jax.random.PRNGKey(0)
K, N, BATCH = 256, 128, 64


def main():
    qcfg = soniq.QuantConfig(mode=soniq.Phase.NOISE, lam=2e-2)
    # Teacher with *heterogeneous channel importance* — the structure SONIQ
    # exists to find: the first quarter of input channels carry most of the
    # signal, the rest progressively less.
    importance = jnp.concatenate([
        jnp.full((K // 4,), 1.0), jnp.full((K // 4,), 0.25),
        jnp.full((K // 4,), 0.05), jnp.full((K // 4,), 0.01)])
    w_true = jax.random.normal(jax.random.PRNGKey(9), (K, N)) * 0.2 \
        * importance[:, None]

    def draw(i):   # fresh data every step (stream; keeps the problem
        xi = jax.random.normal(jax.random.fold_in(KEY, 10_000 + i),
                               (BATCH, K))        # fully determined)
        return xi, xi @ w_true

    state = soniq.init_linear(KEY, K, N, qcfg)
    # Start from the pretrained weights (the realistic QAT workflow — the
    # paper fine-tunes trained networks; a from-scratch co-train needs the
    # paper's epoch-scale Phase I).
    state.params["w"] = w_true + 0.01 * jax.random.normal(KEY, (K, N))
    s0 = state.params["s"]
    print(f"Phase I: {s0.shape[0]} channel groups at "
          f"s_init={float(s0[0]):.3f} "
          f"(sigma={float(noise.sigma(s0[0])):.4f} = 2^-3)")

    @jax.jit
    def step(state, lr, rng, xi, yi):
        def loss(s):
            pred = soniq.apply(s, xi, rng=rng)
            return jnp.mean((pred - yi) ** 2) \
                + qcfg.lam * soniq.bit_penalty(s.params["s"])
        g = jax.grad(loss)(state).params
        # s gets its own (faster) schedule — paper Phase I runs for epochs.
        return state.replace(params={
            "w": state.params["w"] - lr * g["w"],
            "s": state.params["s"] - 8 * lr * g["s"]})

    for i in range(800):
        xi, yi = draw(i)
        state = step(state, 0.03, jax.random.fold_in(KEY, i), xi, yi)
    x, y = draw(999)   # eval batch

    bits = np.asarray(noise.snap_124(
        noise.precision_from_s(state.params["s"])))
    print(f"learned precisions: "
          f"{dict(zip(*np.unique(bits, return_counts=True)))}")

    # Boundary: Problem 1 + PatternMatch under the P4 hardware subset.
    qat, report = soniq.to_qat(state)
    print(f"PatternMatch: {report['layers'][0]['vectors']} vectors, "
          f"bpp={report['layers'][0]['bpp']:.2f} "
          f"(patterns: {report['allowed'][:4]})")

    # Phase II: STE fine-tune (a few steps).
    @jax.jit
    def step2(s):
        def loss(ss):
            return jnp.mean((soniq.apply(ss, x) - y) ** 2)
        g = jax.grad(loss, allow_int=True)(s).params
        return s.replace(params={
            k: (v - 0.01 * g[k] if k == "w" else v)
            for k, v in s.params.items()})

    for _ in range(100):
        qat = step2(qat)

    # Deploy: pack + run the packed forward on the Pallas kernel backend
    # ("pallas" negotiates mosaic on TPU, interpret elsewhere — DESIGN.md
    # §11). (The single layer isn't a stacked scan group, so the trained
    # precisions are kept verbatim — to_serve's "auto" rebudget only
    # touches stacked leaves.)
    served = soniq.to_serve(qat)
    with soniq.use_backend("pallas"):
        y_kernel = soniq.apply(served, x)
    y_qat = soniq.apply(qat, x)
    err = float(jnp.max(jnp.abs(y_kernel - y_qat)))
    nbytes = sum(int(np.prod(served.params[k].shape))
                 for k in ("w4", "w2", "w1"))
    print(f"packed size: {nbytes} bytes vs fp32 {K*N*4} "
          f"({K*N*4/nbytes:.1f}x compression)")
    print(f"kernel vs QAT max err: {err:.2e}")
    rel = float(jnp.linalg.norm(y_qat - y) / jnp.linalg.norm(y))
    print(f"task relative error at deploy: {rel:.3f}")


if __name__ == "__main__":
    main()
