"""H2O-Danube-1.8B [arXiv:2401.16818; hf] — llama+mistral mix with sliding
window: 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000."""
from .base import ArchConfig
from .registry import register


@register("h2o-danube-1.8b")
def h2o_danube() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000, head_dim=80,
        rope_theta=1e4, window=4096, mlp_act="swiglu",
        tie_embeddings=False,
        source="arXiv:2401.16818; hf:h2oai/h2o-danube-1.8b-base",
    )
