"""Mistral-Large-123B [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
— dense: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768."""
from .base import ArchConfig
from .registry import register


@register("mistral-large-123b")
def mistral_large() -> ArchConfig:
    return ArchConfig(
        name="mistral-large-123b", family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        d_ff=28672, vocab_size=32768, head_dim=128,
        rope_theta=1e6, mlp_act="swiglu", tie_embeddings=False,
        source="hf:mistralai/Mistral-Large-Instruct-2407 (unverified)",
    )
