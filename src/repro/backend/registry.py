"""Backend registry: registration, capability negotiation, selection.

Selection precedence at each dispatch site (``resolve(name)``):

1. an active ``use_backend(...)`` context (innermost wins),
2. the explicit ``name`` argument (``QuantConfig.backend``),
3. the ``SONIQ_BACKEND`` environment variable,
4. auto-negotiation: the highest-``priority`` registered backend whose
   ``is_available()`` is True.

Explicit selection (1-3) is strict: naming a backend that is not
registered or not available on this platform raises
:class:`~repro.backend.base.BackendUnavailable` — there is no silent
fallback (the CI backend matrix depends on that). Aliases ("pallas",
"auto") are the negotiated exceptions: they expand to an ordered candidate
list and pick the first available, which is the documented behavior.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, List, Optional, Tuple

from .base import Backend, BackendUnavailable

ENV_VAR = "SONIQ_BACKEND"

_REGISTRY: Dict[str, Backend] = {}
_STACK: List[str] = []          # use_backend() context overrides, innermost last

# Alias -> ordered candidates; the first available one is used. "pallas"
# lets configs ask for "the real kernels" without hard-coding the platform
# flavor (mosaic on TPU, interpret elsewhere).
ALIASES: Dict[str, Tuple[str, ...]] = {
    "pallas": ("pallas_mosaic", "pallas_interpret"),
}


def register(backend: Backend, *, overwrite: bool = False) -> Backend:
    """Add a backend to the registry (import-time side effect of the
    implementation modules; also the extension point for out-of-tree
    backends, e.g. a future Triton/GPU one)."""
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    assert backend.name not in ALIASES and backend.name != "auto", \
        f"{backend.name!r} collides with an alias"
    _REGISTRY[backend.name] = backend
    return backend


def names() -> Tuple[str, ...]:
    """All registered backend names (whether or not available here)."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> Backend:
    """Look up a registered backend by exact name (no availability check)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise BackendUnavailable(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)} "
            f"(aliases: {sorted(ALIASES)})") from None


def available() -> List[str]:
    """Names of backends that can run on this platform, best first."""
    avail = [b for b in _REGISTRY.values() if b.is_available()]
    return [b.name for b in
            sorted(avail, key=lambda b: -b.priority)]


def _strict(name: str) -> Backend:
    """Resolve an explicit name/alias; raise rather than fall back."""
    if name in ALIASES:
        for cand in ALIASES[name]:
            b = _REGISTRY.get(cand)
            if b is not None and b.is_available():
                return b
        raise BackendUnavailable(
            f"no candidate of alias {name!r} is available here: "
            + "; ".join(f"{c}: {get(c).why_unavailable()}"
                        for c in ALIASES[name] if c in _REGISTRY))
    b = get(name)
    if not b.is_available():
        raise BackendUnavailable(
            f"backend {name!r} is registered but not available on this "
            f"platform: {b.why_unavailable()} (explicit selection never "
            f"falls back; unset {ENV_VAR} / QuantConfig.backend to "
            "negotiate)")
    return b


def resolve(name: Optional[str] = None) -> Backend:
    """Select the backend for a dispatch site. See module docstring for
    precedence. Called at trace time — the choice is baked into each jit
    trace, so switch backends via config (or rebuild the jitted fn), not
    by flipping a context around an already-compiled call."""
    if _STACK:
        return _strict(_STACK[-1])
    if name is not None:
        return _strict(name)
    env = os.environ.get(ENV_VAR, "").strip()
    if env:
        return _strict(env)
    order = available()
    if not order:
        raise BackendUnavailable(
            "no kernel backend is available (registry: "
            f"{sorted(_REGISTRY)})")
    return _REGISTRY[order[0]]


def current_backend() -> Backend:
    """The backend an unpinned dispatch would use right now."""
    return resolve(None)


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped override: every dispatch *traced* inside the context uses
    ``name`` (strict — unavailable raises on entry). Overrides
    ``QuantConfig.backend``; does not retroactively affect functions
    already jit-compiled outside the context."""
    _strict(name)                      # validate eagerly
    _STACK.append(name)
    try:
        yield _strict(name)
    finally:
        _STACK.pop()
