"""Kernel-backend dispatch registry: selection semantics, a backend × op
parity matrix against the ``kernels/ref.py`` oracles, activation-scale-mode
parity between the kernel and jnp paths, the block-size autotune cache, and
an end-to-end DecodeEngine smoke run that must be token-identical across
selectable backends (DESIGN.md §11)."""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import (BackendUnavailable, autotune, available,
                           current_backend, registry, resolve, use_backend)
from repro.configs.base import ArchConfig
from repro.core import pack as pack_lib
from repro.core import quant, smol
from repro.core.qtypes import QuantConfig
from repro.kernels import ref
from repro.models import lm
from repro.serve import engine

# Every backend that can run in this environment (on CPU: xla_ref +
# pallas_interpret; on TPU also pallas_mosaic).
BACKENDS = available()


def _rand_packed(key, kp, n, p):
    u = jax.random.randint(key, (kp, n), 0, 2 ** p).astype(jnp.uint8)
    return pack_lib.pack_codes(u, p)


def _serve_leaf(k=256, n=128, key=0):
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25))
    params = smol.linear_init(jax.random.PRNGKey(key), k, n, qcfg)
    params["pbits"] = jnp.asarray(
        np.array([4, 1, 2, 4, 2, 1, 4, 4, 1, 2, 4, 2, 1, 4, 4, 2], np.int8))
    from repro.api import transforms
    return transforms.pack_linear(params, qcfg), qcfg


def test_single_segment_routes_in_kernel_scale(monkeypatch):
    """The shared driver hands the per-token abs-max to the kernel
    (``in_kernel_scale=True``) exactly when one uniform-precision segment
    spans the whole K row under per_token scaling — never for mixed
    segment layouts or non-per-token modes (the scale then spans kernel
    boundaries / isn't a row reduction)."""
    from repro.api import transforms
    b = resolve("pallas_interpret")
    seen = []
    orig = type(b).fused_act_segment_matmul     # pre-patch, via the MRO

    def spy(self, x, wp, scales=None, act_scales=None, *,
            in_kernel_scale=False, **kw):
        seen.append(in_kernel_scale)
        return orig(self, x, wp, scales, act_scales,
                    in_kernel_scale=in_kernel_scale, **kw)

    monkeypatch.setattr(type(b), "fused_act_segment_matmul", spy)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 64))
    qat = QuantConfig(mode="qat")
    uni = transforms.pack_linear(
        {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 32)),
         "pbits": np.full((4,), 4, np.int8)}, qat)
    mixed = transforms.pack_linear(
        {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 32)),
         "pbits": np.asarray([4, 4, 2, 1], np.int8)}, qat)
    for sp, mode, want in ((uni, "per_token", [True]),
                           (uni, "per_tensor", [False]),
                           (uni, "none", [False]),
                           (mixed, "per_token", [False] * 3)):
        seen.clear()
        y = b.packed_matmul(sp, x, QuantConfig(mode="serve",
                                               act_scale_mode=mode))
        assert seen == want, (mode, seen)
        assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------- registry ----
def test_builtin_backends_registered():
    assert {"xla_ref", "pallas_interpret", "pallas_mosaic"} <= set(
        registry.names())
    assert "xla_ref" in BACKENDS and "pallas_interpret" in BACKENDS


def test_unknown_backend_raises():
    with pytest.raises(BackendUnavailable, match="unknown backend"):
        resolve("triton_gpu")


def test_explicit_unavailable_backend_never_falls_back():
    """Naming a backend that cannot run here must raise, not silently
    degrade — the CI matrix depends on this."""
    if jax.default_backend() == "tpu":
        pytest.skip("pallas_mosaic is available on TPU")
    with pytest.raises(BackendUnavailable, match="never"):
        resolve("pallas_mosaic")


def test_env_var_selection(monkeypatch):
    monkeypatch.setenv(registry.ENV_VAR, "pallas_interpret")
    assert resolve().name == "pallas_interpret"
    assert current_backend().name == "pallas_interpret"
    monkeypatch.setenv(registry.ENV_VAR, "no_such_backend")
    with pytest.raises(BackendUnavailable):
        resolve()


def test_env_var_matrix_honored():
    """Whatever SONIQ_BACKEND the harness set (the CI two-way matrix) is
    exactly what unpinned dispatch resolves to."""
    env = os.environ.get(registry.ENV_VAR, "").strip()
    if not env:
        pytest.skip("SONIQ_BACKEND not set")
    assert resolve().name == env


def test_use_backend_context_wins_and_restores(monkeypatch):
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    before = resolve().name
    with use_backend("pallas_interpret") as b:
        assert b.name == "pallas_interpret"
        # the context outranks explicit config names too
        assert resolve("xla_ref").name == "pallas_interpret"
    assert resolve().name == before


def test_supports_capability_probe():
    from repro.backend import OPS
    assert set(autotune.DEFAULT_BLOCKS) <= set(OPS)
    pal = resolve("pallas_interpret")
    for op in ("packed_segment_matmul", "fused_act_segment_matmul",
               "quantize_pack", "noise_inject", "fake_quant",
               "qkv_attn_decode"):
        assert pal.supports(op), op          # own Pallas kernels
    assert not pal.supports("packed_matmul")  # shared driver
    xla = resolve("xla_ref")
    assert xla.supports("packed_segment_matmul")
    assert not xla.supports("noise_inject")  # shared hash implementation
    assert not xla.supports("fake_quant")    # shared STE implementation
    # xla_ref must stay on the two-pass activation-quant form — it is the
    # exactness oracle the fused Pallas prologue is gated against; same
    # for the dequantize-everything quantized-KV decode oracle.
    assert not xla.supports("fused_act_segment_matmul")
    assert not xla.supports("qkv_attn_decode")


def test_pallas_alias_negotiates():
    b = resolve("pallas")
    expect = "pallas_mosaic" if jax.default_backend() == "tpu" \
        else "pallas_interpret"
    assert b.name == expect


def test_quantconfig_backend_flows_to_dispatch(monkeypatch):
    monkeypatch.delenv(registry.ENV_VAR, raising=False)
    qcfg = QuantConfig(mode="serve", backend="pallas_interpret")
    assert qcfg.backend_name == "pallas_interpret"
    legacy = QuantConfig(mode="serve", use_pallas=True)
    assert legacy.backend_name == "pallas"
    assert resolve(legacy.backend_name).name.startswith("pallas_")


# ------------------------------------------- backend x op parity matrix ----
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [1, 2, 4])
@pytest.mark.parametrize("m,kp,n", [(8, 128, 128), (16, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matrix_packed_segment_matmul(backend, p, m, kp, n, dtype):
    key = jax.random.PRNGKey(p * 1000 + m + kp + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, kp), dtype)
    wp = _rand_packed(k2, kp, n, p)
    scales = jax.random.uniform(k3, (kp // 16,), jnp.float32, 0.5, 2.0)
    got = resolve(backend).packed_segment_matmul(x, wp, scales, p=p)
    want = ref.packed_segment_matmul_ref(x, wp, scales, p)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("p", [1, 2, 4])
def test_matrix_quantize_pack(backend, p):
    key = jax.random.PRNGKey(p)
    w = jax.random.normal(key, (128, 128)) * 0.8
    scales = jax.random.uniform(jax.random.PRNGKey(1), (8,),
                                jnp.float32, 0.5, 1.5)
    got = resolve(backend).quantize_pack(w, scales, p=p)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.quantize_pack_ref(
                                      w, p, scales)))


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_noise_inject(backend):
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (128, 256)) * 0.5
    s = jax.random.normal(jax.random.PRNGKey(1), (8,))
    got = resolve(backend).noise_inject(w, s, 1234)
    want = ref.noise_inject_ref(w, s, 1234)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_noise_inject_grad(backend):
    """Phase-I training must work under every backend: the shared custom
    VJP makes the (w, s) gradient exact even where the forward is a
    Pallas call."""
    b = resolve(backend)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.5
    s = jnp.zeros((4,))

    def loss(w, s):
        return jnp.sum(b.noise_inject(w, s, jnp.uint32(7)) ** 2)

    gw, gs = jax.jit(jax.grad(loss, argnums=(0, 1)))(w, s)
    assert np.isfinite(np.asarray(gw)).all()
    assert np.isfinite(np.asarray(gs)).all()
    assert float(jnp.abs(gs).max()) > 0
    gw_ref, gs_ref = jax.grad(loss_ref := lambda w, s: jnp.sum(
        resolve("xla_ref").noise_inject(w, s, jnp.uint32(7)) ** 2),
        argnums=(0, 1))(w, s)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gs_ref),
                               atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_fake_quant(backend):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    pbits = jnp.asarray(np.array([4, 2, 1, 4, 2, 1, 4, 4], np.float32))
    scale = quant.abs_max_scale(x, axis=-1)
    got = resolve(backend).fake_quant(x, pbits, scale, 16)
    want = quant.fake_quant(x, pbits, scale, 16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_full_packed_matmul_vs_serve_rule(backend):
    """The backend driver must match the phase-rule output exactly when
    that rule is pinned to the same backend, and match the xla_ref
    reference to fp32 tolerance regardless."""
    sp, qcfg = _serve_leaf()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    qserve = QuantConfig(mode="serve", mix=qcfg.mix, backend=backend)
    y_rule = smol.linear_apply(sp, x, qserve)
    y_drv = resolve(backend).packed_matmul(sp, x, qserve)
    np.testing.assert_array_equal(np.asarray(y_rule), np.asarray(y_drv))
    y_ref = resolve("xla_ref").packed_matmul(
        sp, x, QuantConfig(mode="serve", mix=qcfg.mix))
    np.testing.assert_allclose(np.asarray(y_drv), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_pack_linear_identical_codes(backend):
    """Deploy-time packing emits identical uint8 carriers on every
    backend (integer outputs leave no tolerance to hide behind)."""
    from repro.api import transforms
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25), backend=backend)
    params = smol.linear_init(jax.random.PRNGKey(0), 128, 64, qcfg)
    sp = transforms.pack_linear(params, qcfg)
    sp_ref = transforms.pack_linear(
        params, QuantConfig(mode="qat", mix=qcfg.mix, backend="xla_ref"))
    for name in ("w4", "w2", "w1"):
        np.testing.assert_array_equal(np.asarray(sp[name]),
                                      np.asarray(sp_ref[name]))


# ------------------------------------- fused activation-quant prologue ----
@pytest.mark.parametrize("mode", ["per_token", "per_tensor", "none"])
def test_fused_prologue_bit_exact_vs_two_pass(mode):
    """The fused activation-quant prologue must be *bit-exact* against the
    two-pass form on the same backend: fusion removes the HBM round-trip
    of the quantized activations, not any arithmetic (DESIGN.md §11)."""
    sp, qcfg = _serve_leaf()
    b = resolve("pallas_interpret")
    x = jax.random.normal(jax.random.PRNGKey(9), (6, 256)) * 1.3
    q_fused = QuantConfig(mode="serve", mix=qcfg.mix, act_scale_mode=mode)
    q_two = dataclasses.replace(q_fused, fuse_act_quant=False)
    np.testing.assert_array_equal(
        np.asarray(b.packed_matmul(sp, x, q_fused)),
        np.asarray(b.packed_matmul(sp, x, q_two)))


def test_pallas_driver_engages_fused_prologue():
    """Under a Pallas backend the serve driver must dispatch the fused
    kernel (not the jnp fallback, not the two-pass form) — the perf claim
    of the fusion depends on this actually being the hot path."""
    from repro.backend import pallas as pallas_mod
    sp, qcfg = _serve_leaf()
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    before = pallas_mod.fused_act_call_count()
    smol.linear_apply(sp, x, QuantConfig(mode="serve", mix=qcfg.mix,
                                         backend="pallas_interpret"))
    assert pallas_mod.fused_act_call_count() > before
    # ...and fuse_act_quant=False really does pin the two-pass form.
    before = pallas_mod.fused_act_call_count()
    smol.linear_apply(sp, x, QuantConfig(mode="serve", mix=qcfg.mix,
                                         backend="pallas_interpret",
                                         fuse_act_quant=False))
    assert pallas_mod.fused_act_call_count() == before


@pytest.mark.parametrize("backend", BACKENDS)
def test_zero_row_act_scale_is_finite(backend):
    """Regression (satellite fix): an all-zero activation row — a padding
    slot fresh from reset_cache_slots — must not make the per-token
    abs-max a 0 divisor (NaN/Inf logits). The epsilon clamp lives in the
    shared driver's act_scale and therefore also feeds the fused
    prologue."""
    sp, qcfg = _serve_leaf()
    b = resolve(backend)
    q = QuantConfig(mode="serve", mix=qcfg.mix, act_scale_mode="per_token")
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 256))
    xz = x.at[2].set(0.0)
    y = np.asarray(b.packed_matmul(sp, xz, q))
    assert np.isfinite(y).all()
    # the zero row must not perturb the other rows either
    np.testing.assert_array_equal(
        np.asarray(b.packed_matmul(sp, x, q))[[0, 1, 3]], y[[0, 1, 3]])


@pytest.mark.parametrize("backend", BACKENDS)
def test_matrix_fake_quant_grad(backend):
    """QAT must differentiate through every backend's fake_quant forward
    (fused Pallas kernel included) with gradients identical to the jnp
    clipped STE — compared jit-to-jit, since XLA fusion of the *reference*
    differs between eager and jit at the ulp level."""
    b = resolve(backend)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    pbits = jnp.asarray(np.array([4, 2, 1, 4, 2, 1, 4, 4], np.float32))

    def loss(x, fq):
        sx = quant.abs_max_scale(x, axis=-1)
        return jnp.sum(fq(x, pbits, sx, 16) ** 2)

    got = jax.jit(jax.grad(lambda x: loss(x, b.fake_quant)))(x)
    want = jax.jit(jax.grad(lambda x: loss(x, quant.fake_quant)))(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------- activation scaling (satellite fix) ----
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("mode", ["per_token", "per_tensor", "none"])
def test_act_scale_mode_parity_kernel_vs_jnp(backend, mode):
    """The old kernel wrapper hard-coded a whole-batch abs-max scale; the
    driver must honor every QuantConfig.act_scale_mode and agree with the
    jnp path token-for-token."""
    sp, qcfg = _serve_leaf()
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 256)) * 1.7
    q = QuantConfig(mode="serve", mix=qcfg.mix, act_scale_mode=mode)
    want = resolve("xla_ref").packed_matmul(sp, x, q)
    got = resolve(backend).packed_matmul(sp, x, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_per_token_scale_is_row_independent(backend):
    """The cross-request magnitude leak (PR 2) must not reappear in any
    backend: with per_token scaling, a row's output cannot depend on what
    else is in the batch."""
    sp, qcfg = _serve_leaf()
    q = QuantConfig(mode="serve", mix=qcfg.mix, act_scale_mode="per_token")
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 256))
    big = x.at[3].set(x[3] * 100.0)         # an outlier row
    b = resolve(backend)
    np.testing.assert_array_equal(
        np.asarray(b.packed_matmul(sp, x, q))[:3],
        np.asarray(b.packed_matmul(sp, big, q))[:3])
    # ...whereas per_tensor (the training default) does couple rows:
    q_t = QuantConfig(mode="serve", mix=qcfg.mix,
                      act_scale_mode="per_tensor")
    assert not np.array_equal(
        np.asarray(b.packed_matmul(sp, x, q_t))[:3],
        np.asarray(b.packed_matmul(sp, big, q_t))[:3])


# ------------------------------------------------- engine smoke matrix ----
@pytest.fixture(scope="module")
def packed_checkpoint():
    cfg = ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode="qat"))
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    from repro.api import transforms
    serve_cfg = cfg.with_quant_mode("serve")
    packed = transforms.convert_tree(params, serve_cfg.quant,
                                     rebudget=True)
    return cfg, packed


def test_decode_engine_token_identical_across_backends(packed_checkpoint):
    """Acceptance bar: greedy decode over the SAME packed checkpoint is
    token-identical on every selectable backend, with selection flowing
    only through the registry (EngineConfig.backend)."""
    cfg, packed = packed_checkpoint
    prompts = np.array([[5, 9, 2, 71], [33, 4, 17, 8]], np.int32)
    outs = {}
    for name in BACKENDS:
        ecfg = engine.EngineConfig(max_batch=2, cache_len=32,
                                   prefill_chunk=2, backend=name)
        eng = engine.DecodeEngine(packed, cfg, ecfg, already_serve=True)
        outs[name] = eng.generate(prompts, 6)
    base = outs["xla_ref"]
    assert base.shape == (2, 10)
    for name, toks in outs.items():
        np.testing.assert_array_equal(base, toks, err_msg=name)


# ----------------------------------------------------------- autotune ----
def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "at.json"))
    autotune.invalidate()
    shape = (8, 128, 128)
    b = resolve("pallas_interpret")
    assert autotune.lookup("packed_segment_matmul", shape=shape, p=4,
                           dtype="float32", backend=b.name) == {}
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 128))
    wp = _rand_packed(key, 128, 128, 4)

    def call(**blocks):
        return b.packed_segment_matmul(x, wp, None, p=4, **blocks)

    cands = [{"block_m": 8, "block_n": 128, "block_k": 128},
             {"block_m": 8, "block_n": 64, "block_k": 64}]
    best = autotune.autotune_op(call, "packed_segment_matmul", shape=shape,
                                p=4, dtype="float32", candidates=cands,
                                iters=1, backend=b.name)
    assert best in cands
    # persisted: a fresh in-memory cache reloads the same entry (keys are
    # per-backend — interpret and mosaic timings must not mix)
    autotune.invalidate()
    assert autotune.lookup("packed_segment_matmul", shape=shape, p=4,
                           dtype="float32", backend=b.name) == best
    assert autotune.lookup("packed_segment_matmul", shape=shape, p=4,
                           dtype="float32", backend="pallas_mosaic") == {}
    # and the backend consults it on the next call (smoke: still correct)
    y = call()
    # atol matters: a split-K winner changes fp32 summation order, so
    # near-zero outputs can carry ~1e-6 absolute error vs the single-dot
    # oracle (same tolerance as the parity matrix above).
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.packed_segment_matmul_ref(
            x, wp, None, 4)), rtol=1e-5, atol=1e-5)


def test_autotune_candidates_are_legal():
    for op in ("packed_segment_matmul", "fused_act_segment_matmul"):
        for blocks in autotune.candidates_for(op, (24, 160, 96)):
            assert 24 % blocks["block_m"] == 0
            assert 96 % blocks["block_n"] == 0
            assert 160 % blocks["block_k"] == 0 and \
                blocks["block_k"] % 16 == 0
    for blocks in autotune.candidates_for("fake_quant", (24, 160)):
        assert 24 % blocks["block_m"] == 0
        assert 160 % blocks["block_k"] == 0 and blocks["block_k"] % 16 == 0


def test_autotune_save_merges_concurrent_writers(tmp_path, monkeypatch):
    """Regression (satellite fix): save_entry must read-merge-save against
    the *live* file, not dump its possibly stale in-memory snapshot — two
    concurrent --autotune sweeps used to clobber each other's entries."""
    path = tmp_path / "at.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    autotune.invalidate()
    autotune.save_entry("keyA", {"block_m": 8}, 1.0, 1)
    assert autotune._load() == json.loads(path.read_text())
    # Another process persists keyB after our in-memory snapshot loaded.
    data = json.loads(path.read_text())
    data["keyB"] = {"blocks": {"block_m": 16}, "us": 2.0, "candidates": 1}
    path.write_text(json.dumps(data))
    autotune.save_entry("keyC", {"block_m": 32}, 3.0, 1)
    final = json.loads(path.read_text())
    assert set(final) == {"keyA", "keyB", "keyC"}
    # nothing but the cache file is left behind (no orphaned temp files)
    assert [p.name for p in tmp_path.iterdir()] == ["at.json"]
