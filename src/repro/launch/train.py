"""Production training launcher: mesh + partition rules + pjit'd two-phase
SONIQ training.

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --reduced --steps 20 --mesh 1x1          # CPU smoke
    python -m repro.launch.train --arch deepseek-67b --mesh 16x16 ...  # TPU

On a real cluster each host runs this under jax.distributed; here the mesh
degenerates gracefully to whatever devices exist. The dry-run
(repro.launch.dryrun) is the no-allocation version of exactly this wiring.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import soniq
from repro.configs import get_config
from repro.data import synthetic
from repro.launch import mesh as mesh_lib
from repro.launch import sharding as sh
from repro.models import shard as shard_ctx
from repro.optim import adamw
from repro.train import checkpoint as ckpt_lib
from repro.train import state as state_lib


def parse_mesh(s: str):
    dims = [int(x) for x in s.split("x")]
    if len(dims) == 1:
        return mesh_lib.make_mesh((dims[0],), ("data",))
    if len(dims) == 2:
        return mesh_lib.make_mesh(tuple(dims), ("data", "model"))
    return mesh_lib.make_mesh(tuple(dims), ("pod", "data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--t1", type=int, default=0, help="Phase I steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--hoist", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = soniq.with_phase(cfg, soniq.Phase.QAT)
    mesh = parse_mesh(args.mesh)
    rules = sh.activation_rules(cfg, mesh, batch=args.batch)
    tcfg = state_lib.TrainConfig(
        num_microbatches=args.microbatches, t1=args.t1, t2=args.steps,
        warmup=max(args.steps // 10, 1), ckpt_dir=args.ckpt,
        checkpoint_every=max(args.steps // 2, 1),
        hoist_weight_quant=args.hoist, grad_compress=args.grad_compress)

    stream = synthetic.TokenStream(synthetic.TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=0), host_id=jax.process_index())

    with mesh_lib.set_mesh(mesh), shard_ctx.sharding_rules(rules):
        key = jax.random.PRNGKey(0)
        state = state_lib.init_state(key, cfg, tcfg)
        state_specs = jax.eval_shape(
            lambda: state_lib.init_state(key, cfg, tcfg))
        state_sh = sh.tree_shardings(state_specs, cfg, mesh, serve=False,
                                     rules=rules)
        state = jax.device_put(state, state_sh)
        dp = rules["batch"]
        step = jax.jit(
            lambda s, b, r: state_lib.train_step(s, b, cfg, tcfg, r),
            in_shardings=(state_sh,
                          {"tokens": NamedSharding(mesh, P(dp, None)),
                           "labels": NamedSharding(mesh, P(dp, None))},
                          NamedSharding(mesh, P())),
            donate_argnums=(0,))

        start = 0
        if args.ckpt:
            latest = ckpt_lib.latest_step(args.ckpt)
            if latest is not None:
                state, start = ckpt_lib.restore(args.ckpt, state)
                print(f"resumed from step {start}")

        batches = stream.batches()
        for i in range(start, args.steps):
            b = next(batches)
            state, metrics = step(state, {k: jax.numpy.asarray(v)
                                          for k, v in b.items()},
                                  jax.random.fold_in(key, i))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f}")
            if args.ckpt and (i + 1) % tcfg.checkpoint_every == 0:
                ckpt_lib.async_save(state, args.ckpt, i + 1).join()
    print("done")


if __name__ == "__main__":
    main()
