"""Serve a SONIQ-quantized LM through the continuous-batching engine.

    PYTHONPATH=src python examples/serve_quantized.py

Trains a tiny LM briefly (QAT), converts to packed 1/2/4-bit weights, then
streams a mixed-length request set through the request-level
``DecodeEngine`` (admission queue, slot reuse, chunked prefill —
DESIGN.md §10); reports the packed-size win and per-request completions as
they finish. Pass a kernel-backend name (``xla_ref``, ``pallas``,
``pallas_interpret`` — DESIGN.md §11) as the first argument to pick the
engine's kernels; default is auto-negotiation.
"""
import sys

sys.path.insert(0, "src")

import jax                                      # noqa: E402
import numpy as np                              # noqa: E402

from repro import soniq                         # noqa: E402
from repro.configs.base import ArchConfig       # noqa: E402
from repro.data import synthetic                # noqa: E402
from repro.train import loop, state as state_lib  # noqa: E402


def main():
    quant = soniq.QuantConfig(mode=soniq.Phase.QAT)
    cfg = ArchConfig(
        name="serve-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        dtype="float32", param_dtype="float32", quant=quant, q_block=64)

    # quick QAT-only training (t1=0 -> no Phase I, mix from config)
    tcfg = state_lib.TrainConfig(t1=0, t2=30, warmup=3)
    stream = synthetic.TokenStream(synthetic.TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=64, batch_size=8))
    result = loop.train(cfg, tcfg, stream.batches())
    params = jax.device_get(result["state"]["params"])

    # 2 slots serving 4 requests: the engine reuses slots as requests
    # finish instead of padding everyone to the longest prompt.
    backend = sys.argv[1] if len(sys.argv) > 1 else None
    eng = soniq.DecodeEngine(
        params, cfg, soniq.EngineConfig(max_batch=2, cache_len=128,
                                        prefill_chunk=4, backend=backend))
    print(f"kernel backend: {soniq.current_backend().name}"
          if backend is None else f"kernel backend: {backend}")
    fp_bytes = sum(v.size * 4 for v in jax.tree.leaves(params)
                   if hasattr(v, "size"))
    q_bytes = soniq.packed_bytes(eng.params)
    print(f"model bytes: fp32 {fp_bytes:,} -> packed {q_bytes:,} "
          f"({fp_bytes/q_bytes:.1f}x smaller)")

    prompts = [[1, 7, 3, 1], [2, 9, 9, 4, 30, 12], [5, 5, 5],
               [11, 3, 7, 2, 8]]
    requests = [soniq.Request(prompt=np.asarray(p, np.int32),
                              max_new_tokens=6 + 3 * i, seed=i)
                for i, p in enumerate(prompts)]
    for c in eng.serve(requests):
        print(f"request {c.request_id} [{c.finish_reason}, "
              f"{c.steps} steps in slot]: prompt={c.request.prompt.tolist()} "
              f"-> {c.new_tokens.tolist()}")


if __name__ == "__main__":
    main()
