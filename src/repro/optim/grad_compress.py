"""Int8 error-feedback gradient compression for the DP all-reduce.

The SONIQ theme applied to the optimizer's communication: gradients are
quantized to int8 (per-leaf abs-max scale) *before* the data-parallel
reduction; the quantization residual is carried in an error-feedback buffer
so the compression is unbiased over time (Karimireddy et al., 2019). Cuts
DP all-reduce bytes 4x vs fp32 / 2x vs bf16; enabled with
TrainConfig.grad_compress.

Inside pjit the reduction itself is GSPMD's; we expose the quantize /
dequantize pair and the shard_map ring variant used in §Perf experiments.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_leaf(g, err):
    """(g + err) -> (int8 codes, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_leaf(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_tree):
    """Returns (quantized tree of (q, scale), new error tree)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree, is_leaf=lambda x: x is None)
    qs, es = [], []
    for g, e in zip(flat_g, flat_e):
        if g is None or not jnp.issubdtype(g.dtype, jnp.floating):
            qs.append((g, None))
            es.append(e)
            continue
        q, s, ne = compress_leaf(g, e)
        qs.append((q, s))
        es.append(ne)
    return jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, es)


def decompress_tree(qtree):
    def dec(leaf):
        q, s = leaf
        return q if s is None else decompress_leaf(q, s)
    return jax.tree.map(dec, qtree,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def init_error_tree(params):
    return jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32)
        if jnp.issubdtype(jnp.asarray(p).dtype, jnp.floating) else None,
        params)


def psum_compressed(grads, axis_name: str) -> Tuple:
    """shard_map building block: int8 all-reduce emulation — quantize,
    psum the int32-upcast codes, dequantize with the max scale. Used by the
    §Perf collective experiments (the GSPMD path compresses before its
    automatic reduction instead)."""
    def one(g):
        if g is None or not jnp.issubdtype(g.dtype, jnp.floating):
            return g
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int32)
        tot = jax.lax.psum(q, axis_name)
        return tot.astype(jnp.float32) * scale
    return jax.tree.map(one, grads)
