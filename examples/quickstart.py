"""Quickstart: the SONIQ pipeline on one linear layer, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. Phase I  — noise-injected precision search (trainable s per 16-channel
              group, bit-count regularizer).
2. Boundary — Problem-1 pattern solve + PatternMatch + precision freeze.
3. Phase II — STE fine-tuning on the frozen {1,2,4}-bit SMOL grid.
4. Deploy   — channel reorder + bit-pack; packed matmul == QAT matmul.
"""
import sys

sys.path.insert(0, "src")

import jax                                       # noqa: E402
import jax.numpy as jnp                          # noqa: E402
import numpy as np                               # noqa: E402

from repro.core import QuantConfig, noise, schedule, smol  # noqa: E402
from repro.kernels import ops                    # noqa: E402

KEY = jax.random.PRNGKey(0)
K, N, BATCH = 256, 128, 64


def main():
    qcfg = QuantConfig(mode="noise", lam=2e-2)
    # Teacher with *heterogeneous channel importance* — the structure SONIQ
    # exists to find: the first quarter of input channels carry most of the
    # signal, the rest progressively less.
    importance = jnp.concatenate([
        jnp.full((K // 4,), 1.0), jnp.full((K // 4,), 0.25),
        jnp.full((K // 4,), 0.05), jnp.full((K // 4,), 0.01)])
    w_true = jax.random.normal(jax.random.PRNGKey(9), (K, N)) * 0.2 \
        * importance[:, None]

    def draw(i):   # fresh data every step (stream; keeps the problem
        xi = jax.random.normal(jax.random.fold_in(KEY, 10_000 + i),
                               (BATCH, K))        # fully determined)
        return xi, xi @ w_true

    params = smol.linear_init(KEY, K, N, qcfg)
    # Start from the pretrained weights (the realistic QAT workflow — the
    # paper fine-tunes trained networks; a from-scratch co-train needs the
    # paper's epoch-scale Phase I).
    params["w"] = w_true + 0.01 * jax.random.normal(KEY, (K, N))
    print(f"Phase I: {params['s'].shape[0]} channel groups at "
          f"s_init={float(params['s'][0]):.3f} "
          f"(sigma={float(noise.sigma(params['s'][0])):.4f} = 2^-3)")

    @jax.jit
    def step(params, lr, rng, xi, yi):
        def loss(p):
            pred = smol.linear_apply(p, xi, qcfg, rng)
            return jnp.mean((pred - yi) ** 2) \
                + qcfg.lam * noise.bit_penalty(p["s"])
        g = jax.grad(loss)(params)
        # s gets its own (faster) schedule — paper Phase I runs for epochs.
        return {"w": params["w"] - lr * g["w"],
                "s": params["s"] - 8 * lr * g["s"]}

    for i in range(800):
        xi, yi = draw(i)
        params = step(params, 0.03, jax.random.fold_in(KEY, i), xi, yi)
    x, y = draw(999)   # eval batch

    bits = np.asarray(noise.snap_124(noise.precision_from_s(params["s"])))
    print(f"learned precisions: {dict(zip(*np.unique(bits, return_counts=True)))}")

    # Boundary: Problem 1 + PatternMatch under the P4 hardware subset.
    qat_params, report = schedule.pattern_match_params(
        {"layer": jax.device_get(params)}, qcfg)
    print(f"PatternMatch: {report['layers'][0]['vectors']} vectors, "
          f"bpp={report['layers'][0]['bpp']:.2f} "
          f"(patterns: {report['allowed'][:4]})")

    # Phase II: STE fine-tune (a few steps).
    qcfg2 = QuantConfig(mode="qat")
    p2 = qat_params["layer"]

    @jax.jit
    def step2(p):
        def loss(pp):
            return jnp.mean((smol.linear_apply(pp, x, qcfg2) - y) ** 2)
        g = jax.grad(loss, allow_int=True)(p)
        return {k: (v - 0.01 * g[k] if k == "w" else v) for k, v in p.items()}

    for _ in range(100):
        p2 = step2(p2)

    # Deploy: pack + run the Pallas kernel path.
    sp = smol.serve_params_from_qat(jax.device_get(p2), qcfg2)
    y_kernel = ops.packed_matmul(x, sp, interpret=True)
    y_qat = smol.linear_apply(p2, x, qcfg2)
    err = float(jnp.max(jnp.abs(y_kernel - y_qat)))
    nbytes = sum(int(np.prod(sp[k].shape)) for k in ("w4", "w2", "w1"))
    print(f"packed size: {nbytes} bytes vs fp32 {K*N*4} "
          f"({K*N*4/nbytes:.1f}x compression)")
    print(f"kernel vs QAT max err: {err:.2e}")
    rel = float(jnp.linalg.norm(y_qat - y) / jnp.linalg.norm(y))
    print(f"task relative error at deploy: {rel:.3f}")


if __name__ == "__main__":
    main()
