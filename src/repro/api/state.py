"""Typed lifecycle state for the ``soniq`` façade.

A :class:`SoniqState` bundles a parameter pytree with the phase it is in
and the (static, hashable) model config that interprets it. It is itself a
registered pytree — only ``params`` are leaves; phase and config ride as
static aux data — so states pass through ``jax.jit`` / ``jax.grad`` /
optimizer updates unchanged:

    state = soniq.init(cfg, rng=key)            # Phase.NOISE
    grads = jax.grad(lambda s: loss(soniq.apply(s, x)))(state)
    qat, report = soniq.to_qat(state)           # Phase.QAT  (host-side)
    packed = soniq.to_serve(qat)                # Phase.SERVE
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.phases import Phase, PhaseSpec
from repro.core.qtypes import QuantConfig


@dataclasses.dataclass(frozen=True)
class LinearSpec:
    """Model config for the single-SmolLinear case (quickstart / unit
    tests): one [K, N] quantized matmul."""
    k: int
    n: int
    use_bias: bool = False
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SoniqState:
    """params + the phase that interprets them + the model config.

    ``model_cfg`` is an ``ArchConfig`` (LM), ``CNNConfig`` (paper CNNs) or
    :class:`LinearSpec`; it must stay hashable (it is jit-static aux data).
    """
    phase: PhaseSpec
    params: Any
    model_cfg: Any

    # ------------------------------------------------------------ pytree ----
    def tree_flatten(self):
        return (self.params,), (self.phase, self.model_cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(phase=aux[0], params=children[0], model_cfg=aux[1])

    # ------------------------------------------------------------ config ----
    @property
    def qcfg(self) -> QuantConfig:
        """The QuantConfig with this state's phase applied."""
        return self.model_cfg.quant.with_mode(self.phase)

    @property
    def forward_cfg(self):
        """The model config with this state's phase applied to its quant
        field — what the layer libraries consume."""
        return dataclasses.replace(self.model_cfg, quant=self.qcfg)

    def replace(self, **kw) -> "SoniqState":
        if "phase" in kw:
            kw["phase"] = Phase.from_mode(kw["phase"])
        return dataclasses.replace(self, **kw)

    def __repr__(self) -> str:
        name = getattr(self.model_cfg, "name", type(self.model_cfg).__name__)
        return f"SoniqState({self.phase!r}, model={name})"
