"""Batched decode engine over packed SONIQ weights.

``serve_convert`` walks a trained QAT parameter tree and packs every
quantized linear: per-layer precisions are re-budgeted to the static
segment mix (scan groups must share packed shapes — groups that trained
4-bit keep their 4 bits while the budget allows, ranked by trained
precision then weight magnitude), channels reordered (paper Obs. 4), codes
bit-packed. The engine then runs greedy/temperature decoding with the ring
KV cache; weights move as 1/2/4-bit carriers — the paper's deployment path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smol
from repro.core.qtypes import QuantConfig
from repro.models import lm


def rebudget_pbits(pbits: np.ndarray, w: np.ndarray,
                   qcfg: QuantConfig) -> np.ndarray:
    """Project trained per-group precisions onto the static segment budget
    (counts from qcfg.mix) preserving the trained ranking; ties broken by
    group abs-max (importance proxy)."""
    n = pbits.shape[0]
    k = w.shape[0]
    g = k // n
    counts = smol.init_pbits_from_mix(k, qcfg)
    n4 = int((counts == 4).sum())
    n2 = int((counts == 2).sum())
    mag = np.abs(w).reshape(n, g, -1).max(axis=(1, 2))
    order = np.lexsort((-mag, -pbits.astype(np.int64)))  # pbits desc, mag desc
    out = np.empty(n, np.int8)
    out[order[:n4]] = 4
    out[order[n4:n4 + n2]] = 2
    out[order[n4 + n2:]] = 1
    return out


def _convert_leaf_layer(w: np.ndarray, pbits: np.ndarray, b,
                        qcfg: QuantConfig) -> Dict:
    params = {"w": jnp.asarray(w), "pbits": jnp.asarray(
        rebudget_pbits(np.asarray(pbits), w, qcfg))}
    if b is not None:
        params["b"] = jnp.asarray(b)
    return smol.serve_params_from_qat(params, qcfg)


def serve_convert(params, qcfg: QuantConfig):
    """QAT pytree -> serve pytree (handles stacked scan/expert dims)."""
    def fix(node):
        if not (isinstance(node, dict) and "w" in node and "pbits" in node):
            return node
        w = np.asarray(node["w"])
        pb = np.asarray(node["pbits"])
        b = np.asarray(node["b"]) if "b" in node else None
        if w.ndim == 2:
            return _convert_leaf_layer(w, pb, b, qcfg)
        lead = w.shape[:-2]
        flat_w = w.reshape((-1,) + w.shape[-2:])
        flat_pb = pb.reshape((-1, pb.shape[-1]))
        flat_b = b.reshape((-1, b.shape[-1])) if b is not None else None
        converted = [
            _convert_leaf_layer(flat_w[i], flat_pb[i],
                                None if flat_b is None else flat_b[i], qcfg)
            for i in range(flat_w.shape[0])]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
            lead + xs[0].shape), *converted)
        return stacked

    return smol._tree_map_dicts(fix, params)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 256
    temperature: float = 0.0        # 0 = greedy
    cache_dtype: str = "float32"


class DecodeEngine:
    """Minimal batched generation loop (greedy / temperature sampling)."""

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        self.cfg = dataclasses.replace(
            arch_cfg, quant=dataclasses.replace(arch_cfg.quant,
                                                mode="serve"))
        self.ecfg = ecfg
        self.params = params if already_serve else serve_convert(
            params, self.cfg.quant)
        self._step = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, self.cfg, c, t, pos))

    def init_cache(self, batch: int):
        return lm.init_cache(self.cfg, batch, self.ecfg.cache_len,
                             jnp.dtype(self.ecfg.cache_dtype))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """prompts [B, S0] int32 -> [B, S0 + max_new] (greedy unless
        temperature > 0)."""
        b, s0 = prompts.shape
        cache = self.init_cache(b)
        toks = jnp.asarray(prompts, jnp.int32)
        out = [toks]
        logits = None
        for t in range(s0):
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = self._step(self.params, cache, toks[:, t], pos)
        cur = self._sample(logits, rng, 0)
        for t in range(max_new_tokens):
            out.append(cur[:, None])
            if t == max_new_tokens - 1:
                break
            pos = jnp.full((b,), s0 + t, jnp.int32)
            logits, cache = self._step(self.params, cache, cur, pos)
            cur = self._sample(logits, rng, t + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _sample(self, logits, rng, t):
        if self.ecfg.temperature <= 0 or rng is None:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        k = jax.random.fold_in(rng, t)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature).astype(jnp.int32)


def packed_model_bytes(serve_params) -> int:
    """Total packed weight bytes (the paper's network-size metric)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(serve_params)[0]:
        if leaf is None:
            continue
        name = str(getattr(path[-1], "key", ""))
        if name in ("w4", "w2", "w1"):
            total += leaf.size
        elif name in ("w", "table", "wscale", "b"):
            total += leaf.size * np.dtype(leaf.dtype).itemsize
    return int(total)
