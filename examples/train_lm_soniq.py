"""End-to-end driver: two-phase SONIQ training of a transformer LM.

    PYTHONPATH=src python examples/train_lm_soniq.py                 # tiny CPU demo
    PYTHONPATH=src python examples/train_lm_soniq.py --preset 100m   # ~100M (TPU)
    PYTHONPATH=src python examples/train_lm_soniq.py --arch h2o-danube-1.8b \
        --reduced --steps 40                                         # any assigned arch

Runs Phase I (noise search) -> Problem-1/PatternMatch boundary -> Phase II
(QAT), with checkpointing; prints loss curve and the final per-layer bpp.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax                                     # noqa: E402
import numpy as np                             # noqa: E402

from repro import soniq                        # noqa: E402
from repro.configs import get_config           # noqa: E402
from repro.configs.base import ArchConfig      # noqa: E402
from repro.data import synthetic               # noqa: E402
from repro.train import loop, state as state_lib  # noqa: E402

QuantConfig = soniq.QuantConfig


def tiny_config(quant: QuantConfig) -> ArchConfig:
    return ArchConfig(
        name="tiny-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512, head_dim=32,
        dtype="float32", param_dtype="float32", quant=quant, q_block=64)


def preset_100m(quant: QuantConfig) -> ArchConfig:
    return ArchConfig(
        name="soniq-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32768,
        quant=quant)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--arch", default=None,
                    help="use an assigned architecture instead of a preset")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    quant = QuantConfig(mode=soniq.Phase.QAT, lam=1e-3)
    if args.arch:
        cfg = get_config(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, quant=quant)
    else:
        cfg = (tiny_config if args.preset == "tiny" else preset_100m)(quant)

    t1 = args.steps // 2
    tcfg = state_lib.TrainConfig(
        t1=t1, t2=args.steps, warmup=max(args.steps // 10, 2),
        checkpoint_every=max(args.steps // 3, 5), ckpt_dir=args.ckpt)

    stream = synthetic.TokenStream(synthetic.TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        batch_size=args.batch))
    batches = stream.batches()

    def to_batch(b):
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        if cfg.family == "vlm":
            import jax.numpy as jnp
            out["positions"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None],
                (3, args.batch, args.seq))
        if cfg.family == "audio":
            out["frames"] = np.random.default_rng(0).normal(
                0, 1, (args.batch, args.seq, cfg.frontend_dim)
            ).astype(np.float32)
        return out

    result = loop.train(cfg, tcfg, map(to_batch, batches))
    hist = result["history"]
    p1 = [h["loss"] for h in hist if h["phase"] == 1]
    p2 = [h["loss"] for h in hist if h["phase"] == 2]
    print(f"\nPhase I loss:  {p1[0]:.3f} -> {p1[-1]:.3f}" if p1 else "")
    print(f"Phase II loss: {p2[0]:.3f} -> {p2[-1]:.3f}" if p2 else "")
    if result["pattern_report"]:
        print(f"deployed bpp: "
              f"{soniq.average_bpp(result['pattern_report']):.2f}"
              f" (vs 32.0 fp32, 4.0 uniform-4)")


if __name__ == "__main__":
    main()
