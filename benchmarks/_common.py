"""Shared benchmark machinery: the two-phase SONIQ CNN trainer used by the
paper-table reproductions, plus CSV helpers."""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import soniq
from repro.core.qtypes import QuantConfig
from repro.data import synthetic
from repro.models import cnn
from repro.optim import adamw

BENCH_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "150"))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


BENCH_BACKEND_JSON = Path(__file__).resolve().parent / "BENCH_backend.json"


def record_backend_bench(section: str, payload: Dict) -> None:
    """Merge ``payload`` under ``section`` in BENCH_backend.json — the
    cross-benchmark record of per-kernel-backend performance
    (serve_throughput tokens/s, runtime_proxy per-op microseconds) that
    the backend perf trajectory is measured against."""
    data: Dict = {}
    if BENCH_BACKEND_JSON.exists():
        try:
            data = json.loads(BENCH_BACKEND_JSON.read_text())
        except ValueError:
            data = {}
    section_data = data.setdefault(section, {})
    for key, value in payload.items():
        # One-level deep merge: a partial sweep (--backends xla_ref) must
        # not drop the other backends' recorded numbers.
        if isinstance(value, dict) and isinstance(section_data.get(key),
                                                  dict):
            section_data[key].update(value)
        else:
            section_data[key] = value
    BENCH_BACKEND_JSON.write_text(json.dumps(data, indent=1,
                                             sort_keys=True) + "\n")
    print(f"[bench] wrote {section} -> {BENCH_BACKEND_JSON}", flush=True)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


_DATA = {}

# The CNN must have >= 128 input channels per quantized conv: one 128-bit
# vector swallows a 16-channel layer whole, and Problem-1's
# max-avg-precision tie-break then (correctly) promotes the excess capacity
# back to 4 bits — mixed precision is only *physical* when layers span
# multiple vectors (the paper's CIFAR nets have 116-1024 channels).
CNN_CHANNELS = (128,)
CNN_BLOCKS = 2
IMG = (6, 6, 3)
BATCH = 32


def data(seed=0):
    if seed not in _DATA:
        _DATA[seed] = synthetic.classification_dataset(
            num_classes=10, dim=IMG, n_train=1024, n_test=256, seed=seed)
    return _DATA[seed]


def freeze_original(params, max_bits: int = 8):
    """'Original SMOL' freeze: per-group precisions = clip(round(raw), 1, 8)
    — no {1,2,4} snap, no pattern matching (paper Alg. 1 line 9)."""
    def fix(node):
        if not (isinstance(node, dict) and "s" in node and "w" in node):
            return node
        s = np.asarray(node["s"], np.float64)
        raw = 1.0 + np.log2(1.0 + np.exp(-s))
        pb = np.clip(np.round(raw), 1, max_bits).astype(np.int8)
        new = {k: v for k, v in node.items() if k != "s"}
        new["pbits"] = jnp.asarray(pb)
        return new

    return soniq.tree_map_layers(fix, params)


def train_cnn(qcfg: QuantConfig, *, t1: int, t2: int, lr: float = 3e-3,
              batch: int = BATCH, seed: int = 0,
              group_size: Optional[int] = None,
              original_freeze: bool = False) -> Dict:
    """Two-phase SONIQ training of the paper's CNN family on synthetic
    CIFAR-like data. Returns accuracy, bpp, and the pattern report."""
    if group_size is not None:
        qcfg = dataclasses.replace(qcfg, group_size=group_size)
    (xtr, ytr), (xte, yte) = data(seed)
    n = xtr.shape[0]
    key = jax.random.PRNGKey(seed)

    phase1 = qcfg.with_mode(soniq.Phase.NOISE) if t1 > 0 else None
    phase2 = qcfg.with_mode(soniq.Phase.QAT) \
        if qcfg.phase is not soniq.Phase.FP else qcfg

    cfg1 = cnn.CNNConfig(quant=phase1 or phase2, channels=CNN_CHANNELS,
                         blocks_per_stage=CNN_BLOCKS)
    params = cnn.cnn_init(key, cfg1)
    opt = adamw.init_state(params)
    # s_lr_mult=25: the paper runs Phase I for 350 *epochs*; the benchmark
    # compresses it to ~150 steps, so the precision logits get a faster
    # schedule to traverse the same s-range.
    ocfg = adamw.AdamWConfig(lr=lr, weight_decay=1e-4, s_lr_mult=25.0)

    def make_step(cfg):
        def step(params, opt, batch_x, batch_y, rng):
            def loss(p):
                return cnn.xent_loss(p, {"x": batch_x, "y": batch_y}, cfg,
                                     rng)[0]
            l, g = jax.value_and_grad(loss, allow_int=True)(params)
            params2, opt2, _ = adamw.apply_updates(params, g, opt, ocfg)
            return params2, opt2, l
        return jax.jit(step)

    # FP warm start (the paper fine-tunes trained nets; the noise search
    # needs roughly-converged weights to read out channel importance).
    if phase1 is not None:
        warm_cfg = cnn.CNNConfig(
            quant=phase1.with_mode(soniq.Phase.FP),
            channels=CNN_CHANNELS, blocks_per_stage=CNN_BLOCKS)
        warm_step = make_step(warm_cfg)
        rngs_w = np.random.default_rng(seed + 7)
        for it in range(max(t1 // 2, 20)):
            idx = rngs_w.integers(0, n, batch)
            params, opt, _ = warm_step(params, opt,
                                       jnp.asarray(xtr[idx]),
                                       jnp.asarray(ytr[idx]),
                                       jax.random.PRNGKey(it))

    step_fn = make_step(cfg1)
    rngs = np.random.default_rng(seed)
    report = None
    cfg_now = cfg1
    for it in range(t2):
        if it == t1 and phase1 is not None:
            params = jax.device_get(params)
            if original_freeze:
                params = freeze_original(params)
            else:
                params, report = soniq.freeze_qat(params, qcfg)
            cfg_now = cnn.CNNConfig(quant=phase2, channels=CNN_CHANNELS,
                                    blocks_per_stage=CNN_BLOCKS)
            opt = adamw.init_state(params)
            step_fn = make_step(cfg_now)
        idx = rngs.integers(0, n, batch)
        params, opt, _ = step_fn(params, opt, jnp.asarray(xtr[idx]),
                                 jnp.asarray(ytr[idx]),
                                 jax.random.PRNGKey(1000 + it))

    eval_cfg = cnn.CNNConfig(quant=phase2, channels=CNN_CHANNELS,
                             blocks_per_stage=CNN_BLOCKS)
    acc = cnn.accuracy(params, jnp.asarray(xte), jnp.asarray(yte), eval_cfg)
    bpp = cnn.bits_per_param(jax.device_get(params), qcfg) \
        if qcfg.phase is not soniq.Phase.FP else 32.0
    return {"accuracy": acc, "bpp": bpp, "report": report, "params": params,
            "cfg": eval_cfg}
