"""Pallas TPU kernels for the SONIQ hot paths (validated via the
``pallas_interpret`` backend).

packed_matmul — mixed 1/2/4-bit packed GEMM (the paper's vmac_Pn) plus the
                fused activation-quant prologue variant
quant_pack    — fused SMOL quantize + bit-pack
noise_inject  — fused Phase-I perturbation with in-kernel PRNG
fake_quant    — fused clipped-STE quantize-dequantize (QAT forward)
attn_decode   — fused quantized-KV flash-decode attention (serve)

These modules are the *implementations* behind the ``pallas_interpret`` /
``pallas_mosaic`` backends in :mod:`repro.backend`; the hot paths reach
them through the dispatch registry, never directly.

Naming: the DEPRECATED pre-registry wrappers in ``kernels.ops`` were
historically re-exported here under the same names as their home modules,
so ``repro.kernels.packed_matmul`` was the *function*, silently shadowing
the module and breaking ``importlib``-style access. The function names
still resolve for compat — via ``__getattr__``, with a
``DeprecationWarning`` — and every kernel module is additionally exposed
under an unambiguous ``*_mod`` alias (``packed_matmul_mod`` etc.); new
code should use :mod:`repro.backend` instead of either.
"""
import warnings as _warnings

from . import ops, prng, ref
from . import attn_decode, fake_quant, quant_pack  # unshadowed module names
from . import attn_decode as attn_decode_mod
from . import fake_quant as fake_quant_mod
from . import noise_inject as noise_inject_mod
from . import packed_matmul as packed_matmul_mod
from . import quant_pack as quant_pack_mod

# Importing a submodule binds it as a package attribute; drop the two
# bindings the legacy function re-exports shadow so access goes through
# __getattr__ (which warns). importlib.import_module and dotted-path
# `from repro.kernels.packed_matmul import ...` still work — they resolve
# via sys.modules, not these attributes.
del packed_matmul, noise_inject  # noqa: F821

# Legacy kernels.ops function re-exports (two of which shadow their home
# modules). Kept for compat; each access warns.
_DEPRECATED_FUNCS = ("packed_matmul", "packed_segment_matmul",
                     "quantize_pack", "noise_inject")

__all__ = ["ops", "prng", "ref", "attn_decode", "fake_quant", "quant_pack",
           "packed_matmul_mod", "quant_pack_mod", "noise_inject_mod",
           "fake_quant_mod", "attn_decode_mod"] + list(_DEPRECATED_FUNCS)


def __getattr__(name):
    if name in _DEPRECATED_FUNCS:
        _warnings.warn(
            f"`repro.kernels.{name}` resolves to the deprecated "
            f"kernels.ops wrapper function (for `packed_matmul` and "
            f"`noise_inject` it shadows the same-named kernel module); "
            f"use the `*_mod` module aliases or the repro.backend "
            "dispatch registry instead",
            DeprecationWarning, stacklevel=2)
        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
