"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; smoke tests see
one device)."""
from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    """jax >= 0.5 takes axis_types; older releases have neither the kwarg
    nor jax.sharding.AxisType (Auto is the implicit behaviour there)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def set_mesh(mesh):
    """Context manager for the ambient mesh: jax.set_mesh where available,
    else the Mesh object itself (the pre-0.5 spelling)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis (the multi-pod dry-run proves the pod axis shards)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-mesh)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
