"""DEPRECATED public wrappers over the kernel ops.

These functions predate the backend dispatch registry
(:mod:`repro.backend`) and remain as thin shims for external callers and
the historical kernel tests. New code selects a backend once
(``QuantConfig.backend`` / ``SONIQ_BACKEND`` / ``soniq.use_backend``) and
lets the phase rules dispatch — or calls the :class:`repro.backend.base
.Backend` methods directly.

Migration of the legacy ``interpret=`` kwarg (no longer part of any
public API — backend *names* replace it):

    interpret=None   registry "pallas" alias (mosaic on TPU, interpreter
                     elsewhere — the old ``default_interpret()`` behavior)
    interpret=True   the "pallas_interpret" backend
    interpret=False  the "pallas_mosaic" backend

The old ``packed_matmul`` wrapper's whole-batch activation scale is now
the driver's ``act_scale_mode="per_tensor"``; pass ``"per_token"`` for the
row-independent scale the serve engines use (DESIGN.md §10/§11).
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.backend import registry
from repro.core.qtypes import QuantConfig

from . import ref  # noqa: F401  (re-exported for tests/benchmarks)


def default_interpret() -> bool:
    """DEPRECATED — backend negotiation replaces the boolean."""
    return jax.default_backend() != "tpu"


def _backend_for(interpret: Optional[bool], fn: str):
    warnings.warn(
        f"kernels.ops.{fn} is deprecated; resolve a backend via "
        "repro.backend.registry (QuantConfig.backend / SONIQ_BACKEND / "
        "soniq.use_backend) and call its op methods instead",
        DeprecationWarning, stacklevel=3)
    if interpret is None:
        return registry.resolve("pallas")
    return registry.get("pallas_interpret" if interpret else "pallas_mosaic")


def packed_segment_matmul(x, wp, scales=None, *, p: int,
                          act_quant: bool = False, act_scale=None,
                          interpret: Optional[bool] = None, **blocks):
    """Uniform-precision packed GEMM; see packed_matmul.py."""
    b = _backend_for(interpret, "packed_segment_matmul")
    if act_quant and act_scale is not None:
        x = x / act_scale
    y = b.packed_segment_matmul(x, wp, scales, p=p, act_quant=act_quant,
                                **blocks)
    if act_quant and act_scale is not None:
        y = y * act_scale
    return y


def packed_matmul(x, serve_params: Dict, *, act_quant: bool = True,
                  act_scale_mode: str = "per_tensor",
                  interpret: Optional[bool] = None, **blocks):
    """Full SmolLinear serve-mode matmul over the [K4|K2|K1] segments of a
    packed serve leaf. Drop-in for the jnp serve path; the shared backend
    driver owns the segment iteration and activation scaling."""
    b = _backend_for(interpret, "packed_matmul")
    qcfg = QuantConfig(mode="serve", quantize_activations=act_quant,
                       act_scale_mode=act_scale_mode)
    # The historical wrapper returned the raw fp32 accumulator (its x/s
    # division promoted bf16 inputs to f32); feed the driver f32 so its
    # final cast back to x.dtype preserves that contract without a
    # round-trip through the narrow dtype.
    return b.packed_matmul(serve_params, jnp.asarray(x, jnp.float32),
                           qcfg, **blocks)


def quantize_pack(w, scales=None, *, p: int,
                  interpret: Optional[bool] = None, **blocks):
    b = _backend_for(interpret, "quantize_pack")
    return b.quantize_pack(w, scales, p=p, **blocks)


def noise_inject(w, s, seed, *, interpret: Optional[bool] = None, **blocks):
    b = _backend_for(interpret, "noise_inject")
    return b.noise_inject(w, s, seed, **blocks)
