from . import synthetic
