"""Smoke tests for the production launchers (subprocess, tiny configs)."""
import os
import subprocess
import sys

ENV = dict(os.environ, PYTHONPATH="src")


def test_train_launcher_runs_and_checkpoints(tmp_path):
    ck = str(tmp_path / "ck")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "h2o-danube-1.8b", "--reduced", "--steps", "6", "--batch", "2",
         "--seq", "32", "--ckpt", ck, "--hoist"],
        env=ENV, cwd=os.getcwd(), capture_output=True, text=True,
        timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done" in out.stdout
    assert os.path.exists(os.path.join(ck, "LATEST"))
    # resume path
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch",
         "h2o-danube-1.8b", "--reduced", "--steps", "8", "--batch", "2",
         "--seq", "32", "--ckpt", ck],
        env=ENV, cwd=os.getcwd(), capture_output=True, text=True,
        timeout=900)
    assert out2.returncode == 0, out2.stderr[-2000:]
    assert "resumed from step" in out2.stdout


def test_serve_launcher_generates(tmp_path):
    # mamba2 exercises the chunked-prefill fallback (SSM -> 1 token/step).
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "mamba2-2.7b", "--reduced", "--requests", "2", "--max-batch", "2",
         "--prompt-len", "4", "--new-tokens", "6", "--cache-len", "32"],
        env=ENV, cwd=os.getcwd(), capture_output=True, text=True,
        timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
    assert "[continuous]" in out.stdout
    assert "req 1 " in out.stdout
