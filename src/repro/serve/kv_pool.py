"""Paged KV-cache subsystem: block-pool allocator + paged device cache
(DESIGN.md §13).

The ring layouts (``models.attention`` fp, ``serve/kv_quant.py`` packed
q4) reserve ``max_batch x cache_len`` K/V slots up front, so cache memory
scales with *configured capacity*. This module pages the same payload into
fixed-size token pages drawn from one global pool, vLLM-style:

* **Device side** — per-layer cache dicts whose payload leaves are pools
  ``[P, page_size, Hk, ...]`` (``k_codes``/``v_codes``/``k_scale``/
  ``v_scale`` for q4, ``k``/``v`` for fp) plus pool-wide position stamps
  ``pos [P, page_size]`` (-1 = empty) and per-slot page tables
  ``page_table [B, NP]`` (physical page id per logical page, -1 =
  unmapped). Physical page 0 is the reserved **null page**: never
  allocated, payload zero, ``pos`` -1 forever — readers clamp unmapped
  ids to it, so a hole in a table reads as empty without special-casing.
* **Host side** — :class:`PagePool`, a jax-free allocator (mirror of the
  ``Scheduler`` split): free-list, per-page refcounts, copy-on-write for
  shared pages, and a content-hash prefix map so identical prompt pages
  are shared across requests (and cached LRU across request lifetimes).
  The pool never touches device memory itself; it emits :class:`StepOps`
  (pages to wipe, COW copies, the table) that the engine applies through
  one fixed-shape jitted call per step (:func:`apply_step_ops`).

Ring parity: logical page ``(pos // page_size) % NP`` at offset
``pos % page_size`` is exactly the ring slot ``pos % cache_len`` when
``page_size`` divides the ring length (the engine asserts it), and COW /
alloc preserve or wipe whole pages, so a paged ``DecodeEngine`` is
token-identical to the ring engine at temperature 0
(tests/test_kv_pool.py). Masked lanes (``pos < 0``) scatter out of
bounds and drop, exactly like both ring families.

``SONIQ_KV_POISON=1`` (or ``PagePool(poison=True)``) returns freed pages
poisoned — NaN scales/payload with the stale ``pos`` stamps kept — so a
stale page-table reference (use-after-free) turns the attention output
NaN instead of silently reading a recycled page. Pages are wiped clean at
allocation, so the knob is parity-preserving for correct code; it cannot
catch a stale read that happens *after* the page was legitimately
reallocated and rewritten (the classic ASAN reuse window).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

POISON_ENV = "SONIQ_KV_POISON"

# Leaf-name vocabulary of the paged family. Payload names deliberately
# match the ring families' so ``kv_quant.cache_payload_bytes`` accounts
# both layouts; ``page_table`` joins ``pos`` in the meta bucket there.
_Q4_PAYLOAD = ("k_codes", "v_codes", "k_scale", "v_scale")
_FP_PAYLOAD = ("k", "v")


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` tokens (ceil)."""
    return -(-tokens // page_size)


# ===================================================== device layout ====
def _paged_shapes(num_pages: int, page_size: int, pages_per_seq: int,
                  batch: int, num_kv_heads: int, head_dim: int,
                  kv_bits: Optional[int], dtype) -> Dict[str, Tuple]:
    assert num_pages >= 2, "pool needs the null page + >= 1 usable page"
    p, ps = num_pages, page_size
    if kv_bits is None:
        shapes = {"k": ((p, ps, num_kv_heads, head_dim), dtype),
                  "v": ((p, ps, num_kv_heads, head_dim), dtype)}
    else:
        assert kv_bits == 4, f"kv_bits must be None or 4, got {kv_bits}"
        assert head_dim % 2 == 0
        shapes = {
            "k_codes": ((p, ps, num_kv_heads, head_dim // 2), jnp.uint8),
            "v_codes": ((p, ps, num_kv_heads, head_dim // 2), jnp.uint8),
            "k_scale": ((p, ps, num_kv_heads, 1), jnp.float16),
            "v_scale": ((p, ps, num_kv_heads, 1), jnp.float16),
        }
    shapes["pos"] = ((p, ps), jnp.int32)
    shapes["page_table"] = ((batch, pages_per_seq), jnp.int32)
    return shapes


def init_paged_cache(num_pages: int, page_size: int, pages_per_seq: int,
                     batch: int, num_kv_heads: int, head_dim: int, *,
                     kv_bits: Optional[int] = None,
                     dtype=jnp.bfloat16) -> Dict:
    """One layer's paged KV cache: payload pools + pos stamps + tables.
    ``num_pages`` includes the reserved null page 0."""
    shapes = _paged_shapes(num_pages, page_size, pages_per_seq, batch,
                           num_kv_heads, head_dim, kv_bits, dtype)
    out = {}
    for name, (sh, dt) in shapes.items():
        fill = -1 if name in ("pos", "page_table") else 0
        out[name] = jnp.full(sh, fill, dt)
    return out


def paged_cache_specs(num_pages: int, page_size: int, pages_per_seq: int,
                      batch: int, num_kv_heads: int, head_dim: int, *,
                      kv_bits: Optional[int] = None,
                      dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStructs of :func:`init_paged_cache` (dry-run)."""
    shapes = _paged_shapes(num_pages, page_size, pages_per_seq, batch,
                           num_kv_heads, head_dim, kv_bits, dtype)
    return {name: jax.ShapeDtypeStruct(sh, dt)
            for name, (sh, dt) in shapes.items()}


def update_paged_cache(cache: Dict, k_new, v_new, pos, *,
                       layer_idx=None) -> Dict:
    """Write a chunk of new K/V (``k_new``/``v_new`` [B, S, H, D]) into
    the pages the table maps for positions ``pos`` ([B] or [B, S]).

    The destination of token ``pos`` is page
    ``page_table[b, (pos // page_size) % NP]`` at offset
    ``pos % page_size`` — the host allocator has already made every
    written page private and mapped (COW/alloc happen *before* the jitted
    step), so the scatter never lands on a shared page. Lanes with
    ``pos < 0`` or an unmapped table entry scatter out of bounds and drop
    (``mode="drop"``), the same masked-lane contract as both ring
    families. q4 caches quantize through ``kv_quant.quantize_kv``; fp
    caches store as-is. ``layer_idx`` selects the stacked ``[L, ...]``
    scan-carry layout.
    """
    stacked = layer_idx is not None
    table = cache["page_table"]
    if stacked:
        table = jax.lax.dynamic_index_in_dim(table, layer_idx, 0, False)
    npages = cache["pos"].shape[1 if stacked else 0]
    ps = cache["pos"].shape[-1]
    n_logical = table.shape[-1]
    posb = pos[:, None] if pos.ndim == 1 else pos            # [B, S]
    lp = ((posb // ps) % n_logical).astype(jnp.int32)
    pid = jnp.take_along_axis(table, lp, axis=1)             # [B, S]
    off = (posb % ps).astype(jnp.int32)
    # Masked / unmapped lanes scatter out of bounds -> dropped.
    dest = jnp.where((posb >= 0) & (pid >= 0), pid,
                     npages).astype(jnp.int32)
    if "k_codes" in cache:
        from . import kv_quant
        kc, ks = kv_quant.quantize_kv(k_new)
        vc, vs = kv_quant.quantize_kv(v_new)
        new = {"k_codes": kc, "v_codes": vc, "k_scale": ks, "v_scale": vs,
               "pos": posb}
    else:
        new = {"k": k_new, "v": v_new, "pos": posb}
    out = dict(cache)
    for name, val in new.items():
        leaf = cache[name]
        val = val.astype(leaf.dtype)
        if stacked:
            out[name] = leaf.at[layer_idx, dest, off].set(val, mode="drop")
        else:
            out[name] = leaf.at[dest, off].set(val, mode="drop")
    return out


def gather_paged(cache: Dict, dtype=jnp.float32):
    """Dense view of a paged layer cache: -> (k [B,T,Hk,D], v, pos [B,T])
    with T = NP * page_size — the jnp oracle the
    ``qkv_attn_decode_paged`` backend op is gated against. Unmapped table
    entries clamp to the null page (payload zero, pos -1), so holes read
    as empty ring entries."""
    table = cache["page_table"]                              # [B, NP]
    b, n_logical = table.shape
    safe = jnp.maximum(table, 0)

    def take(leaf):                                          # [P, ps, ...]
        return jnp.take(leaf, safe, axis=0)                  # [B, NP, ps, ...]

    pos = take(cache["pos"])
    pos = jnp.where(table[..., None] >= 0, pos, -1)
    ps = pos.shape[-1]
    t = n_logical * ps
    pos = pos.reshape(b, t)
    if "k_codes" in cache:
        from . import kv_quant
        k = kv_quant.dequantize_kv(
            take(cache["k_codes"]).reshape(b, t, *cache["k_codes"].shape[2:]),
            take(cache["k_scale"]).reshape(b, t, *cache["k_scale"].shape[2:]),
            dtype)
        v = kv_quant.dequantize_kv(
            take(cache["v_codes"]).reshape(b, t, *cache["v_codes"].shape[2:]),
            take(cache["v_scale"]).reshape(b, t, *cache["v_scale"].shape[2:]),
            dtype)
    else:
        k = take(cache["k"]).reshape(b, t, *cache["k"].shape[2:]).astype(dtype)
        v = take(cache["v"]).reshape(b, t, *cache["v"].shape[2:]).astype(dtype)
    return k, v, pos


# =============================================== device op application ====
def _walk_paged(tree, fn):
    """Apply ``fn`` to every paged cache dict (identified by a
    ``page_table`` leaf) in an lm cache tree; other nodes pass through."""
    if isinstance(tree, dict):
        if "page_table" in tree:
            return fn(tree)
        return {k: _walk_paged(v, fn) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_walk_paged(v, fn) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_walk_paged(v, fn) for v in tree)
    return tree


def apply_step_ops(cache, table, wipe, copy_src, copy_dst):
    """Apply one step's allocator decisions to every paged dict in the
    cache tree (jit once per shape — the engine pads the id vectors to a
    fixed capacity):

    * ``copy_src``/``copy_dst`` [C] int32 — COW: page ``dst`` becomes a
      full copy of ``src`` (payload + pos stamps, so a ring wraparound
      into a shared page preserves what the ring would have kept).
      Padding entries are (0, 0) self-copies of the null page (no-ops).
    * ``wipe`` [W] int32 — freshly allocated pages: payload zero, pos -1
      (clears any stale stamps or debug poison before reuse). Padding
      entries are the null page (already empty; re-wiping is idempotent).
    * ``table`` [B, NP] int32 — the new page tables, broadcast across the
      stacked layer dim (every layer writes the same token positions).

    Copies run before wipes; the allocator never wipes a COW destination
    (it receives a full copy) and never copies from a freed page.
    """
    table = jnp.asarray(table, jnp.int32)
    wipe = jnp.asarray(wipe, jnp.int32)
    src = jnp.asarray(copy_src, jnp.int32)
    dst = jnp.asarray(copy_dst, jnp.int32)

    def fix(d):
        stacked = d["page_table"].ndim == 3
        out = dict(d)
        for name, leaf in d.items():
            if name == "page_table":
                out[name] = (jnp.broadcast_to(table[None], leaf.shape)
                             if stacked else table)
                continue
            fill = jnp.full((), -1 if name == "pos" else 0, leaf.dtype)
            if stacked:
                leaf = leaf.at[:, dst].set(leaf[:, src])  # soniq-lint: disable=SQ001(op ids are null-page-padded by the pool)
                leaf = leaf.at[:, wipe].set(fill)  # soniq-lint: disable=SQ001(op ids are null-page-padded by the pool)
            else:
                leaf = leaf.at[dst].set(leaf[src])  # soniq-lint: disable=SQ001(op ids are null-page-padded by the pool)
                leaf = leaf.at[wipe].set(fill)  # soniq-lint: disable=SQ001(op ids are null-page-padded by the pool)
            out[name] = leaf
        return out

    return _walk_paged(cache, fix)


def apply_poison(cache, pids):
    """Poison freed pages (debug mode): NaN the fp payload / fp16 scales
    and 0xFF the packed codes, but KEEP the ``pos`` stamps — a stale
    page-table reference then sails through the position mask and turns
    the attention output NaN (0-weight x NaN is still NaN through the
    value contraction), which is the use-after-free trip wire
    ``SONIQ_KV_POISON=1`` buys. Allocation wipes the poison away before
    legitimate reuse (:func:`apply_step_ops`)."""
    pids = jnp.asarray(pids, jnp.int32)

    def fix(d):
        out = dict(d)
        for name, leaf in d.items():
            if name in ("pos", "page_table"):
                continue
            bad = jnp.full((), 0xFF if name.endswith("_codes")
                           else jnp.nan, leaf.dtype)
            out[name] = (leaf.at[:, pids].set(bad)  # soniq-lint: disable=SQ001(pids come from the host free-list)
                         if d["page_table"].ndim == 3
                         else leaf.at[pids].set(bad))  # soniq-lint: disable=SQ001(pids come from the host free-list)
        return out

    return _walk_paged(cache, fix)


def paged_payload_bytes_per_page(cache) -> int:
    """Payload bytes of ONE pool page summed over every paged dict (and
    stacked layer) in the cache tree — resident-byte accounting is
    ``pages_in_use x this``."""
    per_page = 0
    names = set(_Q4_PAYLOAD) | set(_FP_PAYLOAD)

    # Bytes of each payload leaf divided by its page count (stacked leaves
    # already include the layer dim in their total, so a "page" here means
    # the page's bytes across every layer — matching how the allocator
    # maps the same physical page id in all layers at once).
    def tally(d):
        nonlocal per_page
        stacked = d["page_table"].ndim == 3
        npages = d["pos"].shape[1 if stacked else 0]
        for name, leaf in d.items():
            if name in names:
                total = int(np.prod(leaf.shape, dtype=np.int64)) \
                    * np.dtype(leaf.dtype).itemsize
                per_page += total // npages
        return d

    _walk_paged(cache, tally)
    return per_page


# ======================================================= host allocator ====
def invariant_violations(pool) -> List[str]:
    """The PagePool state invariants as one shared, assert-free definition
    (DESIGN.md §16): consumed by :meth:`PagePool.check`, the fuzz harness
    in tests/test_kv_pool.py, and the explicit-state model checker
    (``repro.analysis.model_check``). Returns human-readable violation
    strings; empty means the state is sound.

    * the free list, cached LRU and mapped set partition the non-null
      pages (no double-free, no lost pages, no overlap);
    * the null page 0 is never on any list and never mapped;
    * ``refcount[p]`` equals the number of page-table references to ``p``;
    * every cached-LRU page is registered in the prefix map, and the
      prefix map and per-page hashes agree.
    """
    out: List[str] = []
    every = set(range(1, pool.num_pages))
    free = set(pool.free)
    cached = set(pool.cached)
    mapped = {int(p) for p in np.unique(pool.table[pool.table >= 0])}
    if len(free) != len(pool.free):
        dupes = sorted(p for p in free if pool.free.count(p) > 1)
        out.append(f"duplicate page(s) on free list: {dupes}")
    if 0 in free | cached | mapped:
        out.append("null page 0 leaked into free/cached/mapped")
    for a, b, an, bn in ((free, cached, "free", "cached"),
                         (free, mapped, "free", "mapped"),
                         (cached, mapped, "cached", "mapped")):
        both = a & b
        if both:
            out.append(f"page(s) in both {an} and {bn}: {sorted(both)}")
    lost = every - (free | cached | mapped)
    if lost:
        out.append(f"lost page(s) (free/cached/mapped cover nothing): "
                   f"{sorted(lost)}")
    want = np.zeros(pool.num_pages, np.int64)
    pids, counts = np.unique(pool.table[pool.table >= 0],
                             return_counts=True)
    want[pids] = counts
    if not (want == pool.refcount).all():
        drift = [(int(p), int(want[p]), int(pool.refcount[p]))
                 for p in np.nonzero(want != pool.refcount)[0]]
        out.append(f"refcount drift (pid, table refs, refcount): {drift}")
    for pid in sorted(cached):
        if pid not in pool.page_hash:
            out.append(f"cached page {pid} is not registered")
    for digest, pid in pool.prefix_map.items():
        if pool.page_hash.get(pid) != digest:
            out.append(f"prefix map / page hash drift at page {pid}")
    return out


def step_ops_violations(pool, ops: "StepOps") -> List[str]:
    """Check one engine-step batch of accumulated :class:`StepOps` against
    the no-shared-write and poison-cancel contracts, AFTER the allocator
    calls that filled it (shared definition for the fuzz harness and the
    model checker):

    * a wiped (freshly allocated) page must be exclusively ours
      (refcount 1) and not registered prefix content;
    * a COW destination likewise — COW exists precisely so shared or
      registered pages are never in-place write targets;
    * no page is both wiped and poisoned in one batch: the engine applies
      poisons after wipes, so a page freed and reallocated within the
      same batch must have had its poison cancelled (:meth:`_alloc`) or
      the stale poison corrupts the fresh allocation.
    """
    out: List[str] = []
    for pid in ops.wipes:
        if pool.refcount[pid] != 1:
            out.append(f"wiped page {pid} has refcount "
                       f"{int(pool.refcount[pid])} (must be exclusive)")
        if pid in pool.page_hash:
            out.append(f"wiped page {pid} is registered prefix content")
    for _src, dst in ops.copies:
        if pool.refcount[dst] != 1:
            out.append(f"COW destination {dst} has refcount "
                       f"{int(pool.refcount[dst])} (must be exclusive)")
        if dst in pool.page_hash:
            out.append(f"COW destination {dst} is registered prefix "
                       f"content")
    stale = set(ops.poisons) & set(ops.wipes)
    if stale:
        out.append(f"page(s) both wiped and poisoned in one batch "
                   f"(poison-cancel missed): {sorted(stale)}")
    return out


@dataclasses.dataclass
class StepOps:
    """Device work one or more allocator calls accumulated: applied by the
    engine through :func:`apply_step_ops` / :func:`apply_poison`."""
    wipes: List[int] = dataclasses.field(default_factory=list)
    copies: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    poisons: List[int] = dataclasses.field(default_factory=list)

    def any(self) -> bool:
        return bool(self.wipes or self.copies)


class PagePool:
    """Host-side page allocator: free-list + refcounts + COW + prefix map.

    Deliberately jax-free (the ``Scheduler`` split, DESIGN.md §10): all
    decisions happen here on numpy state; device effects are emitted as
    :class:`StepOps`. Invariants (pinned by the hypothesis property tests
    in tests/test_kv_pool.py):

    * every non-null page is in exactly one of {free list, cached LRU,
      mapped (refcount > 0)} — no double-free, no lost pages;
    * ``refcount[p]`` equals the number of page-table references to ``p``;
    * a page that is shared (refcount > 1) or registered in the prefix
      map is never handed out for in-place writes — rollover into it
      triggers copy-on-write;
    * the null page 0 is never allocated, never freed, never written.

    Prefix sharing: full prompt pages are content-hashed (a chain digest,
    so page i's hash commits to pages 0..i) and registered once fully
    written; a later request whose leading pages hash-match maps them
    refcounted instead of re-prefilling (the last prompt token is always
    re-fed — its logits seed sampling — so at most ``len(prompt) - 1``
    tokens resolve from the prefix map). Registered pages whose refcount
    drops to 0 are parked in a cached LRU and revived on the next hit;
    they are evicted (and unregistered) only when the free list runs dry.
    """

    def __init__(self, num_pages: int, page_size: int, pages_per_seq: int,
                 max_batch: int, *, poison: Optional[bool] = None):
        assert num_pages >= 2, "pool needs the null page + >= 1 usable page"
        assert page_size > 0 and pages_per_seq > 0
        self.num_pages = num_pages
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self.max_batch = max_batch
        if poison is None:
            poison = os.environ.get(POISON_ENV, "0") not in ("", "0")
        self.poison = bool(poison)
        # pop() hands out low ids first (nicer to read in tests/dumps)
        self.free: List[int] = list(range(num_pages - 1, 0, -1))
        self.refcount = np.zeros(num_pages, np.int64)
        self.table = np.full((max_batch, pages_per_seq), -1, np.int32)
        self.page_hash: Dict[int, bytes] = {}     # registered pid -> digest
        self.prefix_map: Dict[bytes, int] = {}    # digest -> canonical pid
        self.cached: "OrderedDict[int, bytes]" = OrderedDict()  # LRU
        self.lookups = 0
        self.hits = 0
        self.peak_resident = 0
        self._hash_memo: Dict[int, Tuple[bytes, ...]] = {}
        self._slot_hashes: Dict[int, Tuple[bytes, ...]] = {}
        self._target_pages: Dict[int, int] = {}
        # request_id -> net page demand reserved by an admissible() pass
        # that returned True; consumed by the matching admit().
        self._pending: Dict[int, int] = {}

    # -------------------------------------------------------- geometry ----
    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is reserved)."""
        return self.num_pages - 1

    @property
    def resident_pages(self) -> int:
        """Pages holding live data: mapped (refcount > 0) + cached-LRU
        prefix pages. Free (even poisoned) pages hold nothing."""
        return self.capacity - len(self.free)

    def target_pages(self, prompt_len: int) -> int:
        """Page demand of a prompt, capped at the per-sequence table
        length (longer prompts wrap the logical ring, reusing pages)."""
        return min(pages_for(prompt_len, self.page_size),
                   self.pages_per_seq)

    # --------------------------------------------------------- hashing ----
    def page_hashes(self, prompt) -> Tuple[bytes, ...]:
        """Chain digests of the prompt's FULL pages: hash of page i
        commits to the tokens of pages 0..i, so equal digests mean equal
        whole prefixes, never just an equal middle page."""
        toks = np.asarray(prompt, np.int32).reshape(-1)
        ps = self.page_size
        out = []
        h = b"soniq-paged-kv"
        for i in range(len(toks) // ps):
            h = hashlib.sha1(h + toks[i * ps:(i + 1) * ps].tobytes()).digest()
            out.append(h)
        return tuple(out)

    def _shareable(self, prompt, hashes) -> int:
        """How many leading pages an admission could map from the prefix
        map right now. Capped so the final prompt token is always re-fed
        (its logits seed sampling) and page demand never exceeds the
        table; the scan stops at the first miss (a prefix is contiguous
        by construction)."""
        plen = int(np.asarray(prompt).reshape(-1).shape[0])
        cap = min((plen - 1) // self.page_size, self.pages_per_seq - 1)
        n = 0
        for i in range(min(len(hashes), cap)):
            if hashes[i] not in self.prefix_map:
                break
            n += 1
        return n

    # ------------------------------------------------------- admission ----
    def note_submit(self, request_id: int, prompt) -> int:
        """Prefix-hash lookup at submit() time: memoize the prompt's page
        digests for admission and return how many pages would hit the
        prefix map today (observability; the authoritative mapping
        happens at :meth:`admit`)."""
        hashes = self.page_hashes(prompt)
        self._hash_memo[request_id] = hashes
        return self._shareable(prompt, hashes)

    def _outstanding_prompt_pages(self) -> int:
        """Prompt pages promised but not yet allocated: admitted slots
        whose prefills are still running, plus requests an
        :meth:`admissible` pass reserved for this step (their
        :meth:`admit` has not run yet)."""
        total = sum(self._pending.values())
        for slot, target in self._target_pages.items():
            mapped = int((self.table[slot] >= 0).sum())
            total += max(0, target - mapped)
        return total

    def admissible(self, request) -> bool:
        """Can the pool cover this request's prompt pages right now?
        Counts free + evictable-cached pages, minus pages already
        promised to in-flight prefills — the ``Scheduler.admit`` capacity
        callback (head-of-line blocking: FIFO order is preserved, the
        queue just waits for pages).

        A True return RESERVES the request's net page demand (keyed by
        ``request_id``) until the matching :meth:`admit` consumes it:
        ``Scheduler.admit`` checks each head-of-queue request in a loop
        before the engine runs any ``admit()``, so without the
        reservation the second request of a step would not see the
        first's demand and a tight pool could be overcommitted.

        Cached-LRU pages this request's own prefix would REVIVE must not
        also count as evictable: admit() takes a reference on each
        shareable page, so a parked (refcount 0) prefix hit leaves the
        LRU the moment the request is admitted — subtracting it from
        ``need`` as shareable while counting it in ``avail`` as
        evictable double-counts the page, and on a tight pool
        (free = 0, cached = the prefix pages) that admits a request
        whose first fresh allocation then dies with the mid-step
        pool-exhausted RuntimeError."""
        prompt = np.asarray(request.prompt).reshape(-1)
        rid = getattr(request, "request_id", None)
        hashes = self._hash_memo.get(rid)
        if hashes is None:
            hashes = self.page_hashes(prompt)
        shareable = self._shareable(prompt, hashes)
        revived = sum(
            1 for i in range(shareable)
            if self.refcount[self.prefix_map[hashes[i]]] == 0)
        need = self.target_pages(len(prompt)) - shareable
        avail = len(self.free) + (len(self.cached) - revived) \
            - self._outstanding_prompt_pages()
        ok = need <= avail
        if ok and rid is not None:
            self._pending[rid] = need
        return ok

    def admit(self, slot: int, request) -> int:
        """Map the request's shared prefix pages into ``slot``'s table and
        return the number of prompt tokens they already hold (the engine
        starts the prefill there). No pages are allocated here — writes
        allocate lazily through :meth:`prepare`."""
        assert (self.table[slot] < 0).all(), \
            f"slot {slot} admitted with a dirty table (missing release?)"
        prompt = np.asarray(request.prompt).reshape(-1)
        rid = getattr(request, "request_id", None)
        # The slot's _target_pages entry takes over capacity tracking
        # from the admissible() reservation.
        self._pending.pop(rid, None)
        hashes = self._hash_memo.pop(rid, None)
        if hashes is None:
            hashes = self.page_hashes(prompt)
        self._slot_hashes[slot] = hashes
        self._target_pages[slot] = self.target_pages(len(prompt))
        shared = self._shareable(prompt, hashes)
        for i in range(shared):
            self.lookups += 1
            self.hits += 1
            self._ref_page(self.prefix_map[hashes[i]])
            self.table[slot, i] = self.prefix_map[hashes[i]]
        if shared < len(hashes):
            self.lookups += 1                    # the probe that missed
        return shared * self.page_size

    # ------------------------------------------------------ allocation ----
    def _ref_page(self, pid: int):
        if self.refcount[pid] == 0:
            # Reviving a cached registered page: it leaves the LRU.
            self.cached.pop(pid, None)
        self.refcount[pid] += 1
        self.peak_resident = max(self.peak_resident, self.resident_pages)

    def _unref(self, pid: int, ops: StepOps):
        assert self.refcount[pid] > 0, f"double free of page {pid}"
        self.refcount[pid] -= 1
        if self.refcount[pid]:
            return
        if pid in self.page_hash:
            # Registered prefix pages park in the cached LRU (revivable).
            self.cached[pid] = self.page_hash[pid]
            self.cached.move_to_end(pid)
            return
        self.free.append(pid)
        if self.poison:
            ops.poisons.append(pid)

    def _alloc(self, ops: StepOps, *, wipe: bool) -> int:
        if self.free:
            pid = self.free.pop()
            if pid in ops.poisons:
                # Freed and reallocated within the same op batch: the
                # engine applies poisons after wipes, so a stale poison
                # would corrupt the fresh allocation — drop it (the wipe
                # clears the page either way).
                ops.poisons.remove(pid)
        elif self.cached:
            # Evict the least-recently-parked prefix page: it leaves the
            # prefix map for good (its bytes are about to be overwritten).
            pid, _digest = self.cached.popitem(last=False)
            self._unregister(pid)
        else:
            raise RuntimeError(
                "KV page pool exhausted mid-step: every page is mapped by "
                "an active request. Admission only reserves prompt pages; "
                "size the pool for decode growth (EngineConfig.num_pages "
                ">= max_batch * pages_per_seq + 1, the default) or lower "
                "max_batch.")
        self.refcount[pid] = 1
        if wipe:
            ops.wipes.append(pid)
        self.peak_resident = max(self.peak_resident, self.resident_pages)
        return pid

    def _unregister(self, pid: int) -> None:
        """Drop a page's prefix-map registration (its content is about to
        stop being canonical prompt bytes)."""
        digest = self.page_hash.pop(pid)
        if self.prefix_map.get(digest) == pid:
            del self.prefix_map[digest]

    def prepare(self, slot: int, start: int, width: int,
                ops: StepOps) -> None:
        """Make every page touched by the token positions
        ``[start, start + width)`` of ``slot`` privately writable before
        the device step: unmapped logical pages allocate (and wipe);
        mapped pages that are shared (refcount > 1) or registered
        (immutable prefix content) copy-on-write. Accumulates the device
        work into ``ops`` and updates the host table.

        One COW case degrades gracefully instead of raising: when the
        page is ours alone (refcount 1) and only registered, and the pool
        has no spare page anywhere (free and cached both empty — e.g. a
        full-residency slot's decode wrapping the logical ring with the
        default ``num_pages`` sizing), the canonical is unregistered and
        the page written in place — exactly where the ring layout would
        wrap. Future prompts with that prefix simply re-prefill."""
        assert width > 0
        ps, npg = self.page_size, self.pages_per_seq
        for lp_abs in range(start // ps, (start + width - 1) // ps + 1):
            lp = lp_abs % npg
            pid = int(self.table[slot, lp])
            if pid < 0:
                self.table[slot, lp] = self._alloc(ops, wipe=True)
            elif self.refcount[pid] > 1 or pid in self.page_hash:
                if self.refcount[pid] == 1 and not self.free \
                        and not self.cached:
                    self._unregister(pid)     # write in place (wrap)
                    continue
                new = self._alloc(ops, wipe=False)
                ops.copies.append((pid, new))
                self.table[slot, lp] = new
                self._unref(pid, ops)

    def note_filled(self, slot: int, prompt, n_fed: int) -> None:
        """Register ``slot``'s fully written prompt pages into the prefix
        map (call after each engine step advances). Only exact, final
        content registers: wrapped prompts (longer than the logical ring)
        never do — their early pages were overwritten — and a page whose
        digest already has a canonical copy is left private rather than
        remapped."""
        prompt = np.asarray(prompt).reshape(-1)
        plen = len(prompt)
        if plen > self.pages_per_seq * self.page_size:
            return
        hashes = self._slot_hashes.get(slot)
        if hashes is None:
            hashes = self.page_hashes(prompt)
        full = min(n_fed, plen) // self.page_size
        # Decode growth wrapping the logical ring overwrites the early
        # pages in place (registered pages COW away first, but a private
        # unregistered page is legally rewritten): page i no longer holds
        # prompt content once the wrap reached it, so it must not enter
        # the prefix map.
        wrapped_through = ((n_fed - 1) // self.page_size
                           - self.pages_per_seq
                           if n_fed > self.pages_per_seq * self.page_size
                           else -1)
        for i in range(min(full, len(hashes))):
            if i <= wrapped_through:
                continue
            pid = int(self.table[slot, i])
            if pid < 0 or pid in self.page_hash:
                continue                        # unmapped / already known
            if hashes[i] in self.prefix_map:
                continue                        # another copy is canonical
            self.prefix_map[hashes[i]] = pid
            self.page_hash[pid] = hashes[i]

    def forget_submit(self, request_id: int) -> None:
        """Cancellation of a still-queued request: drop its memoized page
        digests and any :meth:`admissible` reservation — the matching
        :meth:`admit` will never run to consume them, and a dangling
        reservation would hold back capacity forever."""
        self._hash_memo.pop(request_id, None)
        self._pending.pop(request_id, None)

    def rollback(self, slot: int, committed: int, touched: int,
                 ops: StepOps) -> None:
        """Speculative rollback (DESIGN.md §14): a verify pass rejected a
        draft suffix, so the slot's committed content ends at fed count
        ``committed`` while this round's writes reached positions
        ``[0, touched)``. Unmap (and unref) every logical page WHOLLY
        beyond the committed content that the round touched — those hold
        only rejected-draft KV. The boundary page (partially committed)
        stays mapped: its stale tail entries carry future position
        stamps, which the causal mask excludes until the positions are
        legitimately rewritten (the same argument that makes the ring
        layout's rollback pure accounting).

        Only valid when the round did not wrap the logical ring
        (``touched <= pages_per_seq * page_size`` — the engine's spec
        guard): after a wrap, a "stale" logical page also holds the only
        copy of older in-window history and must not be dropped. Every
        page touched this round came out of :meth:`prepare` private and
        unregistered, so the unref frees it outright (COW guarantees a
        shared prefix page was never written in the first place)."""
        assert 0 <= committed <= touched
        assert touched <= self.pages_per_seq * self.page_size, \
            (touched, self.pages_per_seq * self.page_size)
        first_stale = pages_for(committed, self.page_size)
        for lp in range(first_stale, pages_for(touched, self.page_size)):
            pid = int(self.table[slot, lp])
            if pid >= 0:
                self._unref(pid, ops)
                self.table[slot, lp] = -1

    def release(self, slot: int, ops: StepOps) -> None:
        """Drop every page reference of a finished/evicted slot.
        Unregistered pages go back on the free list (poisoned in debug
        mode); registered prefix pages park in the cached LRU for future
        hits."""
        for lp in range(self.pages_per_seq):
            pid = int(self.table[slot, lp])
            if pid >= 0:
                self._unref(pid, ops)
            self.table[slot, lp] = -1
        self._target_pages.pop(slot, None)
        self._slot_hashes.pop(slot, None)

    # ----------------------------------------------------- observability --
    @property
    def prefix_hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def check(self) -> None:
        """Assert the allocator invariants (test hook): the free list,
        cached LRU and mapped set partition the non-null pages, and
        refcounts equal table reference counts. The invariants themselves
        live in the module-level :func:`invariant_violations` so the fuzz
        harness and the model checker share the exact same definition."""
        bad = invariant_violations(self)
        assert not bad, "; ".join(bad)
