from . import engine, kv_pool, kv_quant, scheduler
