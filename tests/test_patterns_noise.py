"""Tests for the 45-pattern table, Problem-1 solver, PatternMatch, Phase-I
noise machinery, and the two-phase schedule boundary transform."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import noise, patterns, quant, schedule, smol
from repro.core.qtypes import QuantConfig


# ------------------------------------------------------------- patterns ----
def test_table2_structure():
    assert len(patterns.PATTERNS) == 45
    for (n1, n2, n4) in patterns.PATTERNS:
        assert n1 * 1 + n2 * 2 + n4 * 4 == 128          # fills the vector
        assert n1 % 16 == 0 and n2 % 8 == 0 and n4 % 4 == 0  # lane granularity
    # Spot-check the paper's Table II rows.
    assert patterns.PATTERNS[2] == (0, 16, 24)    # index 3
    assert patterns.PATTERNS[16] == (16, 56, 0)   # index 17
    assert patterns.PATTERNS[34] == (64, 32, 0)   # index 35


def test_design_point_subsets():
    p4 = patterns.patterns_for(4)
    assert p4 == [(0, 0, 32), (128, 0, 0), (0, 64, 0), (16, 56, 0)]
    assert len(patterns.patterns_for(8)) == 8
    assert len(patterns.patterns_for(45)) == 45


def test_problem1_uniform_cases():
    # All 4-bit: 320 elements -> 10 vectors of (0,0,32).
    sol = patterns.solve_problem1(320, 0, 0)
    assert sol.num_vectors == 10
    assert sol.counts == {(0, 0, 32): 10}
    # All 1-bit: 256 elements -> 2 vectors of (128,0,0).
    sol = patterns.solve_problem1(0, 0, 256)
    assert sol.num_vectors == 2


def test_problem1_promotion():
    # 16 four-bit + 112 one-bit elements = 176 bits -> needs 2 vectors
    # (16 4-bit elems leave only 64 bits, < 112 1-bit elems), and promotion
    # lets the solver satisfy the 1-bit demand with any leftover capacity.
    sol = patterns.solve_problem1(16, 0, 112, patterns.PATTERNS)
    assert sol.num_vectors == 2
    c4, c2, c1 = sol.element_budget()
    assert c4 >= 16 and c4 + c2 + c1 >= 128
    # 8 four-bit + 96 one-bit = 128 bits exactly -> pattern (96, 0, 8) fits in 1.
    sol1 = patterns.solve_problem1(8, 0, 96, patterns.PATTERNS)
    assert sol1.num_vectors == 1


def test_problem1_restricted_subset_needs_more_vectors():
    allowed = patterns.patterns_for(4)
    full = patterns.solve_problem1(100, 100, 100, patterns.PATTERNS)
    restr = patterns.solve_problem1(100, 100, 100, allowed)
    assert restr.num_vectors >= full.num_vectors


@given(st.integers(0, 500), st.integers(0, 500), st.integers(0, 500))
@settings(max_examples=25, deadline=None)
def test_property_problem1_covers(n4, n2, n1):
    sol = patterns.solve_problem1(n4, n2, n1)
    c4, c2, c1 = sol.element_budget()
    assert c4 >= n4
    assert c4 + c2 >= n4 + n2
    assert c4 + c2 + c1 >= n4 + n2 + n1
    # Lower bound: can never beat total-bits / 128.
    assert sol.num_vectors >= -(-(4 * n4 + 2 * n2 + n1) // 128)


def test_pattern_match_ranks_importance():
    # 24 groups; lowest s (most important) must land on 4 bits.
    s = np.linspace(-3, 3, 24)
    sol = patterns.solve_problem1(8 * 16, 8 * 16, 8 * 16)
    s_m = patterns.pattern_match(s, sol, 16)
    pb = patterns.precisions_from_matched_s(s_m)
    c4, c2, c1 = sol.element_budget()
    assert (pb == 4).sum() == c4 // 16
    order = np.argsort(s)
    assert set(pb[order[: c4 // 16]]) == {4}      # most important -> 4 bits
    assert pb[order[-1]] == 1                     # least important -> 1 bit


def test_reorder_channels():
    pbits = np.array([1, 4, 2, 4, 1, 2], np.int8)
    perm = patterns.reorder_channels(pbits)
    np.testing.assert_array_equal(pbits[perm], [4, 4, 2, 2, 1, 1])
    chan = patterns.expand_group_perm(perm, 4)
    assert chan.shape == (24,)
    assert sorted(chan.tolist()) == list(range(24))


def test_select_hardware_subset():
    hists = [(512, 256, 128), (128, 512, 256), (1024, 0, 0)]
    sub = patterns.select_hardware_subset(hists, 4)
    assert len(sub) == 4
    assert (0, 0, 32) in sub     # uniform-4 anchor always present


# ---------------------------------------------------------------- noise ----
def test_sigma_init_matches_roundoff():
    # sigma(s_init(p)) == 2^(1-p) — the paper's core identity.
    for p in (2, 4):   # (p=1 is the asymptotic case)
        assert float(noise.sigma(noise.s_init(p))) == pytest.approx(
            2.0 ** (1 - p), rel=1e-5)
    assert float(noise.sigma(noise.s_init(1))) > 0.999


def test_bits_soft_and_penalty():
    s = jnp.asarray([noise.s_init(4), noise.s_init(2)])
    np.testing.assert_allclose(np.asarray(noise.bits_soft(s)), [4.0, 2.0],
                               rtol=1e-5)
    assert float(noise.bit_penalty(s)) == pytest.approx(3.0 + 1.0, rel=1e-5)


def test_precision_readout_bands():
    s = jnp.asarray([noise.T_4B - 0.1, noise.T_4B + 0.1,
                     noise.T_2B - 0.1, noise.T_2B + 0.1])
    p = noise.snap_124(noise.precision_from_s(s))
    np.testing.assert_array_equal(np.asarray(p), [4, 2, 2, 1])


def test_weight_noise_bounds():
    key = jax.random.PRNGKey(0)
    w = jnp.zeros((32, 8))
    s = jnp.asarray([noise.s_init(4), noise.s_init(2)])
    wn = noise.inject_weight_noise(w, s, key, 16)
    wn = np.asarray(wn)
    assert np.max(np.abs(wn[:16])) <= 2 ** (1 - 4) + 1e-6
    assert np.max(np.abs(wn[16:])) <= 2 ** (1 - 2) + 1e-6
    # Clip: large weights end up inside +-(2 - sigma).
    w2 = jnp.full((32, 8), 5.0)
    wn2 = np.asarray(noise.inject_weight_noise(w2, s, key, 16))
    assert np.max(wn2[:16]) <= 2 - 2 ** (1 - 4) + 1e-6


def test_noise_grad_flows_to_s():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (16, 4))

    def loss(s):
        wn = noise.inject_weight_noise(w, s, key, 16)
        return jnp.sum(wn ** 2) + 1e-2 * noise.bit_penalty(s)

    g = jax.grad(loss)(jnp.asarray([0.0]))
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g[0])) > 0


# ----------------------------------------------------------- smol linear ----
@pytest.mark.parametrize("mode", ["fp", "noise", "qat"])
def test_linear_modes_shapes(mode):
    qcfg = QuantConfig(mode=mode)
    key = jax.random.PRNGKey(0)
    p = smol.linear_init(key, 64, 32, qcfg, use_bias=True)
    x = jax.random.normal(key, (3, 64))
    y = smol.linear_apply(p, x, qcfg, rng=key)
    assert y.shape == (3, 32)
    assert np.isfinite(np.asarray(y)).all()


def test_qat_close_to_fp_at_4bit():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128)) * 0.5

    # Weight-only 4-bit: tight.
    qw = QuantConfig(mode="qat", mix=(1.0, 0.0, 0.0),
                     quantize_activations=False)
    p = smol.linear_init(key, 128, 64, qw)
    y_fp = x @ p["w"]
    rel_w = float(jnp.linalg.norm(smol.linear_apply(p, x, qw) - y_fp)
                  / jnp.linalg.norm(y_fp))
    # absmax-scaled 4-bit on N(0,s) weights: error std ~= 0.127*s_w -> ~13%.
    assert rel_w < 0.16

    # W4A4 (paper's input-weight consistency): looser but bounded.
    qwa = QuantConfig(mode="qat", mix=(1.0, 0.0, 0.0))
    rel_wa = float(jnp.linalg.norm(smol.linear_apply(p, x, qwa) - y_fp)
                   / jnp.linalg.norm(y_fp))
    assert rel_wa < 0.35
    assert rel_w < rel_wa


def test_serve_matches_qat():
    """The packed serve path must reproduce the QAT fake-quant numerics
    (weight side exactly; activation side shares the same quantizer)."""
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25))
    key = jax.random.PRNGKey(0)
    p = smol.linear_init(key, 128, 32, qcfg)
    # scramble pbits so reordering is non-trivial
    pb = np.array([4, 1, 2, 4, 2, 1, 4, 4], np.int8)
    p["pbits"] = jnp.asarray(pb)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 128))
    y_qat = smol.linear_apply(p, x, qcfg)

    from repro.api import transforms
    sp = transforms.pack_linear(p, qcfg)
    qserve = QuantConfig(mode="serve", mix=qcfg.mix)
    y_srv = smol.linear_apply(sp, x, qserve)
    np.testing.assert_allclose(np.asarray(y_qat), np.asarray(y_srv),
                               rtol=2e-2, atol=2e-2)


def test_schedule_boundary_transform():
    qcfg = QuantConfig(mode="noise", num_patterns=4)
    key = jax.random.PRNGKey(0)
    params = {"layer0": smol.linear_init(key, 128, 16, qcfg),
              "layer1": smol.linear_init(key, 64, 16, qcfg)}
    # Pretend training moved s around.
    params["layer0"]["s"] = jnp.asarray(np.linspace(-3, 6, 8), jnp.float32)
    new, report = schedule.pattern_match_params(params, qcfg)
    assert "s" not in new["layer0"] and "pbits" in new["layer0"]
    assert new["layer0"]["pbits"].shape == (8,)
    assert set(np.asarray(new["layer0"]["pbits"]).tolist()) <= {1, 2, 4}
    assert 1.0 <= schedule.average_bpp(report) <= 4.0
    # QAT forward works on the transformed tree.
    qat = QuantConfig(mode="qat", num_patterns=4)
    x = jax.random.normal(key, (2, 128))
    y = smol.linear_apply(new["layer0"], x, qat)
    assert np.isfinite(np.asarray(y)).all()


def test_bit_penalty_of_params_tree():
    qcfg = QuantConfig(mode="noise")
    key = jax.random.PRNGKey(0)
    params = {"a": smol.linear_init(key, 32, 8, qcfg),
              "nested": {"b": smol.linear_init(key, 32, 8, qcfg)}}
    pen = float(smol.bit_penalty_of_params(params))
    assert pen == pytest.approx(2 * 2 * 3.0, rel=1e-4)  # 2 layers * 2 groups * (4-1)
