"""``pallas_interpret`` / ``pallas_mosaic`` — the Pallas kernel backends.

Both route the per-segment ops through the fused TPU kernels in
``repro.kernels`` (in-register unpack + dequant + MXU GEMM — with the
serve activation quantization fused into its prologue —, fused SMOL
quantize+pack, in-kernel-PRNG noise, fused QAT fake_quant forward).
``pallas_interpret`` runs them under
the Pallas interpreter (any platform — the CI parity leg);
``pallas_mosaic`` compiles through Mosaic and is only available on a real
TPU. Selection between them is a registry concern ("pallas" alias);
``interpret`` is an implementation detail that no public API exposes.

Geometry the kernels cannot express (a K narrower than the 16-channel
group, carrier rows that do not tile) falls back per-call to the jnp
reference math — which is numerically *identical* for these ops (integer
pack outputs, hash-exact noise), so the fallback is invisible; it is a
shape-coverage escape hatch, not a different answer.

Block shapes come from :mod:`repro.backend.autotune`: an on-disk cache
keyed by (op, shape, dtype, platform), falling back to the static defaults
the kernels shipped with. Lookup is trace-time-safe (no timing inside a
trace); measurement is explicit (``autotune.autotune_op`` /
``benchmarks/runtime_proxy.py --autotune``).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qtypes import GROUP_SIZE

# The kernels package still answers the legacy function names (with a
# DeprecationWarning); import the kernel modules by their dotted paths.
_pm = importlib.import_module("repro.kernels.packed_matmul")
_qp = importlib.import_module("repro.kernels.quant_pack")
_ni = importlib.import_module("repro.kernels.noise_inject")
_fq = importlib.import_module("repro.kernels.fake_quant")
_ad = importlib.import_module("repro.kernels.attn_decode")

from . import autotune
from .base import Backend
from .registry import register
from .xla_ref import XLA_REF as _REF   # per-call geometry fallback

# Trace-time dispatch counters for the fused kernel paths. CI's
# SONIQ_BACKEND=pallas_interpret leg asserts the serve driver actually
# engaged the fused activation-quant prologue (not the jnp fallback).
_FUSED_ACT_CALLS = 0
_FAKE_QUANT_KERNEL_CALLS = 0
_QKV_ATTN_CALLS = 0
_QKV_PAGED_CALLS = 0


def fused_act_call_count() -> int:
    """How many times a Pallas backend dispatched the fused activation-
    quant GEMM kernel (counted at trace time, not per executed step)."""
    return _FUSED_ACT_CALLS


def fake_quant_kernel_call_count() -> int:
    """How many times a Pallas backend dispatched the fused fake_quant
    forward kernel (vs the jnp geometry fallback)."""
    return _FAKE_QUANT_KERNEL_CALLS


def qkv_attn_call_count() -> int:
    """How many times a Pallas backend dispatched the fused quantized-KV
    flash-decode kernel (vs the dequantize-everything jnp fallback) —
    counted at trace time; CI's pallas_interpret leg asserts the q4 serve
    path actually engaged the kernel (DESIGN.md §12)."""
    return _QKV_ATTN_CALLS


def qkv_attn_paged_call_count() -> int:
    """How many times a Pallas backend dispatched the paged flash-decode
    kernel (page-table walk + online softmax, DESIGN.md §13) vs the dense
    gather oracle — counted at trace time; CI's paged leg asserts the
    paged serve path actually engaged the kernel, not the fallback."""
    return _QKV_PAGED_CALLS


class PallasBackend(Backend):
    """Shared Pallas plumbing; ``interpret`` picks the execution mode."""

    interpret: bool = True

    def _blocks(self, op: str, shape, p, dtype, blocks):
        """Explicit caller blocks win; else the autotune cache; else the
        kernel defaults (autotune returns {} on a miss)."""
        if blocks:
            return blocks
        return autotune.lookup(op, shape=shape, p=p, dtype=dtype,
                               backend=self.name)

    def packed_segment_matmul(self, x, wp, scales=None, *, p: int,
                              act_quant: bool = False,
                              group_size: int = GROUP_SIZE, **blocks):
        if group_size != GROUP_SIZE or x.ndim != 2 \
                or x.shape[1] == 0 or x.shape[1] % GROUP_SIZE:
            return _REF.packed_segment_matmul(
                x, wp, scales, p=p, act_quant=act_quant,
                group_size=group_size)
        m, kp = x.shape
        blocks = self._blocks("packed_segment_matmul", (m, kp, wp.shape[1]),
                              p, x.dtype, blocks)
        return _pm.packed_segment_matmul(x, wp, scales, p=p,
                                         act_quant=act_quant,
                                         interpret=self.interpret, **blocks)

    def fused_act_segment_matmul(self, x, wp, scales=None, act_scales=None,
                                 *, p: int, group_size: int = GROUP_SIZE,
                                 in_kernel_scale: bool = False, **blocks):
        if group_size != GROUP_SIZE or x.ndim != 2 \
                or x.shape[1] == 0 or x.shape[1] % GROUP_SIZE:
            return _REF.fused_act_segment_matmul(
                x, wp, scales, act_scales, p=p, group_size=group_size,
                in_kernel_scale=in_kernel_scale)
        global _FUSED_ACT_CALLS
        _FUSED_ACT_CALLS += 1
        m, kp = x.shape
        blocks = self._blocks("fused_act_segment_matmul",
                              (m, kp, wp.shape[1]), p, x.dtype, blocks)
        if in_kernel_scale:
            # Single-segment fast path: the kernel reduces the per-token
            # abs-max itself (full-K x block) — no [M, 1] jnp pass.
            return _pm.fused_act_selfscale_matmul(
                x, wp, scales, p=p, interpret=self.interpret, **blocks)
        if act_scales is None:
            act_scales = jnp.ones((m, 1), jnp.float32)
        return _pm.fused_act_segment_matmul(
            x, act_scales, wp, scales, p=p, interpret=self.interpret,
            **blocks)

    def qkv_attn_decode(self, q, cache, q_pos, *, window=None, **blocks):
        """Fused quantized-KV flash-decode (kernels/attn_decode.py): the
        packed codes and fp16 scales are unpacked/applied inside the
        attention inner loop, never materialized as a [B,T,Hk,D] fp
        buffer. Falls back to the jnp oracle for geometry the kernel does
        not cover (odd head_dim, empty ring, mismatched carrier
        shapes)."""
        b, s, hk, g, d = q.shape
        kc = cache["k_codes"]
        if d % 2 or kc.ndim != 4 or kc.shape != (b, kc.shape[1], hk, d // 2) \
                or kc.shape[1] == 0:
            return _REF.qkv_attn_decode(q, cache, q_pos, window=window)
        global _QKV_ATTN_CALLS
        _QKV_ATTN_CALLS += 1
        t = kc.shape[1]
        blocks = self._blocks("qkv_attn_decode", (b * hk * s * g, t, d),
                              4, q.dtype, blocks)
        return _ad.qkv_attn_decode(
            q, kc, cache["v_codes"], cache["k_scale"], cache["v_scale"],
            cache["pos"], q_pos, window=window, interpret=self.interpret,
            **blocks)

    def qkv_attn_decode_paged(self, q, cache, q_pos, *, window=None,
                              **blocks):
        """Paged flash-decode (kernels/attn_decode.py): walks the slot's
        page table over the global pool with an online softmax — no dense
        gather, no [SG, T] score row. The kernel covers the packed-q4
        pool; the fp paged family and geometry the kernel cannot express
        (odd head_dim, empty pool) fall back to the gather oracle."""
        b, s, hk, g, d = q.shape
        kc = cache.get("k_codes")
        npg = cache["page_table"].shape[-1]
        if kc is None or d % 2 or kc.ndim != 4 \
                or kc.shape[2:] != (hk, d // 2) or kc.shape[0] == 0 \
                or kc.shape[1] == 0:
            return _REF.qkv_attn_decode_paged(q, cache, q_pos,
                                              window=window)
        global _QKV_PAGED_CALLS
        _QKV_PAGED_CALLS += 1
        npages, ps = kc.shape[0], kc.shape[1]
        blocks = self._blocks("qkv_attn_decode_paged",
                              (b * hk * s * g, npg, ps, d), 4, q.dtype,
                              blocks)
        return _ad.qkv_attn_decode_paged(
            q, kc, cache["v_codes"], cache["k_scale"], cache["v_scale"],
            cache["pos"], cache["page_table"], q_pos, window=window,
            interpret=self.interpret, **blocks)

    def quantize_pack(self, w, scales=None, *, p: int,
                      group_size: int = GROUP_SIZE, **blocks):
        if group_size != GROUP_SIZE or w.ndim != 2 \
                or w.shape[0] % GROUP_SIZE:
            return _REF.quantize_pack(w, scales, p=p, group_size=group_size)
        blocks = self._blocks("quantize_pack", tuple(w.shape), p, w.dtype,
                              blocks)
        return _qp.quantize_pack(w, scales, p=p, interpret=self.interpret,
                                 **blocks)

    def _noise_inject_fwd(self, w, s, seed, group_size, blocks):
        if group_size != GROUP_SIZE or w.ndim != 2 \
                or w.shape[0] % GROUP_SIZE:
            return super()._noise_inject_fwd(w, s, seed, group_size, blocks)
        blocks = self._blocks("noise_inject", tuple(w.shape), 0, w.dtype,
                              blocks)
        return _ni.noise_inject(w, s, seed, interpret=self.interpret,
                                **blocks)

    def _fake_quant_fwd(self, x, pbits, scale, group_size):
        """Fused QAT quantize-dequantize forward. Falls back to the jnp
        reference (numerically identical element-wise math) for geometry
        the kernel does not cover: non-16 groups, K not a multiple of the
        group, or a scale layout that is neither per-row nor per-group."""
        pb = jnp.asarray(pbits)
        k = x.shape[-1] if x.ndim else 0
        if (group_size != GROUP_SIZE or x.ndim < 1 or k == 0
                or k % GROUP_SIZE or pb.ndim != 1
                or pb.shape[0] * GROUP_SIZE != k):
            return super()._fake_quant_fwd(x, pbits, scale, group_size)
        ng = k // GROUP_SIZE
        lead = x.shape[:-1]
        m = int(np.prod(lead, dtype=np.int64)) if lead else 1
        if m == 0:
            return super()._fake_quant_fwd(x, pbits, scale, group_size)
        s = jnp.asarray(scale, jnp.float32)
        if s.ndim == 0 or (s.shape[-1] == 1
                           and all(d == 1 for d in s.shape[:-1])):
            s_op, row = jnp.broadcast_to(s.reshape(-1, 1), (m, 1)), True
        elif s.shape == lead + (1,):
            s_op, row = s.reshape(m, 1), True
        elif s.ndim == 1 and s.shape[0] == ng:
            s_op, row = s, False
        else:
            return super()._fake_quant_fwd(x, pbits, scale, group_size)
        global _FAKE_QUANT_KERNEL_CALLS
        _FAKE_QUANT_KERNEL_CALLS += 1
        blocks = self._blocks("fake_quant", (m, k), 0, x.dtype, {})
        y2 = _fq.fake_quant(x.reshape(m, k), pb, s_op, row_scale=row,
                            interpret=self.interpret, **blocks)
        return y2.reshape(x.shape)


class PallasInterpretBackend(PallasBackend):

    name = "pallas_interpret"
    priority = 10                      # correct everywhere, fast nowhere
    interpret = True


class PallasMosaicBackend(PallasBackend):

    name = "pallas_mosaic"
    priority = 100                     # the point of the whole exercise
    interpret = False

    def is_available(self) -> bool:
        return jax.default_backend() == "tpu"

    def why_unavailable(self) -> str:
        return (f"requires a TPU (jax default backend is "
                f"{jax.default_backend()!r})")


PALLAS_INTERPRET = register(PallasInterpretBackend())
PALLAS_MOSAIC = register(PallasMosaicBackend())
