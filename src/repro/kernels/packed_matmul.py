"""Mixed ultra-low-precision packed GEMM — the TPU `vmac_Pn` (paper §IV-B).

One uniform-precision segment per pallas_call (the paper's sorted-run
execution, Obs. 4): x [M, Kp] @ Wpacked [Kp*p//8, N] -> [M, N] f32, with
in-register unpack (shift/mask), affine SMOL dequant
``v = (2u - (2^p - 1)) * 2^(1-p)``, optional per-16-channel-group scales,
optional activation snap-to-grid (input-weight consistency, Obs. 3), and
fp32 MXU accumulation (the paper's 16.6 accumulator widened to TPU-native).

Grid (M/bm, N/bn, Kp/bk), K innermost (accumulation). VMEM working set per
step at defaults (bm=bk=256, bn=128, f32):
    x 256x256x4 = 256 KiB, wp <= 256x128 = 32 KiB, out 256x128x4 = 128 KiB,
    unpacked w 256x128x4 = 128 KiB  ->  ~0.6 MiB of ~16 MiB VMEM.
MXU dims (bm, bk, bn) are multiples of 128/8 as required.

These kernels are segment-oblivious by design: the draft (low-slice)
forward of self-speculative decoding (DESIGN.md §14) is NOT a new kernel —
the shared ``Backend.packed_matmul`` driver simply invokes the same
segment GEMMs over only the segments whose precision is within
``QuantConfig.draft_slice_bits``, skipping the high-bit carriers. Weight
traffic drops with the skipped bytes; per-segment arithmetic is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.qtypes import GROUP_SIZE
from repro.core.quant import ACT_SCALE_EPS

_GRID_TOP_4 = 2.0 - 2.0 ** (1 - 4)      # quant._static_grid_max(4) = 1.875


def _tpu_compiler_params():
    """K is the innermost (accumulation) grid dim — mark it 'arbitrary' so
    Mosaic may not reorder/parallelize it. Ignored in interpret mode."""
    # The class was renamed TPUCompilerParams -> CompilerParams across jax
    # releases; accept either spelling.
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=("parallel", "parallel", "arbitrary"))


def fit_block(total: int, want: int, multiple: int = 1) -> int:
    """Largest divisor of ``total`` that is <= want and a multiple of
    ``multiple`` (segment sizes are only guaranteed multiples of the
    16-channel group, not of the preferred MXU tile)."""
    want = min(want, total)
    for d in range(want, multiple - 1, -1):
        if total % d == 0 and d % multiple == 0:
            return d
    assert total % multiple == 0, (total, multiple)
    return multiple


def _unpack_dequant(wp, p: int, bk: int):
    """[bk*p//8, bn] uint8 -> [bk, bn] f32 on the SMOL grid (no scale)."""
    vpb = 8 // p
    mask = np.uint8((1 << p) - 1)
    parts = [((wp >> np.uint8(p * j)) & mask) for j in range(vpb)]
    u = jnp.stack(parts, axis=1).reshape(bk, wp.shape[-1])
    u = u.astype(jnp.float32)
    return (2.0 * u - float(2 ** p - 1)) * float(2.0 ** (1 - p))


def _snap(x, p: int):
    """Snap (already scale-normalized) activations to the p-bit grid."""
    h = float(2.0 ** (1 - p))
    two_p = float(2 ** p)
    u = jnp.clip(jnp.round((x / h + (two_p - 1.0)) / 2.0), 0.0, two_p - 1.0)
    return (2.0 * u - (two_p - 1.0)) * h


def _accumulate(xq, wp_ref, s_ref, o_ref, *, p: int, bk: int,
                use_scales: bool):
    """Shared GEMM tail of both segment kernels: zero the accumulator on
    the first K step, unpack-dequant the weight tile, apply per-group
    scales, accumulate the MXU dot. One implementation so the fused and
    plain kernels cannot drift apart."""
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    wd = _unpack_dequant(wp_ref[...], p, bk)
    if use_scales:
        sig = s_ref[...].astype(jnp.float32)            # [bk//16, 1]
        sig = jnp.repeat(sig, GROUP_SIZE, axis=0)       # [bk, 1]
        wd = wd * sig
    o_ref[...] += jax.lax.dot(xq, wd, preferred_element_type=jnp.float32)


def _kernel(x_ref, wp_ref, s_ref, o_ref, *, p: int, bk: int,
            act_quant: bool, use_scales: bool):
    x = x_ref[...].astype(jnp.float32)
    if act_quant:
        x = _snap(x, p)
    _accumulate(x, wp_ref, s_ref, o_ref, p=p, bk=bk, use_scales=use_scales)


def _fused_kernel(x_ref, sx_ref, wp_ref, s_ref, o_ref, *, p: int, bk: int,
                  use_scales: bool):
    """Segment GEMM with the activation fake-quant fused into the prologue:
    divide by the per-token scale, snap to the p-bit grid, rescale, and
    round through the activation dtype — the exact element-wise arithmetic
    of ``core.quant.fake_quant`` — before the MXU dot. One HBM read of x,
    no materialized quantized-activation tensor."""
    x = x_ref[...].astype(jnp.float32)
    sx = sx_ref[...].astype(jnp.float32)                # [bm, 1] per token
    xq = (_snap(x / sx, p) * sx).astype(x_ref.dtype).astype(jnp.float32)
    _accumulate(xq, wp_ref, s_ref, o_ref, p=p, bk=bk, use_scales=use_scales)


def _fused_selfscale_kernel(x_ref, wp_ref, s_ref, o_ref, *, p: int,
                            bk: int, use_scales: bool):
    """Single-segment fused GEMM that computes the per-token abs-max scale
    *in-kernel* (the ROADMAP "in-kernel per-token abs-max" item): the x
    block spans the FULL K row (its index map pins the K grid dim to 0, so
    the tile stays resident across K steps), making the [bm, 1] reduction
    available in the prologue — the last [M, K] -> [M, 1] jnp pass over
    the activations disappears. Legal only when one uniform-precision
    segment spans the whole row: a row crossing segment boundaries would
    need the reduction across kernel invocations (DESIGN.md §11), which is
    exactly why the multi-segment form keeps the scale in the driver.

    Mirrors ``core.quant.abs_max_scale`` element-for-element (fp32 abs-max
    over the full row, ``ACT_SCALE_EPS`` clamp, divide by the 4-bit grid
    top 1.875) so it is bit-exact with the driver-scale form."""
    x = x_ref[...].astype(jnp.float32)                  # [bm, K] full row
    m = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    # The barrier keeps the compiler from strength-reducing the division
    # by the constant grid top into a reciprocal multiply (1-ulp off),
    # which would break bitwise parity with the driver-side act_scale.
    grid_top = jax.lax.optimization_barrier(jnp.float32(_GRID_TOP_4))
    sx = jnp.maximum(m, ACT_SCALE_EPS) / grid_top       # [bm, 1]
    xk = jax.lax.dynamic_slice(x, (0, pl.program_id(2) * bk),
                               (x.shape[0], bk))
    xq = (_snap(xk / sx, p) * sx).astype(x_ref.dtype).astype(jnp.float32)
    _accumulate(xq, wp_ref, s_ref, o_ref, p=p, bk=bk, use_scales=use_scales)


def _segment_call(kern, x, wp, s2d, *extra, bm, bn, bk, p, extra_specs=(),
                  interpret, x_spec=None):
    """Shared pallas_call assembly of the segment GEMMs: (M/bm, N/bn,
    Kp/bk) grid with K innermost, x/wp/per-group-scale block specs (any
    ``extra`` operands slot between x and wp), f32 output. ``x_spec``
    overrides the default K-tiled x block (the self-scale kernel pins the
    full K row instead)."""
    m, kp = x.shape
    n = wp.shape[1]
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, kp // bk),
        in_specs=[
            x_spec or pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            *extra_specs,
            pl.BlockSpec((bk * p // 8, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // GROUP_SIZE, 1), lambda i, j, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_tpu_compiler_params(),
        interpret=interpret,
    )(x, *extra, wp, s2d)


def _fit_segment_blocks(x, wp, p, block_m, block_n, block_k):
    m, kp = x.shape
    assert wp.shape[0] * (8 // p) == kp, (wp.shape, kp, p)
    return (fit_block(m, block_m), fit_block(wp.shape[1], block_n),
            fit_block(kp, block_k, GROUP_SIZE))


def _prep_scales(scales, kp):
    use_scales = scales is not None
    if not use_scales:  # dummy operand keeps one kernel signature
        scales = jnp.ones((kp // GROUP_SIZE,), jnp.float32)
    return use_scales, scales.reshape(-1, 1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "p", "block_m", "block_n", "block_k", "act_quant", "interpret"))
def packed_segment_matmul(x, wp, scales, *, p: int, block_m: int = 256,
                          block_n: int = 128, block_k: int = 256,
                          act_quant: bool = False, interpret: bool = True):
    """x [M, Kp] @ unpack(wp [Kp*p//8, N]) -> [M, N] f32.

    scales: [Kp//16] per-group f32 or None. Pre-divide x by the activation
    scale (and rescale the output) when act_quant=True.
    """
    bm, bn, bk = _fit_segment_blocks(x, wp, p, block_m, block_n, block_k)
    use_scales, s2d = _prep_scales(scales, x.shape[1])
    kern = functools.partial(_kernel, p=p, bk=bk, act_quant=act_quant,
                             use_scales=use_scales)
    return _segment_call(kern, x, wp, s2d, bm=bm, bn=bn, bk=bk, p=p,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "p", "block_m", "block_n", "block_k", "interpret"))
def fused_act_segment_matmul(x, sx, wp, scales, *, p: int,
                             block_m: int = 256, block_n: int = 128,
                             block_k: int = 256, interpret: bool = True):
    """Fused-prologue segment GEMM: quantize the activations to the p-bit
    grid with per-token scales ``sx`` [M, 1] *inside* the kernel, then
    x @ unpack(wp) -> [M, N] f32.

    Numerically this is fake_quant(x, p, sx) followed by
    ``packed_segment_matmul(..., act_quant=False)`` — bit-exactly, since the
    in-kernel prologue runs the same element-wise arithmetic (divide, snap,
    rescale, round-trip through x.dtype) — but without writing the
    quantized activation tensor back to HBM between the two. The per-token
    abs-max reduction itself stays in the driver: the scale spans the full
    permuted K row, which crosses segment (and therefore kernel) boundaries.
    """
    assert sx.shape == (x.shape[0], 1), (sx.shape, x.shape)
    bm, bn, bk = _fit_segment_blocks(x, wp, p, block_m, block_n, block_k)
    use_scales, s2d = _prep_scales(scales, x.shape[1])
    kern = functools.partial(_fused_kernel, p=p, bk=bk,
                             use_scales=use_scales)
    sx_spec = pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0))
    return _segment_call(kern, x, wp, s2d, jnp.asarray(sx, jnp.float32),
                         bm=bm, bn=bn, bk=bk, p=p, extra_specs=(sx_spec,),
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "p", "block_m", "block_n", "block_k", "interpret"))
def fused_act_selfscale_matmul(x, wp, scales, *, p: int,
                               block_m: int = 256, block_n: int = 128,
                               block_k: int = 256, interpret: bool = True):
    """Single-segment fused-prologue GEMM with the per-token abs-max scale
    computed *inside* the kernel: for a uniform-precision layer (one
    segment spans the whole K row) this removes the remaining [M, K] ->
    [M, 1] jnp reduction pass — activations are read once, scaled,
    snapped and multiplied without ever leaving VMEM.

    Bit-exact with ``fused_act_segment_matmul(x, act_scale(x), ...)`` (and
    therefore with the two-pass reference): the in-kernel reduction runs
    the same fp32 abs-max / ``ACT_SCALE_EPS`` clamp / grid-top divide as
    ``core.quant.abs_max_scale``, and the abs-max is row-permutation-
    invariant so driver-side channel reordering does not perturb it.
    """
    bm, bn, bk = _fit_segment_blocks(x, wp, p, block_m, block_n, block_k)
    use_scales, s2d = _prep_scales(scales, x.shape[1])
    kern = functools.partial(_fused_selfscale_kernel, p=p, bk=bk,
                             use_scales=use_scales)
    x_spec = pl.BlockSpec((bm, x.shape[1]), lambda i, j, k: (i, 0))
    return _segment_call(kern, x, wp, s2d, bm=bm, bn=bn, bk=bk, p=p,
                         interpret=interpret, x_spec=x_spec)
