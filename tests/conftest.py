"""Shared fixtures for the tier-1 suite."""
import pytest


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path, monkeypatch):
    """Point the block-size autotune cache at a per-test tmpdir.

    The suite must never read a developer's (or CI runner's)
    ``~/.cache/soniq/autotune.json`` — a stale tuned entry would silently
    change the block shapes every Pallas-backed test runs with — and must
    never write there either.
    """
    from repro.backend import autotune

    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.invalidate()
    yield
    autotune.invalidate()
