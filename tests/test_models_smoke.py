"""Per-architecture smoke tests: reduced config, one forward + one train
grad + one decode step on CPU; asserts shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import lm


def _batch_for(cfg, key, b=2, s=32):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jnp.where(jax.random.uniform(key, (b, s)) < 0.9,
                       jax.random.randint(key, (b, s), 0, cfg.vocab_size),
                       -1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["positions"] = jnp.broadcast_to(jnp.arange(s)[None, None],
                                              (3, b, s))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    hidden, aux = lm.forward(params, cfg, tokens=batch["tokens"],
                             frames=batch.get("frames"),
                             positions=batch.get("positions"),
                             rng=jax.random.PRNGKey(2))
    assert hidden.shape == (2, 32, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    loss, metrics = lm.loss_fn(params, batch, cfg, jax.random.PRNGKey(3))
    assert np.isfinite(float(loss))
    # one grad step to exercise backward (int leaves like pbits get float0)
    g = jax.grad(lambda p: lm.loss_fn(p, batch, cfg,
                                      jax.random.PRNGKey(3))[0],
                 allow_int=True)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    b, cache_len = 2, 64
    cache = lm.init_cache(cfg, b, cache_len, jnp.float32, enc_len=16)
    if cfg.encoder_layers:
        frames = jax.random.normal(key, (b, 16, cfg.frontend_dim))
        enc_out = lm.encode(params, cfg, frames)
        cache["cross"] = lm.build_cross_cache(params, cfg, enc_out)
    tok = jnp.asarray([1, 2], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)
    for step in range(3):
        logits, cache = lm.decode_step(params, cfg, cache, tok, pos + step)
        assert logits.shape == (b, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits.

    Quantization mode must be fp here: with dynamic per-tensor activation
    scales, decode (absmax over 1 token) and forward (absmax over S tokens)
    legitimately quantize differently — equivalence of the cache machinery
    itself is what this test pins down.
    """
    import dataclasses
    from repro.core.qtypes import QuantConfig
    cfg = get_config("h2o-danube-1.8b").reduced()
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="fp"))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    hidden, _ = lm.forward(params, cfg, tokens=tokens)
    full_logits = lm.logits(params, cfg, hidden)        # [B,S,V]

    cache = lm.init_cache(cfg, b, 64, jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = lm.decode_step(params, cfg, cache, tokens[:, t],
                                   jnp.asarray([t]))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    import dataclasses
    from repro.core.qtypes import QuantConfig
    cfg = get_config("mamba2-2.7b").reduced()
    cfg = dataclasses.replace(cfg, quant=QuantConfig(mode="fp"))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    hidden, _ = lm.forward(params, cfg, tokens=tokens)
    full_logits = lm.logits(params, cfg, hidden)
    cache = lm.init_cache(cfg, b, 64, jnp.float32)
    outs = []
    for t in range(s):
        lg, cache = lm.decode_step(params, cfg, cache, tokens[:, t],
                                   jnp.asarray([t]))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_match_reported_sizes():
    """Analytic param counts should land near the published model sizes."""
    approx = {
        "starcoder2-7b": 7.2e9,
        "deepseek-67b": 67e9,
        "mistral-large-123b": 123e9,
        "mixtral-8x22b": 141e9,
        "deepseek-moe-16b": 16.4e9,
        "qwen2-vl-72b": 72e9,
        "mamba2-2.7b": 2.7e9,
        "jamba-1.5-large-398b": 398e9,
        "h2o-danube-1.8b": 1.8e9,
        "whisper-medium": 0.77e9,
    }
    for arch, want in approx.items():
        n = get_config(arch).param_count()
        assert 0.6 * want < n < 1.45 * want, (arch, n, want)


def test_active_params_less_than_total_for_moe():
    for arch in ("mixtral-8x22b", "deepseek-moe-16b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()
