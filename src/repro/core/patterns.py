"""Precision patterns: the paper's Table II, Problem 1, and PatternMatch.

A *pattern* assigns each of the 8 sixteen-channel groups in a 128-channel
block one precision from {1,2,4} with same-precision groups contiguous and
sorted 4 -> 2 -> 1 (paper Obs. 4). Counting by elements, a pattern is
(n1, n2, n4) = (16a, 8b, 4c) with a+b+c = 8 — exactly the paper's 45
patterns. (In the paper an element is one packed value in a 128-bit vector;
on TPU an "element" is one channel slot of the 16-channel group's packed
carrier — the arithmetic is identical.)

Problem 1 (paper §IV-A): given a trained distribution with N4/N2/N1 elements
per precision, choose a multiset of patterns minimizing the number of
vectors subject to the promotion-aware covering constraints
    sum n4_i >= N4
    sum (n4_i + n2_i) >= N4 + N2
    sum (n4_i + n2_i + n1_i) >= N4 + N2 + N1
tie-broken by maximal average precision. Solved exactly with scipy MILP.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Sequence, Tuple

import numpy as np
from scipy import optimize as sopt

from .qtypes import GROUP_SIZE, GROUPS_PER_BLOCK


def all_patterns() -> List[Tuple[int, int, int]]:
    """The 45 patterns as (n1, n2, n4) element counts, in the paper's Table II
    order (n1 ascending, then n2 ascending)."""
    pats = []
    for a in range(GROUPS_PER_BLOCK + 1):          # 1-bit groups
        for b in range(GROUPS_PER_BLOCK + 1 - a):  # 2-bit groups
            c = GROUPS_PER_BLOCK - a - b           # 4-bit groups
            pats.append((16 * a, 8 * b, 4 * c))
    return pats


PATTERNS = all_patterns()
assert len(PATTERNS) == 45
assert PATTERNS[0] == (0, 0, 32) and PATTERNS[8] == (0, 64, 0)
assert PATTERNS[9] == (16, 0, 28) and PATTERNS[44] == (128, 0, 0)

# Paper Table III: pattern indices (1-based) of each design point.
DESIGN_POINT_PATTERNS = {
    4: [1, 45, 9, 17],
    8: [1, 45, 9, 17, 16, 35, 38, 15],
    45: list(range(1, 46)),
}


def patterns_for(np_patterns: int) -> List[Tuple[int, int, int]]:
    idx = DESIGN_POINT_PATTERNS[np_patterns]
    return [PATTERNS[i - 1] for i in idx]


def pattern_avg_bits(pat: Tuple[int, int, int]) -> float:
    n1, n2, n4 = pat
    tot = n1 + n2 + n4
    return (n1 + 2 * n2 + 4 * n4) / tot if tot else 0.0


@dataclasses.dataclass
class PatternSolution:
    num_vectors: int
    counts: Dict[Tuple[int, int, int], int]     # pattern -> multiplicity
    capacity: Tuple[int, int, int]              # total (cap4, cap2, cap1) elems

    def element_budget(self) -> Tuple[int, int, int]:
        """(num4b, num2b, num1b) element slots, in priority order, as consumed
        by PatternMatch."""
        c4 = sum(m * p[2] for p, m in self.counts.items())
        c2 = sum(m * p[1] for p, m in self.counts.items())
        c1 = sum(m * p[0] for p, m in self.counts.items())
        return c4, c2, c1


def solve_problem1(n4: int, n2: int, n1: int,
                   allowed: Sequence[Tuple[int, int, int]] = PATTERNS,
                   ) -> PatternSolution:
    """Exact Problem-1 solve: min #vectors, then max total capacity bits."""
    allowed = list(allowed)
    m = len(allowed)
    a4 = np.array([p[2] for p in allowed], float)
    a2 = np.array([p[1] for p in allowed], float)
    a1 = np.array([p[0] for p in allowed], float)

    # Covering constraints (>=) as  -A x <= -b.
    A = np.stack([a4, a4 + a2, a4 + a2 + a1])
    b = np.array([n4, n4 + n2, n4 + n2 + n1], float)
    lc = sopt.LinearConstraint(A, lb=b, ub=np.inf)
    integrality = np.ones(m)
    bounds = sopt.Bounds(0, np.inf)

    res = sopt.milp(c=np.ones(m), constraints=lc, integrality=integrality,
                    bounds=bounds)
    if not res.success:  # pragma: no cover - covering is always feasible
        raise RuntimeError(f"Problem 1 infeasible: {res.message}")
    p_star = int(round(res.fun))

    # Tie-break: among solutions with exactly p_star vectors, maximize total
    # capacity bits (highest average precision heuristic, paper §IV-A).
    bits = 4 * a4 + 2 * a2 + 1 * a1
    eq = sopt.LinearConstraint(np.ones((1, m)), lb=p_star, ub=p_star)
    res2 = sopt.milp(c=-bits, constraints=[lc, eq], integrality=integrality,
                     bounds=bounds)
    x = np.round(res2.x if res2.success else res.x).astype(int)
    counts = {allowed[i]: int(x[i]) for i in range(m) if x[i] > 0}
    cap = (int(x @ a4), int(x @ a2), int(x @ a1))
    return PatternSolution(num_vectors=p_star, counts=counts, capacity=cap)


def histogram_from_s(s: np.ndarray, group_size: int = GROUP_SIZE
                     ) -> Tuple[int, int, int]:
    """(N4, N2, N1) element counts from a per-group s vector (system-aware:
    every channel in a group shares its s)."""
    s = np.asarray(s)
    # Same banding as noise.snap_124 applied to the raw readout.
    raw = 1.0 + np.log2(1.0 + np.exp(-s.astype(np.float64)))
    p = np.where(raw >= 2.5, 4, np.where(raw >= 1.5, 2, 1))
    n4 = int((p == 4).sum()) * group_size
    n2 = int((p == 2).sum()) * group_size
    n1 = int((p == 1).sum()) * group_size
    return n4, n2, n1


def pattern_match(s: np.ndarray, solution: PatternSolution,
                  group_size: int = GROUP_SIZE) -> np.ndarray:
    """Paper Alg. 3 PatternMatch: rank channel-groups by importance (lower s
    = more important), give the num4b most important groups 4 bits, the next
    num2b 2 bits, the rest 1 bit — all consistent with the solved pattern
    multiset. Returns the transformed s vector."""
    from . import noise
    s = np.asarray(s, np.float64)
    c4, c2, c1 = solution.element_budget()
    g4, g2 = c4 // group_size, c2 // group_size
    order = np.argsort(s, kind="stable")     # ascending: most important first
    s_new = np.empty_like(s)
    s_new[order[:g4]] = noise.S_4B
    s_new[order[g4:g4 + g2]] = noise.S_2B
    s_new[order[g4 + g2:]] = noise.S_1B
    return s_new


def precisions_from_matched_s(s_matched: np.ndarray) -> np.ndarray:
    """Per-group {1,2,4} precisions after PatternMatch."""
    raw = 1.0 + np.log2(1.0 + np.exp(-np.asarray(s_matched, np.float64)))
    return np.where(raw >= 2.5, 4, np.where(raw >= 1.5, 2, 1)).astype(np.int8)


def reorder_channels(pbits: np.ndarray) -> np.ndarray:
    """Permutation making same-precision groups contiguous, sorted 4->2->1
    (paper Obs. 4). Returns group-level permutation indices (stable, so the
    within-precision order is preserved)."""
    rank = {4: 0, 2: 1, 1: 2}
    keys = np.array([rank[int(p)] for p in np.asarray(pbits)])
    return np.argsort(keys, kind="stable")


def expand_group_perm(group_perm: np.ndarray, group_size: int = GROUP_SIZE
                      ) -> np.ndarray:
    """Group-level permutation -> channel-level permutation."""
    base = np.asarray(group_perm)[:, None] * group_size + np.arange(group_size)
    return base.reshape(-1)


def select_hardware_subset(layer_histograms: Sequence[Tuple[int, int, int]],
                           np_patterns: int) -> List[Tuple[int, int, int]]:
    """Paper §V-A: run Problem 1 per representative layer with ALL patterns
    allowed, tally which patterns get used, and keep the np most frequent
    (always including the uniform patterns that anchor the table)."""
    if np_patterns >= len(PATTERNS):
        return list(PATTERNS)
    tally: Counter = Counter()
    for (n4, n2, n1) in layer_histograms:
        sol = solve_problem1(n4, n2, n1)
        for pat, mult in sol.counts.items():
            tally[pat] += mult
    ranked = [p for p, _ in tally.most_common()]
    for anchor in ((0, 0, 32), (128, 0, 0), (0, 64, 0)):  # paper's P4 anchors
        if anchor not in ranked:
            ranked.append(anchor)
    out = ranked[:np_patterns]
    i = 0
    while len(out) < np_patterns:
        if PATTERNS[i] not in out:
            out.append(PATTERNS[i])
        i += 1
    return out


def metadata_ints(pbits: np.ndarray) -> Tuple[int, int, int]:
    """Per-layer metadata: just 3 ints (paper Obs. 1-4) — the number of
    channel-groups at each precision."""
    p = np.asarray(pbits)
    return int((p == 4).sum()), int((p == 2).sum()), int((p == 1).sum())
