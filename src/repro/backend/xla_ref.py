"""``xla_ref`` — the pure-jnp/XLA reference backend.

Every op is ordinary jnp lowered by XLA: correct on any platform, the
parity oracle for the accelerated backends, and the fastest choice on CPU
(interpret-mode Pallas is an interpreter). The per-segment GEMM matches
``kernels.ref.packed_segment_matmul_ref`` (generalized to non-16 group
sizes so layers narrower than a group still pack), and quantize/noise
reuse the same ``core.quant``/hash primitives the kernels implement, so
cross-backend comparisons are exact for integer outputs and fp32 math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import pack as pack_lib
from repro.core import quant
from repro.core.qtypes import GROUP_SIZE

from .base import Backend
from .registry import register


class XlaRefBackend(Backend):

    name = "xla_ref"
    priority = 50                      # default everywhere off-TPU

    def packed_segment_matmul(self, x, wp, scales=None, *, p: int,
                              act_quant: bool = False,
                              group_size: int = GROUP_SIZE, **blocks):
        del blocks                     # block shapes are a kernel concern
        kp = wp.shape[0] * (8 // p)
        u = pack_lib.unpack_codes(wp, p, kp)
        wd = quant.dequantize_int(u, p)
        if scales is not None:
            s_full = jnp.repeat(scales.astype(jnp.float32), group_size,
                                total_repeat_length=kp)
            wd = wd * s_full[:, None]
        xs = jnp.asarray(x, jnp.float32)
        if act_quant:
            xs = quant.snap_to_grid(xs, p)
        return jax.lax.dot_general(
            xs, wd.astype(jnp.float32),
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    def quantize_pack(self, w, scales=None, *, p: int,
                      group_size: int = GROUP_SIZE, **blocks):
        del blocks
        k = w.shape[0]
        ws = jnp.asarray(w, jnp.float32)
        if scales is not None:
            s_full = jnp.repeat(scales.astype(jnp.float32), group_size,
                                total_repeat_length=k)
            ws = ws / s_full[:, None]
        u = quant.quantize_to_int(ws, p).astype(jnp.uint8)
        return pack_lib.pack_codes(u, p)

    # noise_inject / fake_quant / fused_act_segment_matmul: the shared
    # reference implementations in Backend are already pure jnp — nothing
    # to override. In particular NOT overriding fused_act_segment_matmul
    # keeps this backend on the two-pass activation-quant form, which is
    # what makes it the exactness oracle the fused Pallas prologue is
    # gated against (DESIGN.md §11).


XLA_REF = register(XlaRefBackend())
