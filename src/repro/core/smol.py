"""SmolLinear — the universal quantized linear primitive.

Every matmul in every model in this framework goes through ``linear_apply``.
The lifecycle phase (``QuantConfig.phase``) selects the forward rule:

  Phase.FP     y = x @ W                                  (baseline)
  Phase.NOISE  Phase I:  y = (x + sx*sigma(s)*eps) @ clip(W + sw*sigma(s)*eps')
  Phase.QAT    Phase II: y = fq(x; p, sx) @ fq(W; p, sw)  (clipped STE)
  Phase.SERVE  y = q(x) @ unpack_dequant(Wpacked)         (packed 1/2/4-bit)

Each rule is registered against its :class:`~repro.core.phases.PhaseSpec`
(``@Phase.X.defrule("linear")``) so dispatch is by phase identity, not
string comparison; ``repro.api`` exposes the typed lifecycle transforms
between phases. Per-16-channel-group precisions p on the K (input/reduction)
dim are shared by weights and activations (paper Obs. 3), segments
[K4|K2|K1] contiguous (paper Obs. 4), fp32 accumulation (TPU adaptation of
the paper's 16.6 fixed-point accumulator).

The quantized ops inside each rule (packed matmul, fake quant, noise
inject) execute on a pluggable kernel backend resolved from
``QuantConfig.backend`` / ``SONIQ_BACKEND`` / ``soniq.use_backend`` via
``repro.backend.registry`` — the serve path runs the real Pallas kernels
when a Pallas backend is selected, and the pure-jnp ``xla_ref`` emulation
otherwise (DESIGN.md §11).
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import noise as noise_lib
from . import quant
from .phases import Phase
from .qtypes import QuantConfig


def _backend(qcfg: QuantConfig):
    """The kernel backend this config's ops dispatch to (resolved at trace
    time; lazy import keeps ``repro.core`` importable without pulling the
    Pallas toolchain until a quantized op actually runs)."""
    from repro.backend import registry
    return registry.resolve(qcfg.backend_name)


def num_groups(k: int, group_size: int) -> int:
    """Compat wrapper — prefer ``QuantConfig.num_groups(k)``."""
    if k < group_size:
        return 1
    assert k % group_size == 0, (k, group_size)
    return k // group_size


def eff_group_size(k: int, group_size: int) -> int:
    """Compat wrapper — prefer ``QuantConfig.eff_group_size(k)``."""
    return k if k < group_size else group_size


def init_pbits_from_mix(k: int, qcfg: QuantConfig) -> np.ndarray:
    """Compat wrapper — prefer ``QuantConfig.group_pbits(k)``."""
    return qcfg.group_pbits(k)


def linear_init(key, k: int, n: int, qcfg: QuantConfig, *,
                use_bias: bool = False, dtype=jnp.float32,
                quantized: bool = True, scale: float = 1.0) -> Dict:
    """Initialize SmolLinear params. ``quantized=False`` for skip layers."""
    wkey, _ = jax.random.split(key)
    std = scale / np.sqrt(k)
    params: Dict = {"w": (jax.random.normal(wkey, (k, n), jnp.float32) * std
                          ).astype(dtype)}
    if use_bias:
        params["b"] = jnp.zeros((n,), dtype)
    phase = qcfg.phase
    if not quantized or phase is Phase.FP:
        return params
    if phase is Phase.NOISE:
        params["s"] = noise_lib.init_s(qcfg.num_groups(k), qcfg.p_init)
    elif phase is Phase.QAT:
        params["pbits"] = jnp.asarray(qcfg.group_pbits(k))
    elif phase is Phase.SERVE:
        # Packed-buffer layout per qcfg.mix (zero codes; real deployments
        # fill these via soniq.to_serve). Materialized from the phase's
        # param_schema so the dry-run specs and init share one layout,
        # with the non-zero metadata (identity perm, mix precisions, unit
        # scales) filled in.
        del params["w"]
        for name, sd in Phase.SERVE.param_schema(k, n, qcfg).items():
            if name == "b":
                continue
            if name == "perm":
                params[name] = jnp.arange(k, dtype=jnp.int32)
            elif name == "pbits_sorted":
                params[name] = jnp.asarray(qcfg.group_pbits(k))
            elif name == "wscale":
                params[name] = None if sd is None \
                    else jnp.ones(sd.shape, sd.dtype)
            else:
                params[name] = jnp.zeros(sd.shape, sd.dtype)
    return params


def _weight_scales(w, qcfg: QuantConfig, group_size: int):
    if qcfg.scale_mode == "none":
        return jnp.ones((num_groups(w.shape[0], group_size),), jnp.float32)
    return quant.per_group_weight_scale(w, group_size)


def _act_scale(x, qcfg: QuantConfig):
    from repro.backend import base as backend_base
    return backend_base.act_scale(x, qcfg.act_scale_mode)


def _quantize_weight(w, pbits, qcfg: QuantConfig, group_size: int):
    """fake-quant W [K, N] along K with per-group precisions.

    Runs on the kernel backend's ``fake_quant`` op: the QAT forward is a
    fused Pallas kernel on the Pallas backends (no intermediate xs/q
    tensors in HBM) and the jnp reference elsewhere, with the clipped-STE
    backward shared through one custom VJP — so Phase-II gradients are
    identical on every backend."""
    sw = _weight_scales(w, qcfg, group_size)                  # [K//G]
    wq_t = _backend(qcfg).fake_quant(jnp.swapaxes(w, 0, 1), pbits,
                                     sw, group_size)          # [N, K]
    return jnp.swapaxes(wq_t, 0, 1)


def _quantize_act(x, pbits, qcfg: QuantConfig, group_size: int):
    if not qcfg.quantize_activations:
        return x
    sx = _act_scale(x, qcfg)
    return _backend(qcfg).fake_quant(x, pbits, sx, group_size)


def _matmul(x, w, b=None):
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def linear_apply(params: Dict, x, qcfg: QuantConfig,
                 rng: Optional[jax.Array] = None):
    """x: [..., K] -> [..., N]. Dispatches on the lifecycle phase; a leaf
    holding only a plain weight (skip layer) always runs the FP rule."""
    phase = qcfg.phase
    if phase is not Phase.FP and Phase.FP.owns_leaf(params):
        phase = Phase.FP  # skip layer: holds only a plain weight
    if phase is Phase.SERVE and "w4" not in params:
        raise ValueError(
            "serve-phase linear got an unconverted leaf (keys "
            f"{sorted(params)}); run soniq.to_serve / convert_tree first")
    return phase.rule("linear")(params, x, qcfg, rng)


@Phase.FP.defrule("linear")
def _linear_fp(params, x, qcfg, rng):
    return _matmul(x, params["w"], params.get("b"))


@Phase.NOISE.defrule("linear")
def _linear_noise(params, x, qcfg, rng):
    assert rng is not None, "Phase I needs an rng"
    w, b = params["w"], params.get("b")
    k = w.shape[0]
    g = qcfg.eff_group_size(k)
    kw, kx = jax.random.split(rng)
    # Normalize group abs-max to 1.0 (not grid-max 1.875): the Phase-I
    # clip +-(2 - sigma) must not bite below sigma ~= 1, else its loss
    # gradient stalls the precision search at ~sigma 0.27 for every
    # group (the paper's scale-free setting has weights well inside +-2).
    sw = _weight_scales(w, qcfg, g) * float(quant._static_grid_max(4))
    wf = jnp.asarray(w, jnp.float32) / jnp.repeat(
        sw, g, total_repeat_length=k)[:, None]
    # The weight perturbation runs on the kernel backend (fused
    # perturb+clip with in-kernel counter-hash PRNG on Pallas; the same
    # hash in jnp on xla_ref — bit-identical across backends, and
    # differentiable in (w, s) via the shared custom VJP).
    seed = jax.random.bits(kw, (), jnp.uint32)
    wn = _backend(qcfg).noise_inject(wf, params["s"], seed, group_size=g)
    wn = (wn * jnp.repeat(sw, g, total_repeat_length=k)[:, None]
          ).astype(x.dtype)
    if qcfg.quantize_activations:
        sx = _act_scale(x, qcfg)
        x = noise_lib.inject_act_noise(x, params["s"], kx, sx, g)
    return _matmul(x, wn, b)


@Phase.QAT.defrule("linear")
def _linear_qat(params, x, qcfg, rng):
    w, b = params["w"], params.get("b")
    g = qcfg.eff_group_size(w.shape[0])
    pbits = params["pbits"].astype(jnp.float32)
    if qcfg.prequantized:
        wq = w.astype(x.dtype)       # already on the grid (hoisted)
    else:
        wq = _quantize_weight(w, pbits, qcfg, g).astype(x.dtype)
    xq = _quantize_act(x, pbits, qcfg, g)
    return _matmul(xq, wq, b)


@Phase.SERVE.defrule("linear")
def _linear_serve(params, x, qcfg, rng):
    """Packed-weight inference path. The whole op (channel perm,
    ``act_scale_mode``-aware activation quantization, per-[K4|K2|K1]-segment
    unpack-dequant GEMM, fp32 accumulation) is the backend's shared
    ``packed_matmul`` driver: ``xla_ref`` runs the pure-jnp emulation of the
    kernel arithmetic (uint8 loads -> shift/mask unpack -> affine dequant ->
    matmul), the Pallas backends run the fused kernels — including, when
    ``qcfg.fuse_act_quant`` allows, the activation quantization folded into
    the segment kernel's prologue instead of a separate full-tensor
    ``fake_quant`` pass per decode step. Segment order and activation
    scaling live in the driver, so backends agree token-for-token at fp32
    (DESIGN.md §11 "Fused activation quantization").

    When ``qcfg.draft_slice_bits`` is set (self-speculative draft
    forward, DESIGN.md §14), the driver runs the same segment loop over
    only the segments at or below that precision — the low-bit slice of
    the same packed carriers. Nothing changes here: the flag rides the
    qcfg this rule already threads through."""
    return _backend(qcfg).packed_matmul(params, x, qcfg)


def prequantize_tree(params, qcfg: QuantConfig, compute_dtype=jnp.bfloat16):
    """Fake-quantize every (w, pbits) weight in the tree ONCE (per step),
    casting to the compute dtype. Differentiable: wrap in jax.vjp at the
    call site so the microbatch scan consumes already-quantized weights and
    the quantize backward runs once (§Perf 'hoisted weight quantization').
    Handles stacked scan/expert leading dims via vmap."""
    def fix(node):
        if not (isinstance(node, dict) and "w" in node and "pbits" in node):
            return node
        node = dict(node)
        w, pbits = node["w"], node["pbits"]
        g = qcfg.eff_group_size(w.shape[-2])

        def q2d(w2, pb):
            return _quantize_weight(w2, pb.astype(jnp.float32), qcfg, g)

        fn = q2d
        for _ in range(w.ndim - 2):
            fn = jax.vmap(fn)
        node["w"] = fn(w, pbits).astype(compute_dtype)
        return node
    return _tree_map_dicts(fix, params)


def serve_params_from_qat(params: Dict, qcfg: QuantConfig) -> Dict:
    """DEPRECATED legacy entry point — use ``soniq.to_serve`` (or the
    pytree-level ``repro.api.transforms.pack_linear``)."""
    warnings.warn(
        "smol.serve_params_from_qat is deprecated; use soniq.to_serve / "
        "repro.api.transforms.pack_linear instead",
        DeprecationWarning, stacklevel=2)
    from repro.api import transforms as _transforms
    return _transforms.pack_linear(params, qcfg)


def serve_param_specs(k: int, n: int, qcfg: QuantConfig, *,
                      use_bias: bool = False, dtype=jnp.float32) -> Dict:
    """ShapeDtypeStruct stand-ins for a serve-mode SmolLinear — used by the
    multi-pod dry-run (no allocation). Delegates to the SERVE phase's
    param schema."""
    return Phase.SERVE.param_schema(k, n, qcfg, use_bias=use_bias,
                                    dtype=dtype)


def bit_penalty_of_params(params) -> jnp.ndarray:
    """Sum the Phase-I bit regularizer over every ``s`` leaf in a pytree."""
    total = jnp.asarray(0.0, jnp.float32)
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if path and getattr(path[-1], "key", None) == "s":
            total = total + noise_lib.bit_penalty(leaf)
    return total


def project_noise_weights(params, qcfg: QuantConfig):
    """Post-optimizer projection (paper Alg. 1 line 7) applied to every
    (w, s) pair in a pytree of SmolLinear params. Handles stacked scan /
    expert leading dims via vmap."""
    def fix(node):
        if isinstance(node, dict) and "s" in node and "w" in node:
            node = dict(node)
            w = node["w"]
            k = w.shape[-2]
            g = qcfg.eff_group_size(k)

            def proj2d(w2, s1):
                sw = _weight_scales(w2, qcfg, g)
                sfull = jnp.repeat(sw, g, total_repeat_length=k)[:, None]
                lim = noise_lib.clip_weights(
                    jnp.asarray(w2, jnp.float32) / sfull, s1, g)
                return (lim * sfull).astype(w2.dtype)

            fn = proj2d
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            node["w"] = fn(w, node["s"])
            return node
        return node
    return _tree_map_dicts(fix, params)


def _tree_map_dicts(fn, tree):
    if isinstance(tree, dict):
        new = fn(tree)
        if new is not tree:
            return new
        return {k: _tree_map_dicts(fn, v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_tree_map_dicts(fn, v) for v in tree)
    return tree
