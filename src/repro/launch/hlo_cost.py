"""Trip-count-corrected cost analysis over compiled (scheduled) HLO text.

XLA's aggregate ``compiled.cost_analysis()`` counts every while-loop body
ONCE, so any scanned program (layers, microbatches, attention blocks) is
undercounted by exactly the trip counts. The scheduled HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on while ops, so we:

  1. split the module into computations and parse per-instruction
     (dot FLOPs from output x contraction dims; bytes as operands+outputs of
     top-level instructions, XLA-cost-analysis style; collective bytes),
  2. build the call graph (while bodies x trip count, fusion/reduce
     sub-computations marked internal: their bytes are *not* HBM traffic,
     but any dots inside inherit the caller's multiplier),
  3. accumulate totals x the product of enclosing trip counts.

Everything is per-partition (the HLO is post-SPMD), matching the roofline
convention used throughout EXPERIMENTS.md. Validated against unrolled
references in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "f8e4m3": 1, "s64": 8, "u64": 8, "s32": 4,
               "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1,
               "u4": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r'known_trip_count\D*?(\d+)')
_CALLED = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

SKIP_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "constant",
                  "after-all", "bitcast", "iota", "partition-id",
                  "replica-id", "rng-get-and-update-state", "while",
                  "conditional", "call", "custom-call"}


def _type_bytes(ty: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(ty):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(ty: str) -> List[int]:
    m = _SHAPE.search(ty)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    ty: str
    op: str
    line: str


@dataclasses.dataclass
class Comp:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


def parse_computations(hlo: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry = None
    cur: Optional[Comp] = None
    for line in hlo.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Comp(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), line)
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.ty
    return comps, entry


def _args_str(ins: Instr) -> str:
    """Operand list text: after ``<op>(`` up to the matching close paren
    (the instruction TYPE may itself be a parenthesized tuple)."""
    marker = f" {ins.op}("
    idx = ins.line.find(marker)
    if idx < 0:
        return ""
    after = ins.line[idx + len(marker):]
    return after.split(")", 1)[0]


def _dot_flops(ins: Instr, comp: Comp) -> int:
    """2 x prod(output dims) x prod(contracting dims of lhs)."""
    out_dims = _shape_dims(ins.ty)
    ops = _OPERAND.findall(_args_str(ins))
    if not ops:
        return 0
    lhs_ty = comp.shapes.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_ty)
    cm = _CONTRACT.search(ins.line)
    contract = 1
    if cm and lhs_dims:
        for idx in cm.group(1).split(","):
            if idx:
                contract *= lhs_dims[int(idx)]
    out = 1
    for d in out_dims:
        out *= d
    return 2 * out * contract


def _instr_bytes(ins: Instr, comp: Comp, comps=None) -> int:
    """Operand + output bytes, with sliced-access ops counted by the bytes
    they actually touch (in-place DUS on an aliased KV cache does not read/
    write the whole cache)."""
    if ins.op in SKIP_BYTES_OPS:
        return 0
    ops = _OPERAND.findall(_args_str(ins))
    if ins.op == "dynamic-update-slice" and len(ops) >= 2:
        return 2 * _type_bytes(comp.shapes.get(ops[1], ""))
    if ins.op == "dynamic-slice":
        return 2 * _type_bytes(ins.ty)
    if ins.op == "scatter" and len(ops) >= 3:
        return (2 * _type_bytes(comp.shapes.get(ops[2], ""))
                + _type_bytes(comp.shapes.get(ops[1], "")))
    if ins.op == "gather" and len(ops) >= 2:
        return 2 * _type_bytes(ins.ty) \
            + _type_bytes(comp.shapes.get(ops[1], ""))
    if ins.op == "fusion" and comps is not None:
        sub_ops = set()
        for cn in _CALLED.findall(ins.line):
            sub = comps.get(cn)
            if sub:
                sub_ops |= {i.op for i in sub.instrs}
        out_b = _type_bytes(ins.ty)
        op_bytes = [_type_bytes(comp.shapes.get(o, "")) for o in ops]
        if "dynamic-update-slice" in sub_ops:
            # fused in-place DUS (KV-cache/scan-stacking writes): traffic =
            # the update slice + small inputs, read + written once — NOT the
            # whole aliased buffer.
            small = sum(b for b in op_bytes if b < out_b)
            return 2 * max(small, 1)
        if "dynamic-slice" in sub_ops or "gather" in sub_ops:
            # fused sliced reads of a big buffer: cap each operand at the
            # fusion output size (upper bound on touched bytes).
            return out_b + sum(min(b, out_b) for b in op_bytes)
    total = _type_bytes(ins.ty)
    for op_name in ops:
        total += _type_bytes(comp.shapes.get(op_name, ""))
    return total


@dataclasses.dataclass
class CostTotals:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str) -> CostTotals:
    comps, entry = parse_computations(hlo)
    totals = CostTotals()
    if entry is None:
        totals.warnings.append("no ENTRY computation found")
        return totals

    # multiplier per computation; fused/applied comps excluded from bytes
    mult: Dict[str, float] = {}
    internal: set = set()

    def visit(name: str, m: float, is_internal: bool):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        if is_internal:
            internal.add(name)
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op == "while":
                tm = _TRIP.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                if not tm:
                    totals.warnings.append(
                        f"while without known_trip_count in {name}")
                for called in _CALLED.findall(ins.line):
                    visit(called, m * trip, is_internal)
            elif ins.op == "conditional":
                bm = _BRANCHES.search(ins.line)
                if bm:
                    for b in _OPERAND.findall(bm.group(1)):
                        visit(b, m, is_internal)
            elif ins.op in ("fusion", "reduce", "scatter", "sort", "map",
                            "reduce-window", "select-and-scatter", "call",
                            "reduce-scatter", "all-reduce",
                            "all-reduce-start"):
                for called in _CALLED.findall(ins.line):
                    visit(called, m, True)

    visit(entry, 1.0, False)

    for name, m in mult.items():
        comp = comps[name]
        is_int = name in internal
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                totals.dot_flops += m * _dot_flops(ins, comp)
            if is_int:
                continue
            totals.bytes_accessed += m * _instr_bytes(ins, comp, comps)
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if base in COLLECTIVES:
                b = _type_bytes(ins.ty)
                totals.collective_bytes[base] = \
                    totals.collective_bytes.get(base, 0.0) + m * b
                totals.collective_counts[base] = \
                    totals.collective_counts.get(base, 0.0) + m
    return totals
