"""Unit + property tests for the SMOL grid, fake-quant STE, and packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pack, quant
from repro.core.qtypes import QuantConfig

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------- grid ----
def test_paper_examples():
    # Paper §II-B: 1101 -> 1.375, 10 -> 0.5, 1-bit {0,1} -> {-1,+1}.
    assert quant.smol_values(4)[0b1101] == pytest.approx(1.375)
    assert quant.smol_values(2)[0b10] == pytest.approx(0.5)
    np.testing.assert_allclose(quant.smol_values(1), [-1.0, 1.0])


@pytest.mark.parametrize("p", [1, 2, 4])
def test_grid_structure(p):
    v = quant.smol_values(p)
    assert len(v) == 2 ** p
    np.testing.assert_allclose(v, -v[::-1])          # symmetric
    assert 0.0 not in v                               # zero-free
    if p > 1:
        np.testing.assert_allclose(np.diff(v), 2.0 ** (2 - p))  # step
    assert v[-1] == pytest.approx(2 - 2 ** (1 - p))   # range


@pytest.mark.parametrize("p", [1, 2, 4])
def test_quantize_roundtrip_exact(p):
    v = jnp.asarray(quant.smol_values(p))
    u = quant.quantize_to_int(v, p)
    np.testing.assert_allclose(quant.dequantize_int(u, p), v, atol=1e-6)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_snap_is_nearest(p):
    xs = np.linspace(-2.5, 2.5, 1001).astype(np.float32)
    got = np.asarray(quant.snap_to_grid(jnp.asarray(xs), p))
    grid = quant.smol_values(p)
    want = grid[np.argmin(np.abs(xs[:, None] - grid[None, :]), axis=1)]
    # Ties can fall either way; error must never exceed half-step.
    np.testing.assert_array_less(np.abs(got - np.clip(xs, grid[0], grid[-1])),
                                 2.0 ** (1 - p) + 1e-6)
    mism = np.abs(got - want) > 1e-6
    assert mism.mean() < 0.01   # only tie points may differ


@given(st.integers(0, 2 ** 32 - 1), st.sampled_from([1, 2, 4]))
@settings(max_examples=30, deadline=None)
def test_property_max_roundoff_equals_sigma_init(seed, p):
    """|x - snap(x)| <= 2^(1-p) inside the grid range — the identity that
    makes sigma(s_init) the right noise scale."""
    rng = np.random.default_rng(seed)
    lim = 2 - 2.0 ** (1 - p)
    x = rng.uniform(-lim, lim, size=64).astype(np.float32)
    q = np.asarray(quant.snap_to_grid(jnp.asarray(x), p))
    assert np.max(np.abs(x - q)) <= 2.0 ** (1 - p) + 1e-6


# ----------------------------------------------------------- fake quant ----
def test_fake_quant_mixed_precision_groups():
    k, g = 48, 16
    pbits = jnp.asarray([4, 2, 1], jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).uniform(-1.9, 1.9, (5, k)),
                    jnp.float32)
    y = quant.fake_quant(x, pbits, 1.0, g)
    y = np.asarray(y)
    for gi, p in enumerate([4, 2, 1]):
        seg = y[:, gi * g:(gi + 1) * g]
        grid = quant.smol_values(p)
        d = np.min(np.abs(seg[..., None] - grid), axis=-1)
        np.testing.assert_allclose(d, 0, atol=1e-5)


def test_fake_quant_ste_gradient():
    pbits = jnp.asarray([4.0])
    f = lambda x: jnp.sum(quant.fake_quant(x, pbits, 1.0, 4))
    x = jnp.asarray([[0.3, -0.2, 1.0, 5.0]])    # last is out of range
    gx = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(gx), [[1, 1, 1, 0]])  # clipped STE


def test_fake_quant_with_scale():
    pbits = jnp.asarray([4.0])
    x = jnp.asarray([[10.0, -3.0, 0.5, 7.0]])
    s = quant.abs_max_scale(x)
    y = quant.fake_quant(x, pbits, s, 4)
    sv = float(np.asarray(s).reshape(()))
    assert np.max(np.abs(np.asarray(y - x))) <= sv * 2 ** (1 - 4) + 1e-5


# ---------------------------------------------------------------- pack ----
@pytest.mark.parametrize("p,k", [(1, 64), (2, 64), (4, 64), (4, 16), (2, 8),
                                 (1, 8)])
def test_pack_roundtrip(p, k):
    rng = np.random.default_rng(p * 100 + k)
    u = rng.integers(0, 2 ** p, size=(k, 7)).astype(np.uint8)
    b = pack.pack_codes(jnp.asarray(u), p)
    assert b.shape == (k * p // 8, 7)
    u2 = pack.unpack_codes(b, p, k)
    np.testing.assert_array_equal(np.asarray(u2), u)


@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([(4, 2, 2), (8, 0, 0), (0, 8, 0), (0, 0, 8),
                        (2, 3, 3), (5, 2, 1)]))
@settings(max_examples=20, deadline=None)
def test_property_pack_weight_roundtrip(seed, mix_groups):
    """quantize->pack->unpack->dequant == fake_quant for any segment mix."""
    g4, g2, g1 = mix_groups
    gsz = 16
    k = (g4 + g2 + g1) * gsz
    pbits = np.array([4] * g4 + [2] * g2 + [1] * g1, np.int8)
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1.99, 1.99, size=(k, 5)).astype(np.float32)
    packed = pack.quantize_pack_weight(jnp.asarray(w), pbits, None, gsz)
    w_rt = np.asarray(pack.unpack_dequantize_weight(packed))
    want = np.asarray(quant.fake_quant(
        jnp.asarray(w.T), jnp.asarray(pbits, jnp.float32), 1.0, gsz)).T
    np.testing.assert_allclose(w_rt, want, atol=1e-5)


def test_packed_size_matches_bpp():
    qc = QuantConfig(mode="serve", mix=(0.5, 0.25, 0.25), scale_mode="none")
    k, n = 128, 32
    pbits = np.array([4] * 4 + [2] * 2 + [1] * 2, np.int8)
    w = np.random.default_rng(0).uniform(-1, 1, (k, n)).astype(np.float32)
    packed = pack.quantize_pack_weight(jnp.asarray(w), pbits, None, 16)
    bpp = pack.bits_per_param(packed)
    # (64*4 + 32*2 + 32*1)/128 = 2.75 bits + metadata
    assert abs(bpp - 2.75) < 0.05


# ------------------------------------------- serve-path pack round-trip ----
# pack_linear / pack_conv (the soniq deploy transforms) feed
# pack.dequant_packed_carriers (the serve forward's arithmetic); these
# property tests pin that the full path — rebudget-free: quantize, reorder,
# bit-pack, unpack, dequant — recovers the quantized grid exactly for any
# segment mix, including k < group_size (single whole group) and the
# uniform all-4-bit / all-2-bit budgets.

def _expected_grid(w_sorted, pbits_sorted, scales, g):
    """fake_quant oracle for the packed path: [K, N] on the SMOL grid."""
    if scales is None:
        s_full = np.ones((w_sorted.shape[0],), np.float32)
    else:
        s_full = np.repeat(np.asarray(scales, np.float32), g)
    ws = w_sorted / s_full[:, None]
    q = np.asarray(quant.fake_quant(
        jnp.asarray(ws.T), jnp.asarray(pbits_sorted, jnp.float32), 1.0, g)).T
    return q * s_full[:, None]


@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([(3, 2, 3), (8, 0, 0), (0, 8, 0), (0, 0, 8),
                        (1, 1, 0), (2, 0, 6)]),
       st.sampled_from(["none", "per_group"]),
       st.integers(1, 9))
@settings(max_examples=25, deadline=None)
def test_property_pack_linear_dequant_roundtrip(seed, mix_groups,
                                                scale_mode, n):
    from repro.api import transforms
    from repro.core.qtypes import GROUP_SIZE

    g4, g2, g1 = mix_groups
    k = (g4 + g2 + g1) * GROUP_SIZE
    pbits = np.array([4] * g4 + [2] * g2 + [1] * g1, np.int8)
    rng = np.random.default_rng(seed)
    rng.shuffle(pbits)                      # pack_linear must reorder
    lim = 1.99 if scale_mode == "none" else 3.0
    w = rng.uniform(-lim, lim, size=(k, n)).astype(np.float32)
    qcfg = QuantConfig(mode="serve", scale_mode=scale_mode)

    packed = transforms.pack_linear({"w": w, "pbits": pbits}, qcfg)
    wd = np.asarray(pack.dequant_packed_carriers(
        {name: packed[name] for name in ("w4", "w2", "w1")}, jnp.float32,
        wscale=packed["wscale"], group_size=GROUP_SIZE))
    assert wd.shape == (k, n)
    perm = np.asarray(packed["perm"])
    want = _expected_grid(w[perm], np.asarray(packed["pbits_sorted"]),
                          None if packed["wscale"] is None
                          else np.asarray(packed["wscale"]), GROUP_SIZE)
    np.testing.assert_allclose(wd, want, atol=2e-5)
    # the permutation is a bijection feeding the serve matmul's x-gather
    assert sorted(perm.tolist()) == list(range(k))


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 6, 8, 12]))
@settings(max_examples=15, deadline=None)
def test_property_pack_linear_narrow_k_roundtrip(seed, k):
    """k < group_size: one whole group, held at 4 bits (qcfg.group_pbits),
    effective group size k."""
    from repro.api import transforms

    qcfg = QuantConfig(mode="serve", scale_mode="per_group")
    pbits = qcfg.group_pbits(k)
    assert pbits.tolist() == [4]
    rng = np.random.default_rng(seed)
    w = rng.uniform(-2.5, 2.5, size=(k, 3)).astype(np.float32)
    packed = transforms.pack_linear({"w": w, "pbits": pbits}, qcfg)
    g = qcfg.eff_group_size(k)
    assert g == k
    wd = np.asarray(pack.dequant_packed_carriers(
        {name: packed[name] for name in ("w4", "w2", "w1")}, jnp.float32,
        wscale=packed["wscale"], group_size=g))
    want = _expected_grid(w[np.asarray(packed["perm"])],
                          np.asarray(packed["pbits_sorted"]),
                          np.asarray(packed["wscale"]), g)
    np.testing.assert_allclose(wd, want, atol=2e-5)


@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from([(2, 1, 1), (4, 0, 0), (0, 4, 0), (1, 2, 1)]),
       st.sampled_from([(1, 1), (3, 3), (2, 5)]))
@settings(max_examples=15, deadline=None)
def test_property_pack_conv_dequant_roundtrip(seed, mix_groups, spatial):
    """Conv leaves quantize along Cin; the packed buffers keep
    [rows, kh, kw, Cout] so the CNN serve forward reconstructs the kernel
    by reshaping back to 2-D — exactly what this round-trip does."""
    from repro.api import transforms
    from repro.core.qtypes import GROUP_SIZE

    g4, g2, g1 = mix_groups
    kh, kw = spatial
    cin = (g4 + g2 + g1) * GROUP_SIZE
    cout = 4
    pbits = np.array([4] * g4 + [2] * g2 + [1] * g1, np.int8)
    rng = np.random.default_rng(seed)
    rng.shuffle(pbits)
    w = rng.uniform(-2.0, 2.0, size=(kh, kw, cin, cout)).astype(np.float32)
    qcfg = QuantConfig(mode="serve", scale_mode="per_group")
    packed = transforms.pack_conv({"w": w, "pbits": pbits}, qcfg)
    for name, p in (("w4", 4), ("w2", 2), ("w1", 1)):
        assert packed[name].shape[1:] == (kh, kw, cout)
    bufs = {name: jnp.asarray(np.asarray(packed[name]).reshape(
        packed[name].shape[0], kh * kw * cout)) for name in ("w4", "w2", "w1")}
    wd = np.asarray(pack.dequant_packed_carriers(
        bufs, jnp.float32, wscale=packed["wscale"],
        group_size=GROUP_SIZE))                       # [Cin, kh*kw*Cout]
    w2d = np.moveaxis(w, 2, 0).reshape(cin, -1)
    want = _expected_grid(w2d[np.asarray(packed["perm"])],
                          np.asarray(packed["pbits_sorted"]),
                          np.asarray(packed["wscale"]), GROUP_SIZE)
    np.testing.assert_allclose(wd, want, atol=2e-5)


def test_fixed_point_16_6():
    x = jnp.asarray([0.015625, 0.02, 1000.0, -1000.0])
    y = np.asarray(quant.to_fixed_16_6(x))
    assert y[0] == pytest.approx(1 / 64)
    assert y[1] == pytest.approx(1 / 64)          # rounds to nearest 1/64
    assert y[2] == pytest.approx((2 ** 15 - 1) / 64)   # saturates
    assert y[3] == pytest.approx(-(2 ** 15) / 64)
