"""SONIQ/SySMOL on TPU: ultra-low fine-grained mixed-precision training and
serving in JAX. See DESIGN.md."""
__version__ = "1.0.0"
