"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float = 1.0, warmup: int = 100,
                  total: int = 10000, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def two_phase(step, *, t1: int, warmup: int = 100, total: int = 10000,
              phase2_mult: float = 0.3):
    """SONIQ schedule: Phase I explores (full lr); Phase II fine-tunes the
    frozen-precision network at a reduced lr (paper fine-tuning phase)."""
    lr = warmup_cosine(step, warmup=warmup, total=total)
    return jnp.where(step < t1, lr, lr * phase2_mult)
