"""``pallas_interpret`` / ``pallas_mosaic`` — the Pallas kernel backends.

Both route the per-segment ops through the fused TPU kernels in
``repro.kernels`` (in-register unpack + dequant + MXU GEMM, fused SMOL
quantize+pack, in-kernel-PRNG noise). ``pallas_interpret`` runs them under
the Pallas interpreter (any platform — the CI parity leg);
``pallas_mosaic`` compiles through Mosaic and is only available on a real
TPU. Selection between them is a registry concern ("pallas" alias);
``interpret`` is an implementation detail that no public API exposes.

Geometry the kernels cannot express (a K narrower than the 16-channel
group, carrier rows that do not tile) falls back per-call to the jnp
reference math — which is numerically *identical* for these ops (integer
pack outputs, hash-exact noise), so the fallback is invisible; it is a
shape-coverage escape hatch, not a different answer.

Block shapes come from :mod:`repro.backend.autotune`: an on-disk cache
keyed by (op, shape, dtype, platform), falling back to the static defaults
the kernels shipped with. Lookup is trace-time-safe (no timing inside a
trace); measurement is explicit (``autotune.autotune_op`` /
``benchmarks/runtime_proxy.py --autotune``).
"""
from __future__ import annotations

import importlib

import jax

from repro.core.qtypes import GROUP_SIZE

# The kernels package re-exports the op *functions* under the same names
# as their home modules (kernels.packed_matmul is a function attribute of
# the package), so plain `from repro.kernels import packed_matmul` would
# grab the function; import the modules explicitly.
_pm = importlib.import_module("repro.kernels.packed_matmul")
_qp = importlib.import_module("repro.kernels.quant_pack")
_ni = importlib.import_module("repro.kernels.noise_inject")

from . import autotune
from .base import Backend
from .registry import register
from .xla_ref import XLA_REF as _REF   # per-call geometry fallback


class PallasBackend(Backend):
    """Shared Pallas plumbing; ``interpret`` picks the execution mode."""

    interpret: bool = True

    def _blocks(self, op: str, shape, p, dtype, blocks):
        """Explicit caller blocks win; else the autotune cache; else the
        kernel defaults (autotune returns {} on a miss)."""
        if blocks:
            return blocks
        return autotune.lookup(op, shape=shape, p=p, dtype=dtype,
                               backend=self.name)

    def packed_segment_matmul(self, x, wp, scales=None, *, p: int,
                              act_quant: bool = False,
                              group_size: int = GROUP_SIZE, **blocks):
        if group_size != GROUP_SIZE or x.ndim != 2 \
                or x.shape[1] % GROUP_SIZE:
            return _REF.packed_segment_matmul(
                x, wp, scales, p=p, act_quant=act_quant,
                group_size=group_size)
        m, kp = x.shape
        blocks = self._blocks("packed_segment_matmul", (m, kp, wp.shape[1]),
                              p, x.dtype, blocks)
        return _pm.packed_segment_matmul(x, wp, scales, p=p,
                                         act_quant=act_quant,
                                         interpret=self.interpret, **blocks)

    def quantize_pack(self, w, scales=None, *, p: int,
                      group_size: int = GROUP_SIZE, **blocks):
        if group_size != GROUP_SIZE or w.ndim != 2 \
                or w.shape[0] % GROUP_SIZE:
            return _REF.quantize_pack(w, scales, p=p, group_size=group_size)
        blocks = self._blocks("quantize_pack", tuple(w.shape), p, w.dtype,
                              blocks)
        return _qp.quantize_pack(w, scales, p=p, interpret=self.interpret,
                                 **blocks)

    def _noise_inject_fwd(self, w, s, seed, group_size, blocks):
        if group_size != GROUP_SIZE or w.ndim != 2 \
                or w.shape[0] % GROUP_SIZE:
            return super()._noise_inject_fwd(w, s, seed, group_size, blocks)
        blocks = self._blocks("noise_inject", tuple(w.shape), 0, w.dtype,
                              blocks)
        return _ni.noise_inject(w, s, seed, interpret=self.interpret,
                                **blocks)


class PallasInterpretBackend(PallasBackend):

    name = "pallas_interpret"
    priority = 10                      # correct everywhere, fast nowhere
    interpret = True


class PallasMosaicBackend(PallasBackend):

    name = "pallas_mosaic"
    priority = 100                     # the point of the whole exercise
    interpret = False

    def is_available(self) -> bool:
        return jax.default_backend() == "tpu"

    def why_unavailable(self) -> str:
        return (f"requires a TPU (jax default backend is "
                f"{jax.default_backend()!r})")


PALLAS_INTERPRET = register(PallasInterpretBackend())
PALLAS_MOSAIC = register(PallasMosaicBackend())
