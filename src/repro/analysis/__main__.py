"""``python -m repro.analysis`` — the analyzer CLI (DESIGN.md §15–16).

Modes::

    python -m repro.analysis                  # lint + dataflow vs baseline
    python -m repro.analysis --check          # + jaxpr audits, kernel
                                              #   audit, PagePool model
                                              #   check (the CI leg)
    python -m repro.analysis --json           # machine-readable report
    python -m repro.analysis --sarif out.sarif  # SARIF 2.1.0 for upload
    python -m repro.analysis --list-rules     # rule table with rationales
    python -m repro.analysis --write-baseline # grandfather current findings
    python -m repro.analysis path.py other/   # analyze specific paths

Engines and their skip flags (all run under ``--check``):

* AST lint (SQ001–SQ007) — always on.
* Interprocedural scale dataflow (SQ008) — ``--skip-dataflow``.
* Trace-time jaxpr audits — ``--skip-jaxpr`` (``--no-train`` skips the
  train-step audit; ``--backends`` picks the engine matrix).
* Pallas kernel contract audit — ``--skip-kernel-audit``.
* PagePool interleaving model check — ``--skip-model-check``
  (``--mc-depth`` bounds the BFS; the default explores every
  interleaving of a 2-slot, 3-page pool to depth 6 in ~1s).

Exit status: 0 clean, 1 findings, 2 bad invocation. ``--check`` is what
CI's static-analysis leg runs (``--backends`` defaults to the two-way
CPU matrix).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import dataflow as dataflow_mod
from . import lint as lint_mod

# src/repro/analysis/__main__.py -> repo root
_REPO_ROOT = Path(__file__).resolve().parents[3]
_DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"
_DEFAULT_BACKENDS = "xla_ref,pallas_interpret"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="SONIQ-specific static analyzer: AST lint (SQ rules) "
                    "+ interprocedural scale dataflow + jaxpr audits + "
                    "Pallas kernel contract audit + PagePool model check.")
    p.add_argument("paths", nargs="*", type=Path,
                   help="files/directories to analyze (default: the "
                        "repo's src/ tree)")
    p.add_argument("--check", action="store_true",
                   help="also run the trace-time jaxpr audits, the kernel "
                        "contract audit and the PagePool model check "
                        "(what CI runs); exit 1 on any finding")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit one JSON report on stdout")
    p.add_argument("--sarif", type=Path, metavar="FILE",
                   help="also write a SARIF 2.1.0 log of every finding "
                        "to FILE (for code-scanning upload)")
    p.add_argument("--backends", default=_DEFAULT_BACKENDS,
                   help="comma-separated backend names for the jaxpr "
                        f"audits (default: {_DEFAULT_BACKENDS})")
    p.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE,
                   help="baseline file of grandfathered violations "
                        "(default: the committed repro/analysis/"
                        "baseline.json)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline (report everything)")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline file with the currently "
                        "standing lint violations and exit 0")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table with one-line rationales")
    p.add_argument("--skip-jaxpr", action="store_true",
                   help="with --check: skip the trace-time jaxpr audits "
                        "(used by the lint-speed CI shard)")
    p.add_argument("--skip-dataflow", action="store_true",
                   help="skip the interprocedural scale-dataflow pass "
                        "(SQ008)")
    p.add_argument("--skip-kernel-audit", action="store_true",
                   help="with --check: skip the Pallas kernel contract "
                        "audit")
    p.add_argument("--skip-model-check", action="store_true",
                   help="with --check: skip the PagePool interleaving "
                        "model check")
    p.add_argument("--mc-depth", type=int, default=6,
                   help="model-check BFS depth bound (default: 6 — deep "
                        "enough for every known violation class)")
    p.add_argument("--no-train", action="store_true",
                   help="with --check: skip the train-step jaxpr audit")
    return p


def _print_rules() -> None:
    for r in lint_mod.all_rules():
        print(f"{r.code}  {r.name:<24} {r.rationale}")
    print("SQ008  cross-function-scale-div   interprocedural dataflow: a "
          "raw abs-max scale reaches a divide in another function with "
          "no epsilon clamp on any path")


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        _print_rules()
        return 0

    paths = args.paths or [_REPO_ROOT / "src"]
    for p in paths:
        if not Path(p).exists():
            print(f"error: no such path {p}", file=sys.stderr)
            return 2

    baseline_path = None if args.no_baseline else args.baseline
    result = lint_mod.lint_paths(paths, baseline=baseline_path)

    if args.write_baseline:
        entries = lint_mod.baseline_entries(result.violations
                                            + result.baselined)
        args.baseline.write_text(json.dumps(entries, indent=1,
                                            sort_keys=True) + "\n")
        print(f"wrote {len(entries)} baseline entries to {args.baseline}")
        return 0

    df_result = None
    if not args.skip_dataflow:
        df_result = dataflow_mod.analyze_paths(paths)

    audit_report, audit_issues = None, []
    if args.check and not args.skip_jaxpr:
        from . import jaxpr_checks
        backends = [b.strip() for b in args.backends.split(",") if b.strip()]
        audit_report, audit_issues = jaxpr_checks.run_audits(
            backends, train=not args.no_train)

    kernel_report, kernel_issues = None, []
    if args.check and not args.skip_kernel_audit:
        from . import kernel_audit
        kernel_report, kernel_issues = kernel_audit.run_kernel_audit()

    mc_result = None
    if args.check and not args.skip_model_check:
        from . import model_check
        mc_result = model_check.explore(max_depth=args.mc_depth)

    df_findings = list(df_result.findings) if df_result is not None else []
    mc_bad = 0 if mc_result is None or mc_result.ok else 1
    findings = (len(result.violations) + len(df_findings)
                + len(audit_issues) + len(kernel_issues) + mc_bad)

    if args.sarif:
        from . import sarif as sarif_mod
        log = sarif_mod.build_sarif(
            violations=result.violations + df_findings,
            issues=audit_issues + kernel_issues,
            mc_result=mc_result, rule_table=lint_mod.all_rules())
        args.sarif.write_text(json.dumps(log, indent=1, sort_keys=True)
                              + "\n")

    if args.as_json:
        out = {
            "ok": findings == 0,
            "violations": [v.to_json() for v in result.violations],
            "suppressed": [s.to_json() for s in result.suppressed],
            "baselined": [v.to_json() for v in result.baselined],
            "audit_issues": [i.to_json() for i in audit_issues],
        }
        if audit_report is not None:
            out["audit_report"] = audit_report
        if df_result is not None:
            out["dataflow"] = {
                "findings": [v.to_json() for v in df_findings],
                "suppressed": [s.to_json() for s in df_result.suppressed],
            }
        if kernel_report is not None:
            out["kernel_audit"] = {
                "report": kernel_report,
                "issues": [i.to_json() for i in kernel_issues],
            }
        if mc_result is not None:
            out["model_check"] = mc_result.to_json()
        print(json.dumps(out, indent=1, default=str))
        return 1 if findings else 0

    for v in result.violations:
        print(v.format())
    for v in df_findings:
        print(v.format())
    for i in audit_issues:
        print(i.format())
    for i in kernel_issues:
        print(i.format())
    if mc_result is not None and not mc_result.ok:
        print(mc_result.violation.format())
    tail = (f"{len(result.violations)} violation(s), "
            f"{len(result.suppressed)} suppressed, "
            f"{len(result.baselined)} baselined")
    if df_result is not None:
        tail += (f", {len(df_findings)} dataflow finding(s) "
                 f"({len(df_result.suppressed)} suppressed)")
    if args.check and not args.skip_jaxpr:
        tail += f", {len(audit_issues)} audit issue(s)"
    if kernel_report is not None:
        tail += (f", {len(kernel_issues)} kernel issue(s) over "
                 f"{kernel_report['candidates']} geometries")
    if mc_result is not None:
        tail += (f", model check {'OK' if mc_result.ok else 'VIOLATION'} "
                 f"({mc_result.states_explored} states, depth "
                 f"{mc_result.depth_reached})")
    status = "FAILED" if findings else "OK"
    print(f"soniq-analysis {status}: {tail}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
