"""Serving launcher: packed-weight continuous batching behind a request
queue.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --requests 8 --new-tokens 16

Initializes (or loads) QAT weights, converts to the packed 1/2/4-bit serve
format, and streams a mixed-length synthetic request workload through the
continuous-batching ``DecodeEngine`` (DESIGN.md §10): requests are admitted
into batch slots as they arrive / as slots free up, prompts prefill in
chunks while other slots decode, and completions stream back as they
finish — the deployment path of the paper's pipeline at production shape.
``--lockstep`` runs the fixed-batch baseline instead (same packed weights)
for an on-box throughput comparison.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import soniq
from repro.backend import registry as backend_registry
from repro.configs import get_config
from repro.models import lm
from repro.train import checkpoint as ckpt_lib


def build_requests(args, vocab_size: int, rng) -> list:
    """Mixed-length synthetic workload: prompt lengths in
    [prompt_len/2, prompt_len], generation lengths in [new_tokens/2,
    new_tokens], staggered arrivals."""
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1),
                                args.prompt_len + 1))
        new = int(rng.integers(max(args.new_tokens // 2, 1),
                               args.new_tokens + 1))
        reqs.append(soniq.Request(
            prompt=rng.integers(0, vocab_size, (plen,)).astype(np.int32),
            max_new_tokens=new, temperature=args.temperature, seed=i,
            arrival_step=i // max(args.max_batch, 1)))
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--lockstep", action="store_true",
                    help="run the fixed-batch baseline engine instead")
    ap.add_argument("--backend", default=None,
                    help="kernel backend for the jitted steps (xla_ref, "
                         "pallas_interpret, pallas_mosaic, or the "
                         "'pallas' alias; default: SONIQ_BACKEND env / "
                         "auto-negotiation)")
    ap.add_argument("--kv-bits", type=int, default=None, choices=[4],
                    help="quantize the decode KV cache to this many bits "
                         "(packed 4-bit ring + fused flash-decode, "
                         "DESIGN.md §12); default: fp cache")
    ap.add_argument("--kv-layout", default="ring",
                    choices=["ring", "paged"],
                    help="KV cache layout: contiguous per-slot ring "
                         "buffers, or the paged block-pool with "
                         "copy-on-write prefix sharing (DESIGN.md §13; "
                         "continuous engine only)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout; must divide "
                         "the effective cache length)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = soniq.with_phase(cfg, soniq.Phase.QAT)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt:
        state, step = ckpt_lib.restore(args.ckpt, {"params": params})
        params = state["params"]
        print(f"loaded checkpoint step {step}")

    ecfg = soniq.EngineConfig(max_batch=args.max_batch,
                              cache_len=args.cache_len,
                              temperature=args.temperature,
                              prefill_chunk=args.prefill_chunk,
                              backend=args.backend,
                              kv_bits=args.kv_bits,
                              kv_layout=args.kv_layout,
                              page_size=args.page_size)
    print(f"kernel backend: {backend_registry.resolve(args.backend).name}"
          f", kv cache: "
          f"{'fp' if args.kv_bits is None else f'q{args.kv_bits}'}"
          f", layout: {args.kv_layout}"
          + (f" (page_size {args.page_size})"
             if args.kv_layout == "paged" else ""))
    rng = np.random.default_rng(0)

    if args.lockstep:
        eng = soniq.LockstepEngine(jax.device_get(params), cfg, ecfg)
        print(f"packed model: {soniq.packed_bytes(eng.params):,} bytes")
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.requests, args.prompt_len)
                               ).astype(np.int32)
        t0 = time.time()
        out = eng.generate(prompts, args.new_tokens,
                           jax.random.PRNGKey(1) if args.temperature > 0
                           else None)
        dt = time.time() - t0
        total_new = args.requests * args.new_tokens
        print(f"[lockstep] {total_new} tokens in {dt:.2f}s "
              f"({total_new / dt:.1f} tok/s)")
        for i, row in enumerate(out):
            print(f"req {i}: {row[:args.prompt_len].tolist()} -> "
                  f"{row[args.prompt_len:].tolist()}")
        return

    eng = soniq.DecodeEngine(jax.device_get(params), cfg, ecfg)
    print(f"packed model: {soniq.packed_bytes(eng.params):,} bytes")
    reqs = build_requests(args, cfg.vocab_size, rng)
    t0 = time.time()
    total_new = 0
    for c in eng.serve(reqs):
        total_new += c.new_tokens.size
        print(f"req {c.request_id} [{c.finish_reason} @ step "
              f"{c.finished_step}]: {c.request.prompt.tolist()} -> "
              f"{c.new_tokens.tolist()}")
    dt = time.time() - t0
    print(f"[continuous] {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s, {eng.sched.step_count} engine "
          f"steps, max_batch {args.max_batch})")
    if args.kv_layout == "paged":
        st = eng.paged_kv_stats()
        print(f"[paged-kv] {st['num_pages']} pages x {st['page_size']} "
              f"tokens, peak resident {st['peak_resident_pages']} pages "
              f"({st['peak_resident_payload_bytes']:,} payload bytes of "
              f"{st['reserved_payload_bytes']:,} reserved), prefix hit "
              f"rate {st['prefix_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
