"""Pluggable kernel-backend dispatch for the SONIQ hot paths (DESIGN.md
§11).

    from repro.backend import registry
    registry.resolve()                  # negotiated default
    registry.resolve("pallas")          # best Pallas flavor here
    with registry.use_backend("pallas_interpret"):
        ...                             # scoped (trace-time) override

Backends implement the :class:`~repro.backend.base.Backend` protocol
(packed_matmul / packed_segment_matmul / quantize_pack / noise_inject /
fake_quant) and register at import time:

    xla_ref           pure jnp/XLA — reference semantics, CPU default
    pallas_interpret  Pallas kernels under the interpreter (any platform)
    pallas_mosaic     Pallas kernels compiled via Mosaic (TPU only)

Selection precedence: ``use_backend`` context > ``QuantConfig.backend`` >
``SONIQ_BACKEND`` env > negotiation by priority/availability. Explicit
names never fall back silently.
"""
from . import autotune                              # noqa: F401
from .base import OPS, Backend, BackendUnavailable  # noqa: F401
from .registry import (available, current_backend,  # noqa: F401
                       get, names, register, resolve, use_backend)

# Importing the implementation modules registers the built-in backends.
from . import xla_ref as _xla_ref                   # noqa: F401,E402
from . import pallas as _pallas                     # noqa: F401,E402

__all__ = ["Backend", "BackendUnavailable", "OPS", "autotune", "available",
           "current_backend", "get", "names", "register", "resolve",
           "use_backend"]
