"""Top-level language model: init / forward / loss / decode for every
assigned architecture (dense, MoE, VLM-backbone, SSM, hybrid, enc-dec).

Layers are scanned (stacked params per plan group) with configurable remat;
the vocabulary loss is computed in sequence chunks (rematerialized) so
[B, S, V] logits are never resident — required for the 100k+-vocab archs at
seq 4k. Modality frontends are stubs per the task brief: whisper consumes
precomputed mel frames through one projection; qwen2-vl consumes precomputed
patch/text embeddings plus M-RoPE position streams.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import smol
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig
from . import blocks
from .common import (embed_init, embed_logits, embed_lookup, layer_norm,
                     layer_norm_init, rms_norm, rms_norm_init,
                     sinusoid_positions)
from .shard import shard

LOSS_CHUNK = 1024
Z_LOSS = 1e-4
MOE_AUX = 0.01


def _norm_init(cfg):
    return layer_norm_init(cfg.d_model) if cfg.norm == "ln" \
        else rms_norm_init(cfg.d_model)


def _norm(cfg, p, x):
    return (layer_norm if cfg.norm == "ln" else rms_norm)(p, x, cfg.norm_eps)


def _stacked_init(key, kind: str, count: int, cfg, qcfg) -> Dict:
    keys = jax.random.split(key, count)
    return jax.vmap(lambda k: blocks.block_init(k, kind, cfg, qcfg))(keys)


def init_params(key, cfg) -> Dict:
    qcfg = cfg.quant
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    p: Dict = {"embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
               "final_norm": _norm_init(cfg)}
    if not cfg.tie_embeddings:
        p["lm_head"] = smol.linear_init(ks[1], cfg.d_model, cfg.vocab_size,
                                        qcfg, quantized=False, dtype=dt)
    p["groups"] = [
        _stacked_init(jax.random.fold_in(ks[2], i), kind, count, cfg, qcfg)
        for i, (kind, count) in enumerate(cfg.layer_plan())]
    if cfg.encoder_layers:
        p["enc_groups"] = [_stacked_init(ks[3], "enc", cfg.encoder_layers,
                                         cfg, qcfg)]
        p["enc_norm"] = _norm_init(cfg)
        p["frontend"] = smol.linear_init(ks[4], cfg.frontend_dim,
                                         cfg.d_model, qcfg, quantized=False,
                                         dtype=dt)
    return p


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def _run_group(gparams, kind: str, x, positions, cfg, qcfg, rng,
               cross_x=None):
    """lax.scan over the stacked layers of one plan group."""
    use_rng = qcfg.phase.needs_rng

    def blk(lp, x_, key):
        return blocks.block_apply(lp, kind, x_, positions, cfg, qcfg,
                                  key if use_rng else None, cross_x=cross_x)

    blk = _remat(cfg, blk)

    def body(carry, lp):
        x_, key, aux = carry
        key, sub = jax.random.split(key)
        x_, a = blk(lp, x_, sub)
        return (x_, key, aux + a), None

    key0 = rng if rng is not None else jax.random.PRNGKey(0)
    (x, _, aux), _ = jax.lax.scan(body, (x, key0, jnp.zeros((), jnp.float32)),
                                  gparams)
    return x, aux


def encode(params, cfg, frames, rng=None):
    """Whisper encoder: frames [B, T, frontend_dim] -> [B, T, D]."""
    qcfg = cfg.quant
    dt = jnp.dtype(cfg.dtype)
    x = smol.linear_apply(params["frontend"], frames.astype(dt), qcfg)
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(dt)[None]
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                           (x.shape[0], x.shape[1]))
    for g in params["enc_groups"]:
        x, _ = _run_group(g, "enc", x, pos, cfg, qcfg, rng)
    return _norm(cfg, params["enc_norm"], x)


def forward(params, cfg, *, tokens=None, embeds=None, frames=None,
            positions=None, rng=None):
    """Returns (hidden [B,S,D], moe_aux). Readout is applied by the loss
    (chunked) or by `logits()`."""
    qcfg = cfg.quant
    dt = jnp.dtype(cfg.dtype)
    if embeds is not None:
        x = embeds.astype(dt)
    else:
        x = embed_lookup(params["embed"], tokens, dt)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = shard(x, "batch", "seq", "embed")

    cross_x = None
    if cfg.encoder_layers:
        assert frames is not None, "encoder-decoder arch needs frames"
        cross_x = encode(params, cfg, frames, rng)
        x = x + sinusoid_positions(s, cfg.d_model).astype(dt)[None]

    aux = jnp.zeros((), jnp.float32)
    for gi, (g, (kind, _)) in enumerate(zip(params["groups"],
                                            cfg.layer_plan())):
        r = None if rng is None else jax.random.fold_in(rng, gi)
        x, a = _run_group(g, kind, x, positions, cfg, qcfg, r,
                          cross_x=cross_x)
        aux = aux + a
    x = _norm(cfg, params["final_norm"], x)
    return x, aux


def _readout(params, cfg, h):
    """h [..., D] -> fp32 logits [..., V]."""
    if cfg.tie_embeddings:
        return embed_logits(params["embed"], h)
    return smol.linear_apply(params["lm_head"], h.astype(jnp.float32),
                             cfg.quant)


def logits(params, cfg, h):
    return _readout(params, cfg, h)


def lm_loss(params, cfg, hidden, labels, chunk: int = LOSS_CHUNK):
    """Chunked (and rematerialized) softmax cross-entropy over the vocab.

    labels [B, S] int32; positions with label < 0 are masked out.
    """
    b, s, d = hidden.shape
    c = chunk if s % chunk == 0 else int(np.gcd(s, chunk))
    nc = s // c

    def one(chunk_idx):
        h = jax.lax.dynamic_slice_in_dim(hidden, chunk_idx * c, c, axis=1)
        y = jax.lax.dynamic_slice_in_dim(labels, chunk_idx * c, c, axis=1)
        lg = _readout(params, cfg, h)                      # [B,c,V] fp32
        lg = shard(lg, "batch", "seq", "vocab")
        mask = (y >= 0).astype(jnp.float32)
        yc = jnp.clip(y, 0)
        logz = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, yc[..., None], axis=-1)[..., 0]
        ce = jnp.sum((logz - ll) * mask)
        zl = jnp.sum(jnp.square(logz) * mask)
        return ce + Z_LOSS * zl, jnp.sum(mask)

    one = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, i):
        tot, cnt = carry
        l, n = one(i)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(nc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch: Dict, cfg, rng):
    """Scalar training loss: CE + z-loss + MoE aux + (Phase I) the SONIQ bit
    regularizer lambda * ||log2(1+e^-s)||_1."""
    hidden, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        frames=batch.get("frames"), positions=batch.get("positions"),
        rng=rng)
    loss = lm_loss(params, cfg, hidden, batch["labels"])
    loss = loss + MOE_AUX * aux
    if cfg.quant.phase is Phase.NOISE:
        loss = loss + cfg.quant.lam * smol.bit_penalty_of_params(params)
    return loss, {"ce": loss, "moe_aux": aux}


# --------------------------------------------------------------- decode ----
def _sinusoid_at(pos, d: int):
    """Sinusoidal embedding evaluated at arbitrary positions [...] ->
    [..., d]."""
    dim = jnp.arange(0, d, 2)
    ang = pos[..., None].astype(jnp.float32) / (1e4 ** (dim / d))
    out = jnp.zeros(pos.shape + (d,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out


def _stack_cache(c, count: int, specs: bool):
    if specs:
        return jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((count,) + sd.shape, sd.dtype), c)
    return jax.tree.map(lambda a: jnp.repeat(a[None], count, axis=0), c)


def init_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16, *,
               enc_len: int = 0, specs: bool = False,
               kv_bits: Optional[int] = None, kv_layout: str = "ring",
               page_size: int = 16,
               num_pages: Optional[int] = None) -> Dict:
    """Decode cache for the whole model; specs=True returns
    ShapeDtypeStructs (dry-run, no allocation).

    kv_bits: None keeps the fp ring-KV cache in ``dtype``; 4 selects the
    packed 4-bit family (``serve/kv_quant.py`` — ~4x fewer K/V payload
    bytes, attention runs on the ``qkv_attn_decode`` backend op,
    DESIGN.md §12). Cross-attention K/V (enc-dec) stay fp — they are
    computed once per request, not ring-written per token.

    kv_layout: "ring" keeps per-slot ring buffers; "paged" swaps in the
    page-pool layout (``serve/kv_pool.py``, DESIGN.md §13 — ``num_pages``
    pool pages of ``page_size`` tokens shared across slots through
    per-slot page tables; attention runs on ``qkv_attn_decode_paged``).
    The paged layout needs the engine's host-side ``PagePool`` to drive
    allocation — it is a serve-path layout, not a training one."""
    cache: Dict = {"groups": []}
    for kind, count in cfg.layer_plan():
        c1 = blocks.block_cache_init(kind, cfg, batch, cache_len, dtype,
                                     specs=specs, kv_bits=kv_bits,
                                     kv_layout=kv_layout,
                                     page_size=page_size,
                                     num_pages=num_pages)
        cache["groups"].append(_stack_cache(c1, count, specs))
    if cfg.encoder_layers:
        t = enc_len or 1500
        shapes = {"k": ((batch, t, cfg.num_kv_heads, cfg.hd), dtype),
                  "v": ((batch, t, cfg.num_kv_heads, cfg.hd), dtype),
                  "pos": ((batch, t), jnp.int32)}
        if specs:
            kv = {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt)
                  in shapes.items()}
        else:
            kv = {k: jnp.zeros(sh, dt) for k, (sh, dt) in shapes.items()}
        cache["cross"] = _stack_cache(kv, cfg.num_layers, specs)
    return cache


def build_cross_cache(params, cfg, enc_out) -> Dict:
    """Precompute per-decoder-layer cross K/V from encoder output."""
    qcfg = cfg.quant
    b, t, _ = enc_out.shape

    def proj(layer_p):
        k = smol.linear_apply(layer_p["cross"]["wk"], enc_out, qcfg)
        v = smol.linear_apply(layer_p["cross"]["wv"], enc_out, qcfg)
        return (k.reshape(b, t, cfg.num_kv_heads, cfg.hd),
                v.reshape(b, t, cfg.num_kv_heads, cfg.hd))

    ks, vs = jax.vmap(proj)(params["groups"][0])
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    return {"k": ks, "v": vs,
            "pos": jnp.repeat(pos[None], cfg.num_layers, axis=0)}


def _decode_core(params, cfg, cache: Dict, x, pos, *,
                 inplace_cache: bool = False):
    """Shared decode/prefill body: run x [B, S, D] at positions ``pos``
    ([B] or [B, S]; lanes with pos < 0 are masked — their ring-cache writes
    are dropped) through every layer group, updating the cache. Returns
    (hidden [B, S, D] pre-final-norm, new cache)."""
    qcfg = cfg.quant
    new_groups = []
    for gi, (g, (kind, count)) in enumerate(zip(params["groups"],
                                                cfg.layer_plan())):
        gcache = cache["groups"][gi]
        cross = cache.get("cross")

        if inplace_cache:
            # The stacked cache rides the CARRY (updated in place at
            # [layer_idx, b, slot]); params/cross are xs.
            def body(carry, inp):
                x_, cache_ = carry
                lp, l, lcross = inp
                ck = None
                if lcross is not None:
                    ck = (lcross["k"], lcross["v"], lcross["pos"])
                x2, cache2 = blocks.block_decode(lp, kind, x_, cache_, pos,
                                                 cfg, qcfg, cross_kv=ck,
                                                 layer_idx=l)
                return (x2, cache2), None

            xs = (g, jnp.arange(count),
                  cross if (cross is not None and kind == "dec") else None)
            (x, new_cache_g), _ = jax.lax.scan(body, (x, gcache), xs)
        else:
            def body(x_, inp):
                lp, lc, lcross = inp
                ck = None
                if lcross is not None:
                    ck = (lcross["k"], lcross["v"], lcross["pos"])
                x2, nc = blocks.block_decode(lp, kind, x_, lc, pos, cfg,
                                             qcfg, cross_kv=ck)
                return x2, nc

            xs = (g, gcache,
                  cross if (cross is not None and kind == "dec") else None)
            x, new_cache_g = jax.lax.scan(body, x, xs)
        new_groups.append(new_cache_g)
    new_cache = dict(cache)
    new_cache["groups"] = new_groups
    return x, new_cache


def decode_step(params, cfg, cache: Dict, tokens, pos, *, active=None,
                inplace_cache: bool = False):
    """One decode step. tokens [B] int32, pos [B] int32.
    Returns (logits [B, V] fp32, new cache).

    active: optional [B] bool — per-slot mask for continuous batching
    (DESIGN.md §10). Inactive slots get position -1: their ring-cache
    writes are dropped (out-of-bounds scatter) and their logits are
    garbage the engine ignores; active slots are bitwise unaffected, which
    is what makes the engine token-parity with lockstep decoding.

    inplace_cache: carry the stacked cache through the decode scan and
    scatter the new token in place ([l, b, slot] — one token's bytes)
    instead of the xs->ys per-layer rebuild. On TPU the carried scatter
    aliases (write traffic ~0); the CPU backend legalizes bf16 scatter via
    whole-buffer f32 converts, inverting the win — hence opt-in
    (EXPERIMENTS.md §Perf C3)."""
    dt = jnp.dtype(cfg.dtype)
    if active is not None:
        pos = jnp.where(active, pos, -1)
    x = embed_lookup(params["embed"], tokens[:, None], dt)   # [B,1,D]
    if cfg.encoder_layers:
        x = x + _sinusoid_at(pos, cfg.d_model).astype(dt)[:, None]
    x, new_cache = _decode_core(params, cfg, cache, x, pos,
                                inplace_cache=inplace_cache)
    x = _norm(cfg, params["final_norm"], x)
    lg = _readout(params, cfg, x[:, 0])
    return lg, new_cache


def supports_chunked_prefill(cfg) -> bool:
    """Chunked prefill feeds S > 1 tokens through the decode path at once;
    that needs position-indexed cache writes only. SSM/hybrid blocks carry
    a strictly sequential recurrent state and the audio enc-dec family uses
    per-token sinusoids in decode — those fall back to 1-token prefill."""
    if cfg.encoder_layers or cfg.family == "audio":
        return False
    return not any("mamba" in kind or kind.startswith("hybrid")
                   for kind, _ in cfg.layer_plan())


def prefill_step(params, cfg, cache: Dict, tokens, pos, last_idx, *,
                 inplace_cache: bool = False):
    """Chunked prefill step (continuous batching, DESIGN.md §10): feed up
    to C tokens per slot into the KV cache in ONE forward. tokens [B, C]
    int32, pos [B, C] int32 with -1 marking padding lanes (slots with fewer
    than C tokens to feed — their writes are dropped), last_idx [B] the
    lane index of each slot's last real token. Returns (logits [B, V] fp32
    for each slot's last token, new cache).

    Requires ``supports_chunked_prefill(cfg)`` — the engine gates this."""
    dt = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens, dt)            # [B,C,D]
    x, new_cache = _decode_core(params, cfg, cache, x, pos,
                                inplace_cache=inplace_cache)
    x = _norm(cfg, params["final_norm"], x)
    h = jnp.take_along_axis(
        x, last_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    lg = _readout(params, cfg, h)
    return lg, new_cache


def verify_step(params, cfg, cache: Dict, tokens, pos, *,
                inplace_cache: bool = False):
    """Speculative-verify forward (DESIGN.md §14): identical cache-write
    semantics to ``prefill_step`` (tokens [B, C], pos [B, C] with -1
    padding lanes whose writes are dropped), but returns fp32 logits for
    EVERY lane — [B, C, V] — instead of only each slot's last token. The
    engine's verify call needs per-position logits to score k draft
    tokens in one batched full-mix step; the same call doubles as a
    chunked-prefill feed for prefill-phase slots riding along (they just
    ignore all but their last real lane). C is small (spec_tokens + 1),
    so the [B, C, V] readout the chunked-loss machinery exists to avoid
    is fine here.

    Requires ``supports_chunked_prefill(cfg)`` — the engine gates this."""
    dt = jnp.dtype(cfg.dtype)
    x = embed_lookup(params["embed"], tokens, dt)            # [B,C,D]
    x, new_cache = _decode_core(params, cfg, cache, x, pos,
                                inplace_cache=inplace_cache)
    x = _norm(cfg, params["final_norm"], x)
    lg = _readout(params, cfg, x)                            # [B,C,V]
    return lg, new_cache


def reset_cache_slots(cache: Dict, slots):
    """Wipe the cache rows of the given batch slots (request admission /
    eviction in the continuous-batching engine). Ring cache leaves are
    stacked [L, B, ...]: ``pos`` leaves become -1 (ring entries read as
    empty), K/V/SSM state leaves become 0 — including the quantized
    family's codes and scales (``kv_quant.reset_slots`` semantics). Rows
    not listed are untouched.

    Paged cache dicts (``serve/kv_pool.py`` — detected by their
    ``page_table`` leaf) are slot-indexed only through the table: the
    slot's table row becomes -1 (every logical page unmapped), while the
    pool payload/pos leaves are page-indexed shared state owned by the
    host allocator and must not be wiped per-slot (another slot may map
    those pages). Page recycling itself is the allocator's job
    (``PagePool.release`` + ``apply_step_ops``)."""
    idx = jnp.asarray(slots, jnp.int32)

    def walk(tree, name=""):
        if isinstance(tree, dict):
            if "page_table" in tree:
                out = dict(tree)
                tbl = tree["page_table"]
                out["page_table"] = (tbl.at[:, idx].set(-1)  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
                                     if tbl.ndim == 3 else
                                     tbl.at[idx].set(-1))  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
                return out
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, name) for v in tree]
        if isinstance(tree, tuple):
            return tuple(walk(v, name) for v in tree)
        if tree is None:
            return None
        if name == "pos":
            return tree.at[:, idx].set(-1)  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
        return tree.at[:, idx].set(  # soniq-lint: disable=SQ001(reset slots are scheduler-validated)
            jnp.zeros((), tree.dtype))

    return walk(cache)
