"""Property tests for the fused activation-quantization paths (DESIGN.md
§11 "Fused activation quantization").

The contract under test: fusing the activation fake-quant into the Pallas
segment-GEMM prologue (serve) or into a Pallas forward kernel (QAT
fake_quant) removes HBM traffic, *never* arithmetic — so fused outputs
must equal the two-pass ``act_scale`` + ``fake_quant`` + matmul reference
bit-exactly on the same backend, across every segment layout (all-4 /
all-2 / all-1 / mixed, K narrower than a group) and every
``act_scale_mode`` (per_token / per_tensor / none), including degenerate
all-zero and outlier rows.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.api import transforms
from repro.backend import resolve
from repro.core import quant
from repro.core.qtypes import QuantConfig


def _packed_leaf(pbits, k, n, seed):
    qcfg = QuantConfig(mode="qat")
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.7
    return transforms.pack_linear(
        {"w": w, "pbits": np.asarray(pbits, np.int8)}, qcfg)


@st.composite
def _serve_cases(draw):
    if draw(st.booleans(), label="narrow"):
        k = draw(st.sampled_from([4, 8, 12]))    # K < group: one 4-bit group
        pbits = [4]
    else:
        ngroups = draw(st.integers(1, 8))
        pbits = draw(st.lists(st.sampled_from([4, 2, 1]),
                              min_size=ngroups, max_size=ngroups))
        k = 16 * ngroups
    m = draw(st.integers(1, 6))
    seed = draw(st.integers(0, 2 ** 16))
    mode = draw(st.sampled_from(["per_token", "per_tensor", "none"]))
    zero_row = draw(st.booleans())
    outlier_row = draw(st.booleans())
    return pbits, k, m, seed, mode, zero_row, outlier_row


@settings(max_examples=25, deadline=None)
@given(_serve_cases())
def test_fused_prologue_equals_two_pass_bit_exact(case):
    pbits, k, m, seed, mode, zero_row, outlier_row = case
    sp = _packed_leaf(pbits, k, 32, seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k)) * 1.5
    if zero_row:
        x = x.at[0].set(0.0)                     # padding / fresh slot row
    if outlier_row:
        x = x.at[m - 1].multiply(100.0)
    b = resolve("pallas_interpret")
    q_fused = QuantConfig(mode="serve", act_scale_mode=mode)
    q_two = dataclasses.replace(q_fused, fuse_act_quant=False)
    y_fused = np.asarray(b.packed_matmul(sp, x, q_fused))
    y_two = np.asarray(b.packed_matmul(sp, x, q_two))
    np.testing.assert_array_equal(y_fused, y_two)
    assert np.isfinite(y_fused).all()
    # and the xla_ref two-pass oracle agrees to fp32 tolerance
    y_ref = np.asarray(resolve("xla_ref").packed_matmul(sp, x, q_fused))
    np.testing.assert_allclose(y_fused, y_ref, rtol=1e-5, atol=1e-5)


@st.composite
def _selfscale_cases(draw):
    ngroups = draw(st.integers(1, 8))
    k = 16 * ngroups
    p = draw(st.sampled_from([4, 2, 1]))     # uniform precision: 1 segment
    m = draw(st.integers(1, 6))
    n = draw(st.sampled_from([8, 32]))
    seed = draw(st.integers(0, 2 ** 16))
    zero_row = draw(st.booleans())
    outlier_row = draw(st.booleans())
    return p, k, m, n, seed, zero_row, outlier_row


@settings(max_examples=25, deadline=None)
@given(_selfscale_cases())
def test_in_kernel_selfscale_equals_driver_scale_bit_exact(case):
    """ROADMAP satellite: for a uniform-precision (single-segment) layer
    the per-token abs-max moves into the fused kernel's prologue
    (``in_kernel_scale=True``). It must equal the driver-scale fused form
    — and therefore the two-pass reference — bit-exactly, zero rows
    (ACT_SCALE_EPS clamp) and outliers included."""
    p, k, m, n, seed, zero_row, outlier_row = case
    sp = _packed_leaf([p] * (k // 16), k, n, seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k)) * 1.5
    if zero_row:
        x = x.at[0].set(0.0)
    if outlier_row:
        x = x.at[m - 1].multiply(100.0)
    from repro.backend.base import act_scale
    b = resolve("pallas_interpret")
    name = {4: "w4", 2: "w2", 1: "w1"}[p]
    wp = sp[name]
    scales = sp.get("wscale")
    sx = jnp.broadcast_to(act_scale(x, "per_token").reshape(-1, 1), (m, 1))
    y_self = np.asarray(b.fused_act_segment_matmul(
        x, wp, scales, None, p=p, in_kernel_scale=True))
    y_driver = np.asarray(b.fused_act_segment_matmul(
        x, wp, scales, sx, p=p))
    y_two = np.asarray(resolve("xla_ref").fused_act_segment_matmul(
        x, wp, scales, None, p=p, in_kernel_scale=True))
    np.testing.assert_array_equal(y_self, y_driver)
    np.testing.assert_array_equal(y_self, y_two)
    assert np.isfinite(y_self).all()


@st.composite
def _fake_quant_cases(draw):
    ngroups = draw(st.integers(1, 8))
    pbits = draw(st.lists(st.sampled_from([4, 2, 1]),
                          min_size=ngroups, max_size=ngroups))
    m = draw(st.integers(1, 8))
    seed = draw(st.integers(0, 2 ** 16))
    scale_kind = draw(st.sampled_from(["per_row", "per_group", "scalar"]))
    return pbits, m, seed, scale_kind


@settings(max_examples=25, deadline=None)
@given(_fake_quant_cases())
def test_pallas_fake_quant_matches_jnp_bit_exact(case):
    pbits, m, seed, scale_kind = case
    k = 16 * len(pbits)
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * 1.3
    pb = jnp.asarray(np.asarray(pbits, np.float32))
    if scale_kind == "per_row":
        scale = quant.abs_max_scale(x, axis=-1)
    elif scale_kind == "per_group":
        scale = quant.per_group_weight_scale(x.T, 16)
    else:
        scale = 1.0
    got = resolve("pallas_interpret").fake_quant(x, pb, scale, 16)
    want = quant.fake_quant(x, pb, scale, 16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
