"""DeepSeekMoE-16B [arXiv:2401.06066; hf] — fine-grained MoE: 28L
d_model=2048 16H (kv=16) per-expert d_ff=1408, 64 routed top-6 + 2 shared,
first layer dense (d_ff=10944), vocab=102400."""
from .base import ArchConfig
from .registry import register


@register("deepseek-moe-16b")
def deepseek_moe() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400, head_dim=128,
        rope_theta=1e4, mlp_act="swiglu",
        num_experts=64, top_k=6, num_shared_experts=2,
        first_dense_layers=1, dense_d_ff=10944,
        tie_embeddings=False,
        source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
    )
