"""SARIF 2.1.0 emitter for the analyzer (DESIGN.md §16).

One run, one driver (``soniq-analysis``), four result families:

* lint/dataflow ``Violation``s — physical locations (repo-relative path,
  1-based line/column) and their SQ rule ids;
* jaxpr-audit / kernel-audit ``Issue``s — rule id is the check name
  (``segment_dtype``, ``kernel_geometry``, ...); the ``where`` context
  string rides in the message and the location anchors to the audited
  subsystem's source file (GitHub code scanning requires a physical
  location even for whole-subsystem findings);
* a model-checker violation — anchored to ``serve/kv_pool.py`` with the
  minimal trace in the message.

The JSON report (``--json``) stays the machine interface of record; the
SARIF file exists so CI can upload findings to code scanning. Keys are
sorted and the layout is deterministic for a given set of findings.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
           "master/Schemata/sarif-schema-2.1.0.json")

# Where a subsystem-level Issue (no single source line) anchors. Paths
# are repo-relative; GitHub drops results whose uri does not resolve, so
# these name the files whose contracts the checks verify.
_CHECK_ANCHORS = {
    "recompile": "src/repro/serve/engine.py",
    "segment_dtype": "src/repro/backend/base.py",
    "callback": "src/repro/serve/engine.py",
    "donation": "src/repro/serve/engine.py",
    "traffic": "src/repro/serve/engine.py",
    "kernel_geometry": "src/repro/backend/pallas.py",
    "kernel_dtype": "src/repro/backend/pallas.py",
    "kernel_mapping": "src/repro/backend/pallas.py",
    "model_check": "src/repro/serve/kv_pool.py",
}
_FALLBACK_ANCHOR = "src/repro/analysis/__main__.py"


def _rule(rule_id: str, description: str) -> Dict:
    return {"id": rule_id,
            "shortDescription": {"text": description or rule_id}}


def _violation_result(v) -> Dict:
    return {
        "ruleId": v.code,
        "level": "error",
        "message": {"text": v.message},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": str(v.path).replace("\\", "/"),
                                     "uriBaseId": "SRCROOT"},
                "region": {"startLine": max(1, int(v.line)),
                           "startColumn": max(1, int(v.col) + 1)},
            },
        }],
    }


def _issue_result(issue) -> Dict:
    anchor = _CHECK_ANCHORS.get(issue.check, _FALLBACK_ANCHOR)
    return {
        "ruleId": issue.check,
        "level": "error",
        "message": {"text": f"{issue.where}: {issue.message}"},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": anchor, "uriBaseId": "SRCROOT"},
                "region": {"startLine": 1},
            },
        }],
    }


def build_sarif(violations: Iterable = (), issues: Iterable = (),
                mc_result=None, rule_table: Optional[Iterable] = None
                ) -> Dict:
    """Assemble the SARIF log dict. ``violations`` are lint/dataflow
    ``Violation``s, ``issues`` are jaxpr/kernel-audit ``Issue``s,
    ``mc_result`` an ``MCResult`` (its violation becomes one result),
    ``rule_table`` the lint Rule objects for rule metadata."""
    results: List[Dict] = [_violation_result(v) for v in violations]
    rule_ids: Dict[str, str] = {}
    for r in (rule_table or ()):
        rule_ids[r.code] = r.rationale
    for issue in issues:
        results.append(_issue_result(issue))
        rule_ids.setdefault(issue.check, f"analyzer check '{issue.check}'")
    if mc_result is not None and mc_result.violation is not None:
        results.append({
            "ruleId": "model_check",
            "level": "error",
            "message": {"text": mc_result.violation.format()},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": _CHECK_ANCHORS["model_check"],
                        "uriBaseId": "SRCROOT"},
                    "region": {"startLine": 1},
                },
            }],
        })
        rule_ids.setdefault("model_check",
                            "PagePool interleaving model checker")
    for res in results:
        rule_ids.setdefault(res["ruleId"], res["ruleId"])
    return {
        "$schema": _SCHEMA,
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "soniq-analysis",
                "rules": [_rule(k, rule_ids[k])
                          for k in sorted(rule_ids)],
            }},
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": results,
        }],
    }
