"""Repo-specific AST lint rules for the SONIQ hazard classes (DESIGN.md §15).

Each rule codifies a bug class that was found and fixed by hand in an
earlier PR (see CHANGES.md) and must never be re-writable:

    SQ001  cache-write scatter (``.at[dynamic].set/add``) without
           ``mode="drop"`` — the PR 5 masked-lane ring clobber: a pos<0
           lane wrapped to a live slot and silently evicted it.
    SQ002  dividing by a raw abs-max that is never clamped — the PR 4
           zero-row activation-scale divide (all-zero padding rows made
           NaN logits for the whole batch once they mixed in the matmul).
    SQ003  importing ``repro.kernels`` outside ``repro/backend`` — a
           registry bypass: the call would skip the shared driver that
           owns activation scaling (the PR 3 whole-batch act-scale leak
           lived exactly in such a wrapper) and break backend parity.
    SQ004  hot-path ``jax.jit`` in ``repro/serve`` without buffer
           donation — every undonated step doubles the KV-cache working
           set (two live copies of cache-sized buffers per step).
    SQ005  host synchronization inside an engine step loop — each
           ``.item()`` / ``np.asarray`` / ``device_get`` is a device
           round-trip on the decode critical path; the engine budgets
           exactly one per step (the sampled-token transfer).
    SQ006  wall-clock / global-RNG nondeterminism in trace scope — a
           ``time.time()`` or unseeded ``np.random``/stdlib-``random``
           draw baked into a jitted function changes numerics between
           traces, which no parity pin can survive.
    SQ007  unused suppression — a ``disable=SQxxx(...)`` whose rule no
           longer fires on that line: the hazard was fixed or moved, and
           a stale reason would silently swallow the rule the next time
           it fires there for a *new* bug.

SQ002 covers the divide spellings: ``x / s``, ``x * (1.0 / s)``,
``jnp.reciprocal(s)``, ``lax.div(x, s)`` / ``jnp.divide`` /
``jnp.true_divide``. The *interprocedural* version (producer and divide
in different functions) is SQ008, owned by ``repro.analysis.dataflow``.

Suppressions are inline and must carry a reason::

    x = cache.at[idx].set(v)  # soniq-lint: disable=SQ001(host-validated ids)

A suppression comment may sit on the flagged line or alone on the line
directly above it. Multiple codes: ``disable=SQ001(why),SQ005(why)``.
A ``disable=`` without a parenthesized reason does not suppress anything —
it is reported as a malformed suppression (SQ000).

Grandfathered violations live in the committed baseline file
(``src/repro/analysis/baseline.json``): entries match on (relative path,
code, stripped source line), so unrelated edits do not invalidate them
while any change to the flagged line itself forces a re-decision.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import tokenize
from io import StringIO
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

# --------------------------------------------------------------------------
# Data model
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str                    # repo-relative posix path ("" for snippets)
    line: int
    col: int
    code: str                    # "SQ001" ... "SQ006" / "SQ000"
    message: str
    source_line: str = ""        # stripped text of the flagged line

    def format(self) -> str:
        return f"{self.path or '<source>'}:{self.line}:{self.col}: " \
               f"{self.code} {self.message}"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    path: str
    line: int                    # line the suppression applies to
    code: str
    reason: str
    source_line: str = ""

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LintResult:
    """Violations that stand, suppressions that fired (with their recorded
    reasons), and violations matched away by the baseline file."""
    violations: List[Violation] = dataclasses.field(default_factory=list)
    suppressed: List[Suppression] = dataclasses.field(default_factory=list)
    baselined: List[Violation] = dataclasses.field(default_factory=list)

    def extend(self, other: "LintResult") -> None:
        self.violations.extend(other.violations)
        self.suppressed.extend(other.suppressed)
        self.baselined.extend(other.baselined)

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    rationale: str               # one line: the originating bug class
    make_visitor: Callable[["_FileContext"], ast.NodeVisitor]


_RULES: Dict[str, Rule] = {}


def rule(code: str, name: str, rationale: str):
    """Register a rule: decorates an ``ast.NodeVisitor`` subclass whose
    ``__init__`` takes the :class:`_FileContext`. This is also the
    extension point for new rules (DESIGN.md §15)."""
    def deco(cls):
        assert code not in _RULES, f"duplicate rule {code}"
        _RULES[code] = Rule(code, name, rationale, cls)
        return cls
    return deco


def all_rules() -> Tuple[Rule, ...]:
    return tuple(_RULES[c] for c in sorted(_RULES))


class _FileContext:
    """Per-file state shared with the rule visitors."""

    def __init__(self, path: str, source: str):
        self.path = path             # repo-relative posix ("" for snippets)
        self.source = source
        self.lines = source.splitlines()
        self.violations: List[Violation] = []

    # Path predicates the rules scope themselves with. A snippet with no
    # path ("") is treated as in-scope for every rule so rule fixtures and
    # ad-hoc `--stdin` linting exercise all of them.
    def in_pkg(self, *parts: str) -> bool:
        if not self.path:
            return True
        p = self.path
        return any(f"repro/{part}/" in p or p.endswith(f"repro/{part}.py")
                   for part in parts)

    def add(self, node: ast.AST, code: str, message: str) -> None:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        src = self.lines[line - 1].strip() if line <= len(self.lines) else ""
        self.violations.append(
            Violation(self.path, line, col, code, message, src))


# --------------------------------------------------------------------------
# AST helpers
# --------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """'jnp.max' for Attribute/Name chains, '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _call_name(node: ast.Call) -> str:
    return _dotted(node.func)


def _is_static_index(node: ast.AST) -> bool:
    """True for index elements that cannot scatter out of bounds at run
    time: literals, constant slices, Ellipsis, None — anything whose value
    is fixed at trace time."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.operand,
                                                    ast.Constant):
        return True
    if isinstance(node, ast.Slice):
        return all(e is None or _is_static_index(e)
                   for e in (node.lower, node.upper, node.step))
    return False


def _index_elements(sub: ast.Subscript) -> List[ast.AST]:
    idx = sub.slice
    if isinstance(idx, ast.Tuple):
        return list(idx.elts)
    return [idx]


# --------------------------------------------------------------------------
# SQ001 — cache-write scatter without mode="drop"
# --------------------------------------------------------------------------

_AT_UPDATE_METHODS = {"set", "add", "mul", "min", "max", "apply"}


@rule("SQ001", "unmasked-scatter-write",
      "PR 5 masked-lane ring clobber: a pos<0 lane wrapped to slot "
      "cache_len-1 and silently evicted a live request's KV entry")
class _ScatterRule(ast.NodeVisitor):
    """Flag ``<buf>.at[<dynamic index>].set/add/...(...)`` calls with no
    ``mode=`` keyword. A dynamically indexed scatter in jax clamps
    out-of-bounds writes *to the last element* by default — the exact
    mechanism of the ring clobber. In-bounds-by-construction sites
    suppress inline with the reason; cache writes take ``mode="drop"``."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx

    def visit_Call(self, node: ast.Call):
        f = node.func
        if (isinstance(f, ast.Attribute) and f.attr in _AT_UPDATE_METHODS
                and isinstance(f.value, ast.Subscript)
                and isinstance(f.value.value, ast.Attribute)
                and f.value.value.attr == "at"):
            has_mode = any(kw.arg == "mode" for kw in node.keywords)
            dynamic = [e for e in _index_elements(f.value)
                       if not _is_static_index(e)]
            if dynamic and not has_mode:
                self.ctx.add(
                    node, "SQ001",
                    f".at[...].{f.attr} with a dynamic index and no "
                    f"mode= — an out-of-bounds lane clamps onto a live "
                    f"entry; pass mode=\"drop\" (cache writes) or "
                    f"suppress with the in-bounds argument")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ002 — scale divide not clamped
# --------------------------------------------------------------------------

_CLAMP_MARKERS = re.compile(
    r"\b(maximum|clip|clamp|eps|EPS|where|abs_max_scale|"
    r"per_group_weight_scale)\b")
_ABS_CALLS = {"abs", "jnp.abs", "np.abs", "jax.numpy.abs"}
_MAX_CALLS = {"max", "amax", "jnp.max", "np.max", "jnp.amax", "np.amax",
              "jax.numpy.max", "jax.numpy.amax"}


def _is_raw_absmax(node: ast.AST) -> bool:
    """True when ``node`` computes an abs-max with no clamp anywhere in the
    expression: ``jnp.max(jnp.abs(x))``, ``jnp.abs(x).max()`` and friends.
    The textual clamp check is deliberately permissive — any ``maximum`` /
    ``clip`` / ``eps`` in the same expression counts as clamped; the rule
    exists to catch the bare pattern, not to prove numerical safety."""
    text = ast.unparse(node)
    if _CLAMP_MARKERS.search(text):
        return False
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        name = _call_name(sub)
        if name in _MAX_CALLS or (isinstance(sub.func, ast.Attribute)
                                  and sub.func.attr in ("max", "amax")):
            if re.search(r"\babs\s*\(", ast.unparse(sub)):
                return True
    return False


# Function-call divide/reciprocal spellings SQ002 must also catch: the
# hazard is identical whether the divide is an operator or a call.
_DIV_FN_CALLS = {"lax.div", "jax.lax.div",
                 "jnp.divide", "np.divide", "jax.numpy.divide",
                 "jnp.true_divide", "np.true_divide",
                 "jax.numpy.true_divide"}
_RECIP_CALLS = {"jnp.reciprocal", "np.reciprocal", "jax.numpy.reciprocal",
                "lax.reciprocal", "jax.lax.reciprocal"}


@rule("SQ002", "unclamped-scale-divide",
      "PR 4 zero-row activation-scale divide: an all-zero padding row's "
      "abs-max of 0 became a divisor — NaN/Inf logits for every row once "
      "mixed in the matmul; clamp via core.quant.ACT_SCALE_EPS")
class _ScaleDivideRule(ast.NodeVisitor):
    """Intraprocedural: record names assigned a raw (unclamped) abs-max
    expression, flag divisions by them — or by such an expression inline.
    Catches the operator form ``x / s`` (so ``x * (1.0 / s)`` trips via
    the inner divide), the call forms ``lax.div(x, s)`` /
    ``jnp.divide(x, s)`` / ``jnp.true_divide(x, s)``, and reciprocals
    ``jnp.reciprocal(s)``. Also flags explicitly disabling the clamp
    (``eps=0``)."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx
        self._raw: Dict[str, ast.AST] = {}

    def _is_raw_scale(self, node: ast.AST) -> bool:
        return (isinstance(node, ast.Name) and node.id in self._raw) \
            or _is_raw_absmax(node)

    def _enter_scope(self, node):
        saved = self._raw
        self._raw = {}
        self.generic_visit(node)
        self._raw = saved

    visit_FunctionDef = _enter_scope
    visit_AsyncFunctionDef = _enter_scope
    visit_Lambda = _enter_scope

    def visit_Assign(self, node: ast.Assign):
        if _is_raw_absmax(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self._raw[t.id] = node.value
        else:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self._raw.pop(t.id, None)
        self.generic_visit(node)

    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, ast.Div) and self._is_raw_scale(node.right):
            self.ctx.add(
                node, "SQ002",
                "dividing by a raw abs-max with no epsilon clamp — "
                "an all-zero row yields a 0 divisor; floor it with "
                "jnp.maximum(m, ACT_SCALE_EPS) (core.quant)")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        name = _call_name(node)
        if name in _DIV_FN_CALLS and len(node.args) >= 2 and \
                self._is_raw_scale(node.args[1]):
            self.ctx.add(
                node, "SQ002",
                f"{name}(x, s) divides by a raw abs-max with no epsilon "
                f"clamp — an all-zero row yields a 0 divisor; floor it "
                f"with jnp.maximum(m, ACT_SCALE_EPS) (core.quant)")
        if name in _RECIP_CALLS and node.args and \
                self._is_raw_scale(node.args[0]):
            self.ctx.add(
                node, "SQ002",
                f"{name}(s) of a raw abs-max with no epsilon clamp — "
                f"an all-zero row makes the reciprocal Inf and the "
                f"multiply NaN; floor s with jnp.maximum(m, "
                f"ACT_SCALE_EPS) (core.quant) first")
        if name.endswith("abs_max_scale") or \
                name.endswith("per_group_weight_scale"):
            for kw in node.keywords:
                if kw.arg == "eps" and isinstance(kw.value, ast.Constant) \
                        and not kw.value.value:
                    self.ctx.add(node, "SQ002",
                                 f"{name}(eps=0) disables the zero-row "
                                 f"clamp the serve path depends on")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ003 — repro.kernels import outside the backend layer
# --------------------------------------------------------------------------

@rule("SQ003", "kernel-registry-bypass",
      "PR 3 whole-batch act-scale leak lived in a direct kernel wrapper: "
      "calls that bypass the Backend registry skip the shared driver that "
      "owns activation scaling and segment order, breaking backend parity")
class _KernelImportRule(ast.NodeVisitor):
    """``repro.kernels`` may only be imported by ``repro/backend`` (the
    implementations) and ``repro/kernels`` itself. Everything else goes
    through ``repro.backend.registry.resolve(...)`` so dispatch, autotune
    and the parity matrix see every call."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx
        self.exempt = ctx.in_pkg("backend", "kernels")

    def _flag(self, node, what: str):
        if not self.exempt:
            self.ctx.add(
                node, "SQ003",
                f"{what} outside repro/backend bypasses the kernel "
                f"registry — dispatch via "
                f"repro.backend.registry.resolve(...) instead")

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            if a.name == "repro.kernels" or \
                    a.name.startswith("repro.kernels."):
                self._flag(node, f"import {a.name}")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        if mod == "repro.kernels" or mod.startswith("repro.kernels."):
            self._flag(node, f"from {mod} import ...")
        elif mod == "repro" and any(a.name == "kernels"
                                    for a in node.names):
            self._flag(node, "from repro import kernels")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        if _call_name(node) in ("importlib.import_module",
                                "import_module") and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and \
                    isinstance(arg.value, str) and \
                    arg.value.startswith("repro.kernels"):
                self._flag(node, f"import_module({arg.value!r})")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ004 — serve-path jax.jit without buffer donation
# --------------------------------------------------------------------------

@rule("SQ004", "undonated-hot-jit",
      "an undonated serve-step jit keeps TWO live copies of every "
      "cache-sized buffer (old + new KV ring) per step — at production "
      "cache sizes that halves the batch that fits")
class _JitDonationRule(ast.NodeVisitor):
    """In ``repro/serve``, every ``jax.jit(...)`` must pass
    ``donate_argnums``/``donate_argnames`` (the engine step functions all
    thread cache-sized state through). Jits elsewhere (train loops, launch
    tooling, kernels' shape-specializing wrappers) are out of scope."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx
        self.in_scope = ctx.in_pkg("serve")

    def visit_Call(self, node: ast.Call):
        if self.in_scope and _call_name(node) in ("jax.jit", "jit"):
            if not any(kw.arg in ("donate_argnums", "donate_argnames")
                       for kw in node.keywords):
                self.ctx.add(
                    node, "SQ004",
                    "serve-path jax.jit without donate_argnums/"
                    "donate_argnames — cache-sized buffers double-buffer "
                    "every step; donate the cache operand (see "
                    "DecodeEngine._jit) or suppress with why no operand "
                    "is cache-sized")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ005 — host sync inside engine step loops
# --------------------------------------------------------------------------

_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get", "device_get", "np.copy"}
_SYNC_METHODS = {"item", "block_until_ready", "tolist"}
_STEP_NAME = re.compile(r"(^|_)(step|run)($|_)|spec_step|after_advance")


@rule("SQ005", "host-sync-in-step-loop",
      "each host sync in the decode loop is a blocking device round-trip "
      "on the critical path; the engine budgets exactly one per step "
      "(the [B]-int sampled-token transfer, DESIGN.md §10)")
class _HostSyncRule(ast.NodeVisitor):
    """Inside ``repro/serve`` functions whose name marks them as engine
    step loops (``step``/``run``/``_spec_step``/...), flag device→host
    materializations: ``np.asarray``/``np.array``, ``.item()``,
    ``.tolist()``, ``jax.device_get``, ``.block_until_ready()``,
    ``float(<name or subscript>)``. The intentional per-step transfer
    suppresses inline with its budget note."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx
        self.in_scope = ctx.in_pkg("serve")
        self._depth = 0              # inside a step-loop function?

    def _visit_fn(self, node):
        marked = bool(_STEP_NAME.search(node.name))
        if marked:
            self._depth += 1
        self.generic_visit(node)
        if marked:
            self._depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call):
        if self.in_scope and self._depth:
            name = _call_name(node)
            hit = None
            if name in _SYNC_CALLS:
                hit = name
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _SYNC_METHODS and not node.args:
                hit = f".{node.func.attr}()"
            elif name == "float" and node.args and isinstance(
                    node.args[0], (ast.Name, ast.Subscript)):
                hit = "float()"
            if hit:
                self.ctx.add(
                    node, "SQ005",
                    f"{hit} inside an engine step loop is a blocking "
                    f"device->host sync — keep it on device, or suppress "
                    f"with the per-step transfer budget it spends")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ006 — wall-clock / global-RNG nondeterminism in trace scope
# --------------------------------------------------------------------------

_CLOCK_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
                "time.time_ns", "time.perf_counter_ns",
                "datetime.now", "datetime.utcnow",
                "datetime.datetime.now", "datetime.datetime.utcnow"}
# Global-state numpy RNG entry points (legacy API). Generator methods on a
# seeded np.random.default_rng(...) are deterministic and allowed.
_GLOBAL_NP_RANDOM = re.compile(
    r"^(np|numpy)\.random\.(?!default_rng$|SeedSequence$|Generator$)")
_STDLIB_RANDOM = re.compile(
    r"^random\.(random|randint|randrange|choice|choices|shuffle|sample|"
    r"uniform|gauss|normalvariate|getrandbits|seed)$")


def _is_jit_decorated(node) -> bool:
    for d in node.decorator_list:
        text = ast.unparse(d)
        if re.search(r"\bjax\.jit\b|(^|\W)jit\b", text):
            return True
    return False


@rule("SQ006", "traced-nondeterminism",
      "a clock or unseeded global-RNG draw baked into a traced function "
      "makes every retrace numerically different — no parity pin, "
      "recompile guard, or cross-backend token identity can survive it")
class _NondeterminismRule(ast.NodeVisitor):
    """Inside trace-scope code — any function in ``repro/kernels``,
    ``repro/models`` or ``repro/core``, plus ``@jax.jit``-decorated
    functions anywhere — flag wall-clock reads, stdlib ``random`` and
    legacy global-state ``np.random.*`` calls. Seeded
    ``np.random.default_rng(seed)`` generators and ``jax.random`` keys are
    the sanctioned sources."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx
        self.always = ctx.in_pkg("kernels", "models", "core")
        self._depth = 0

    def _visit_fn(self, node):
        marked = self.always or _is_jit_decorated(node)
        if marked:
            self._depth += 1
        self.generic_visit(node)
        if marked:
            self._depth -= 1

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def visit_Call(self, node: ast.Call):
        if self._depth:
            name = _call_name(node)
            if name in _CLOCK_CALLS or _STDLIB_RANDOM.match(name) or \
                    _GLOBAL_NP_RANDOM.match(name):
                self.ctx.add(
                    node, "SQ006",
                    f"{name}(...) in trace scope is nondeterministic "
                    f"across traces — derive randomness from a passed-in "
                    f"jax.random key / seeded default_rng, and timestamps "
                    f"from the host caller")
        self.generic_visit(node)


# --------------------------------------------------------------------------
# SQ007 — unused (stale) suppression
# --------------------------------------------------------------------------

@rule("SQ007", "unused-suppression",
      "a stale disable=SQxxx(reason) keeps claiming a hazard that no "
      "longer exists — and silently swallows the rule the next time it "
      "fires on that line for a brand-new bug")
class _UnusedSuppressionRule(ast.NodeVisitor):
    """Driver-implemented rule: :func:`lint_source` reports any parsed
    ``disable=SQxxx(...)`` whose rule ran on this file but did not fire on
    the suppressed line. Registered here (with a no-op visitor) so the
    code shows up in the registry / ``--list-rules`` and participates in
    ``codes=`` selection. Suppression codes whose rule did *not* run in
    this invocation (e.g. ``SQ008``, owned by the dataflow pass) are left
    alone — their owner validates them."""

    def __init__(self, ctx: _FileContext):
        self.ctx = ctx


# --------------------------------------------------------------------------
# Suppression parsing
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"soniq-lint:\s*disable=(.*)")
_CODE_REASON_RE = re.compile(r"(SQ\d{3})\s*(?:\(([^)]*)\))?")


def _parse_suppressions(source: str, path: str
                        ) -> Tuple[Dict[int, Dict[str, str]],
                                   List[Violation]]:
    """line -> {code: reason} plus malformed-suppression violations.

    A comment-only suppression line applies to the next non-comment line;
    an end-of-line suppression applies to its own (logical) line."""
    by_line: Dict[int, Dict[str, str]] = {}
    malformed: List[Violation] = []
    lines = source.splitlines()
    pending: List[Tuple[int, str, str]] = []   # (comment line, code, reason)
    try:
        tokens = list(tokenize.generate_tokens(StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return by_line, malformed

    def parse_comment(text: str, line: int) -> List[Tuple[str, str]]:
        m = _SUPPRESS_RE.search(text)
        if not m:
            return []
        found = _CODE_REASON_RE.findall(m.group(1))
        out = []
        if not found:
            malformed.append(Violation(
                path, line, 0, "SQ000",
                "malformed soniq-lint suppression: expected "
                "disable=SQxxx(reason)",
                lines[line - 1].strip() if line <= len(lines) else ""))
        for code, reason in found:
            if not reason.strip():
                malformed.append(Violation(
                    path, line, 0, "SQ000",
                    f"suppression of {code} without a reason — write "
                    f"disable={code}(<why this site is safe>)",
                    lines[line - 1].strip() if line <= len(lines) else ""))
                continue
            out.append((code, reason.strip()))
        return out

    for tok in tokens:
        ttype, text, (srow, scol), _end, logical = tok
        if ttype == tokenize.COMMENT:
            pairs = parse_comment(text, srow)
            own_line = logical[:scol].strip()
            if own_line:                         # end-of-line comment
                for code, reason in pairs:
                    by_line.setdefault(srow, {})[code] = reason
            else:                                # comment-only line
                pending.extend((srow, c, r) for c, r in pairs)
        elif ttype in (tokenize.NL, tokenize.NEWLINE, tokenize.INDENT,
                       tokenize.DEDENT):
            continue
        elif ttype != tokenize.ENDMARKER and pending:
            for _comment_row, code, reason in pending:
                by_line.setdefault(srow, {})[code] = reason
            pending = []
    return by_line, malformed


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------

def baseline_key(v: Violation) -> Tuple[str, str, str]:
    return (v.path, v.code, v.source_line)


def load_baseline(path: Optional[Path]) -> List[Dict]:
    if path is None or not Path(path).exists():
        return []
    return json.loads(Path(path).read_text())


def match_baseline(result: LintResult, baseline: Iterable[Dict]
                   ) -> LintResult:
    """Move violations matching a baseline entry into ``baselined``.
    Matching is by (path, code, stripped line text): editing the flagged
    line invalidates the grandfather, forcing a fix-or-suppress."""
    keys = {(e["path"], e["code"], e["content"]) for e in baseline}
    keep, grandfathered = [], []
    for v in result.violations:
        (grandfathered if baseline_key(v) in keys else keep).append(v)
    return LintResult(keep, result.suppressed,
                      result.baselined + grandfathered)


def baseline_entries(violations: Iterable[Violation]) -> List[Dict]:
    return [{"path": v.path, "code": v.code, "content": v.source_line}
            for v in violations]


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

def lint_source(source: str, path: str = "",
                codes: Optional[Iterable[str]] = None) -> LintResult:
    """Lint one source string. ``path`` (repo-relative posix) feeds the
    rules' scope predicates; empty path means every rule applies."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return LintResult([Violation(path, e.lineno or 1, e.offset or 0,
                                     "SQ000", f"syntax error: {e.msg}")])
    ctx = _FileContext(path, source)
    wanted = set(codes) if codes is not None else None
    for r in all_rules():
        if wanted is not None and r.code not in wanted:
            continue
        r.make_visitor(ctx).visit(tree)
    supp_map, malformed = _parse_suppressions(source, path)
    violations: List[Violation] = list(malformed)
    suppressed: List[Suppression] = []
    used: set = set()                       # (line, code) that fired
    for v in sorted(ctx.violations, key=lambda v: (v.line, v.col, v.code)):
        reason = supp_map.get(v.line, {}).get(v.code)
        if reason is not None:
            used.add((v.line, v.code))
            suppressed.append(Suppression(v.path, v.line, v.code, reason,
                                          v.source_line))
        else:
            violations.append(v)
    # SQ007: any suppression whose rule ran in this invocation but did
    # not fire on its line is itself stale. Codes outside this run (a
    # `codes=` subset, or SQ008 which the dataflow pass owns) are left to
    # their owner; disable=SQ007(reason) on the same line is honored.
    ran = {r.code for r in all_rules()
           if wanted is None or r.code in wanted}
    if "SQ007" in ran:
        lines = ctx.lines
        for line in sorted(supp_map):
            src = lines[line - 1].strip() if line <= len(lines) else ""
            for code in sorted(supp_map[line]):
                if code == "SQ007" or code not in ran or \
                        (line, code) in used:
                    continue
                reason7 = supp_map[line].get("SQ007")
                if reason7 is not None:
                    suppressed.append(Suppression(path, line, "SQ007",
                                                  reason7, src))
                else:
                    violations.append(Violation(
                        path, line, 0, "SQ007",
                        f"unused suppression: {code} does not fire on "
                        f"this line — the hazard was fixed or moved; "
                        f"remove the stale disable={code}(...)", src))
    return LintResult(violations, suppressed)


def lint_file(path: Path, root: Optional[Path] = None) -> LintResult:
    rel = path.resolve()
    if root is not None:
        try:
            rel = rel.relative_to(Path(root).resolve())
        except ValueError:
            pass
    return lint_source(path.read_text(), rel.as_posix())


def _default_root(paths: Iterable[Path]) -> Optional[Path]:
    """Nearest ancestor holding this package's source tree — makes the
    repo-relative paths in reports/baseline stable regardless of cwd."""
    for p in paths:
        cur = Path(p).resolve()
        for anc in [cur] + list(cur.parents):
            if (anc / "src" / "repro").is_dir():
                return anc
    return None


def lint_paths(paths: Iterable[Path], root: Optional[Path] = None,
               baseline: Optional[Path] = None) -> LintResult:
    """Lint files/directories (``.py`` files, recursively) and apply the
    baseline."""
    paths = [Path(p) for p in paths]
    if root is None:
        root = _default_root(paths)
    files: List[Path] = []
    for p in paths:
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    result = LintResult()
    for f in files:
        result.extend(lint_file(f, root))
    result.violations.sort(key=lambda v: (v.path, v.line, v.col))
    return match_baseline(result, load_baseline(baseline))
