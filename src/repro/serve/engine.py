"""Serve engines over packed SONIQ weights (DESIGN.md §10).

Two engines share the packed-weight serve path (``soniq.to_serve`` /
``repro.api.transforms.convert_tree``: per-layer precisions re-budgeted to
the static segment mix, channels reordered (paper Obs. 4), codes
bit-packed into 1/2/4-bit carriers):

* :class:`LockstepEngine` — the original fixed-batch loop: one blocking
  ``generate()`` call, full-batch prefill, every row decodes until the
  longest request finishes. Kept as the parity/throughput baseline.
* :class:`DecodeEngine` — request-level **continuous batching**: an
  admission queue of :class:`repro.serve.scheduler.Request`, slot-based
  batch state, chunked prefill that fills idle slots while other slots
  decode, per-slot sampling params (temperature + seeded rng), and a
  streaming iterator returning :class:`Completion` objects as requests
  finish. Per-slot rows are independent, so its temperature-0 tokens are
  identical to the lockstep engine's (pinned by
  ``tests/test_serve_scheduler.py``).

``rebudget_pbits`` / ``serve_convert`` are deprecation shims kept for
external callers; the implementations moved to ``repro.api.transforms``.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import transforms as lifecycle
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig
from repro.models import lm

from . import kv_pool
from .scheduler import DECODE, Completion, Request, Scheduler


def _paged_geometry(arch_cfg, ecfg: "EngineConfig"):
    """(page_size, pages_per_seq, num_pages) of the paged layout — the
    engine-side mirror of ``blocks.block_cache_init``'s geometry (the
    logical table length is the effective ring length in pages)."""
    clen = min(ecfg.cache_len, arch_cfg.window) if arch_cfg.window \
        else ecfg.cache_len
    ps = ecfg.page_size
    if clen % ps:
        raise ValueError(
            f"page_size {ps} must divide the effective ring length {clen} "
            f"(cache_len clipped to the window) so paged rollover wraps "
            f"where the ring layout does")
    pps = clen // ps
    npages = ecfg.num_pages if ecfg.num_pages is not None \
        else ecfg.max_batch * pps + 1
    return ps, pps, npages


def rebudget_pbits(pbits: np.ndarray, w: np.ndarray,
                   qcfg: QuantConfig) -> np.ndarray:
    """DEPRECATED — moved to ``repro.api.transforms.rebudget_pbits``."""
    warnings.warn(
        "engine.rebudget_pbits is deprecated; use "
        "repro.api.transforms.rebudget_pbits (soniq.rebudget_pbits)",
        DeprecationWarning, stacklevel=2)
    return lifecycle.rebudget_pbits(pbits, w, qcfg)


def serve_convert(params, qcfg: QuantConfig):
    """DEPRECATED — use ``soniq.to_serve`` (or the pytree-level
    ``repro.api.transforms.convert_tree``)."""
    warnings.warn(
        "engine.serve_convert is deprecated; use soniq.to_serve / "
        "repro.api.transforms.convert_tree",
        DeprecationWarning, stacklevel=2)
    return lifecycle.convert_tree(params, qcfg, rebudget=True)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    cache_len: int = 256
    temperature: float = 0.0        # 0 = greedy (default for generate())
    cache_dtype: str = "float32"
    # Prompt tokens fed per slot per prefill step (1 = token-level prefill;
    # auto-reduced to 1 for SSM/hybrid/enc-dec archs, which need strictly
    # sequential state updates — see lm.supports_chunked_prefill).
    prefill_chunk: int = 8
    # Kernel backend for the jitted decode/prefill steps — a registry name
    # ("xla_ref", "pallas_interpret", "pallas_mosaic", alias "pallas") or
    # None to keep the model config's choice / SONIQ_BACKEND / negotiation
    # (repro.backend.registry; DESIGN.md §11). Baked into QuantConfig at
    # engine construction, so it is jit-trace-stable.
    backend: Optional[str] = None
    # Allow the backend to fuse the per-decode-step activation quantization
    # into the packed-GEMM prologue (bit-exact with the two-pass form —
    # DESIGN.md §11). False pins the two-pass reference; benchmarks flip
    # this to record the fused-vs-unfused delta.
    fuse_act_quant: bool = True
    # KV-cache precision (DESIGN.md §12). None = fp ring cache in
    # ``cache_dtype`` (status quo); 4 = packed 4-bit ring cache
    # (serve/kv_quant.py): ~4x fewer K/V payload bytes, decode attention
    # runs on the backend's ``qkv_attn_decode`` op (fused flash-decode
    # kernel on Pallas). Greedy tokens stay engine- and backend-parity at
    # q4; they differ from kv_bits=None by the pinned KV round-trip error.
    kv_bits: Optional[int] = None
    # KV-cache layout (DESIGN.md §13). "ring" reserves max_batch x
    # cache_len slots up front; "paged" draws ``page_size``-token pages
    # from a global pool on demand (serve/kv_pool.py: free-list +
    # refcounted copy-on-write prefix sharing), so resident bytes scale
    # with tokens actually cached and shared system prompts are stored
    # once. DecodeEngine only; greedy tokens stay token-identical to the
    # ring layout at equal kv_bits. ``page_size`` must divide the
    # effective ring length (cache_len clipped to the window).
    kv_layout: str = "ring"
    page_size: int = 16
    # Total pool pages incl. the reserved null page 0; None sizes for
    # full per-slot residency (max_batch * pages_per_seq + 1 — paging can
    # then never run out, occupancy is the win). Smaller pools gate
    # admission on page availability (head-of-line, FIFO preserved).
    num_pages: Optional[int] = None
    # Self-speculative decoding (DESIGN.md §14). 0 disables (status quo).
    # k > 0 makes each decode round draft k tokens with the low-slice
    # forward (the [K<=spec_draft_bits] segments of the SAME packed
    # carriers — zero extra weight bytes), then verify them in ONE
    # batched full-mix step; the longest matching prefix plus the verify
    # step's own token commit (1..k+1 tokens per round). Greedy streams
    # are token-identical to spec_tokens=0; temperature > 0 runs standard
    # rejection sampling (distribution-correct, not bitwise-equal).
    # DecodeEngine only; needs lm.supports_chunked_prefill (the verify
    # step feeds k+1 tokens per slot in one forward).
    spec_tokens: int = 0
    # Precision bound of the draft slice: segments above this skip.
    spec_draft_bits: int = 2


@dataclasses.dataclass
class JitEntry:
    """One jitted engine step function plus its audit metadata.

    Engines create every hot-path jit through :meth:`_PackedEngine._jit`,
    which records — *at trace time*, so steady-state steps pay nothing —
    how many times the function compiled (``trace_count``; the
    ``repro.analysis`` recompile guard pins this to 1 per shape family
    over a mixed traffic trace) and the abstract shapes it was traced at
    (``abstract_args``; the jaxpr/donation audits re-lower from these).
    ``donate_argnums`` is the engine's declaration of which cache-sized
    operands are donated (SQ004): the audit cross-checks it against the
    ``tf.aliasing_output`` markers in the lowered module.
    """
    name: str
    fn: Callable                       # the pre-jit python callable
    jitted: Callable = None
    donate_argnums: Tuple[int, ...] = ()
    trace_count: int = 0
    abstract_args: Optional[tuple] = None


class _PackedEngine:
    """Shared packed-params + jitted-step plumbing of both engines."""

    def _jit(self, name: str, fn: Callable, *,
             donate_argnums: Tuple[int, ...] = ()) -> Callable:
        """``jax.jit`` with the engine's audit bookkeeping (JitEntry) and
        buffer donation. Every cache-threading step function donates its
        cache operand: the old ring/pool buffers alias the new ones
        in-place instead of double-buffering cache-sized arrays each step
        (SQ004 — at production cache sizes the copy halves the batch that
        fits)."""
        entry = JitEntry(name, fn, donate_argnums=tuple(donate_argnums))

        @functools.wraps(fn)
        def traced(*args):
            entry.trace_count += 1
            entry.abstract_args = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                               jnp.result_type(x)), args)
            return fn(*args)

        entry.jitted = jax.jit(traced, donate_argnums=donate_argnums)
        self.jit_table[name] = entry
        return entry.jitted

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        self.jit_table: Dict[str, JitEntry] = {}
        self.cfg = arch_cfg.with_quant_mode(Phase.SERVE)
        if ecfg.backend is not None:
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, backend=ecfg.backend))
        if not ecfg.fuse_act_quant:
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, fuse_act_quant=False))
        if self.cfg.quant.act_scale_mode == "per_tensor":
            # Per-tensor dynamic act scales couple batch rows; serving needs
            # every request's tokens independent of batch composition
            # (continuous batching + lockstep parity), so the engines run
            # the row-independent per-token scale (DESIGN.md §10).
            self.cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant, act_scale_mode="per_token"))
        if ecfg.kv_layout not in ("ring", "paged"):
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r} "
                             f"(expected 'ring' or 'paged')")
        self.ecfg = ecfg
        self.params = params if already_serve else lifecycle.convert_tree(
            params, self.cfg.quant, rebudget=True)
        self._step = self._jit(
            "step", lambda p, c, t, pos: lm.decode_step(p, self.cfg, c, t,
                                                        pos),
            donate_argnums=(1,))

    def init_cache(self, batch: int):
        ecfg = self.ecfg
        if ecfg.kv_layout not in ("ring", "paged"):
            raise ValueError(f"unknown kv_layout {ecfg.kv_layout!r} "
                             f"(expected 'ring' or 'paged')")
        kwargs = {}
        if ecfg.kv_layout == "paged":
            ps, _pps, npages = _paged_geometry(self.cfg, ecfg)
            kwargs = dict(kv_layout="paged", page_size=ps,
                          num_pages=npages)
        return lm.init_cache(self.cfg, batch, ecfg.cache_len,
                             jnp.dtype(ecfg.cache_dtype),
                             kv_bits=ecfg.kv_bits, **kwargs)


class LockstepEngine(_PackedEngine):
    """Fixed-batch generation loop (greedy / shared-rng temperature
    sampling): the pre-continuous-batching baseline. Every row prefills and
    decodes in lockstep, so mixed-length batches burn full decode steps on
    rows that are already finished — `benchmarks/serve_throughput.py`
    quantifies the gap."""

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """prompts [B, S0] int32 -> [B, S0 + max_new] (greedy unless
        temperature > 0)."""
        if self.ecfg.kv_layout != "ring":
            raise ValueError(
                "LockstepEngine only supports kv_layout='ring': the paged "
                "layout needs the DecodeEngine's host-side PagePool to "
                "drive page allocation (DESIGN.md §13)")
        b, s0 = prompts.shape
        cache = self.init_cache(b)
        toks = jnp.asarray(prompts, jnp.int32)
        out = [toks]
        logits = None
        for t in range(s0):
            pos = jnp.full((b,), t, jnp.int32)
            logits, cache = self._step(self.params, cache, toks[:, t], pos)
        cur = self._sample(logits, rng, 0)
        for t in range(max_new_tokens):
            out.append(cur[:, None])
            if t == max_new_tokens - 1:
                break
            pos = jnp.full((b,), s0 + t, jnp.int32)
            logits, cache = self._step(self.params, cache, cur, pos)
            cur = self._sample(logits, rng, t + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    def _sample(self, logits, rng, t):
        if self.ecfg.temperature <= 0 or rng is None:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        k = jax.random.fold_in(rng, t)
        return jax.random.categorical(
            k, logits / self.ecfg.temperature).astype(jnp.int32)


def _key_bits(key) -> np.ndarray:
    """Raw uint32 bits of a PRNG key (accepts legacy raw or typed keys)."""
    if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return np.asarray(key, np.uint32)


def _softmax(x: np.ndarray) -> np.ndarray:
    """Numerically stable host-side softmax over the last axis (the
    speculative acceptance rule runs on host — DESIGN.md §14)."""
    x = np.asarray(x, np.float64)
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


def _sample_tokens(logits, keys, temps, counts):
    """Per-slot sampling: greedy where temp <= 0, else categorical with the
    slot's request key folded by its generated-token index (scheduling-
    invariant: request i's t-th token always uses fold_in(key_i, t))."""
    def one(lg, key, temp, n):
        greedy = jnp.argmax(lg, -1).astype(jnp.int32)
        k = jax.random.fold_in(key, n)
        samp = jax.random.categorical(
            k, lg / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        return jnp.where(temp > 0, samp, greedy)
    return jax.vmap(one)(logits, keys, temps, counts)


class DecodeEngine(_PackedEngine):
    """Request-level continuous-batching engine (DESIGN.md §10).

    Usage — streaming::

        eng = DecodeEngine(params, cfg, EngineConfig(max_batch=8))
        for completion in eng.serve(requests):   # yields as they finish
            ...

    or incremental (``submit`` / ``step``) for request loops that interleave
    admission with other work. ``generate()`` is a lockstep-compatible
    wrapper (same-shape prompts in, stacked tokens out) used by the legacy
    callers; at temperature 0 it returns exactly the lockstep tokens.
    """

    def __init__(self, params, arch_cfg, ecfg: EngineConfig,
                 *, already_serve: bool = False):
        super().__init__(params, arch_cfg, ecfg,
                         already_serve=already_serve)
        self.chunk = (ecfg.prefill_chunk
                      if lm.supports_chunked_prefill(self.cfg) else 1)
        b = ecfg.max_batch

        # Self-speculative decoding (DESIGN.md §14): a draft step running
        # the low-slice forward (same packed weights, high-bit carriers
        # skipped) and a verify step returning per-lane full-mix logits.
        self.spec_width = ecfg.spec_tokens + 1
        if ecfg.spec_tokens > 0:
            if not lm.supports_chunked_prefill(self.cfg):
                raise ValueError(
                    "spec_tokens > 0 needs chunked prefill: the batched "
                    "verify step feeds k+1 tokens per slot in one forward, "
                    "and this arch family is strictly sequential "
                    "(lm.supports_chunked_prefill — DESIGN.md §14)")
            if self.spec_width > ecfg.cache_len:
                raise ValueError(
                    f"spec_tokens={ecfg.spec_tokens} cannot exceed "
                    f"cache_len-1={ecfg.cache_len - 1}")
            self._draft_cfg = dataclasses.replace(
                self.cfg, quant=dataclasses.replace(
                    self.cfg.quant,
                    draft_slice_bits=ecfg.spec_draft_bits))

            # Both return (argmax tokens, logits, cache): at temp 0 only
            # the tiny int argmaxes cross to host; the logits stay on
            # device unless a slot actually samples (rejection sampling).
            def draft_step(p, c, t, pos, act):
                lg, c2 = lm.decode_step(p, self._draft_cfg, c, t, pos,
                                        active=act)
                return jnp.argmax(lg, -1).astype(jnp.int32), lg, c2

            def verify_step(p, c, t, pos):
                lg, c2 = lm.verify_step(p, self.cfg, c, t, pos)
                return jnp.argmax(lg, -1).astype(jnp.int32), lg, c2

            self._draft = self._jit("draft", draft_step,
                                    donate_argnums=(1,))
            self._verify = self._jit("verify", verify_step,
                                     donate_argnums=(1,))

        # Sampling is fused into the jitted step: one dispatch and one
        # [B]-int transfer per engine step (the decode loop is host-latency
        # bound at small batch).
        def decode_sample(p, c, t, pos, act, keys, temps, counts):
            logits, c2 = lm.decode_step(p, self.cfg, c, t, pos, active=act)
            return _sample_tokens(logits, keys, temps, counts), c2

        def prefill_sample(p, c, t, pos, last, keys, temps, counts):
            logits, c2 = lm.prefill_step(p, self.cfg, c, t, pos, last)
            return _sample_tokens(logits, keys, temps, counts), c2

        self._decode = self._jit("decode", decode_sample,
                                 donate_argnums=(1,))
        self._prefill = self._jit("prefill", prefill_sample,
                                  donate_argnums=(1,))
        # One compiled reset for any admission set: idx is padded to
        # max_batch by repeating the first slot (re-wiping a row is
        # idempotent), so eager per-admission scatters never compile.
        self._reset = self._jit("reset", lm.reset_cache_slots,
                                donate_argnums=(0,))
        if ecfg.kv_layout == "paged":
            self._apply_ops = self._jit("apply_ops", kv_pool.apply_step_ops,
                                        donate_argnums=(0,))
            self._apply_poison = self._jit("apply_poison",
                                           kv_pool.apply_poison,
                                           donate_argnums=(0,))
        self._init_host_state()
        self.cache = None
        self._keys = np.zeros((b, 2), np.uint32)
        self._temps = np.zeros((b,), np.float32)

    def _init_host_state(self):
        """(Re)build the host-side scheduler — and, in the paged layout,
        the page-pool allocator that gates its admission."""
        b = self.ecfg.max_batch
        if self.ecfg.kv_layout == "paged":
            ps, pps, npages = _paged_geometry(self.cfg, self.ecfg)
            self.pool = kv_pool.PagePool(npages, ps, pps, b)
            self.sched = Scheduler(b, can_admit=self.pool.admissible)
            # Per-step device-op capacities (fixed jit shapes): each
            # planned slot touches at most ceil(width/page) + 1 pages,
            # where the step width is the prefill chunk — or the
            # speculative round width k+1 when that is larger.
            w = max(self.chunk, getattr(self, "spec_width", 1), 1)
            self._op_cap = b * (-(-w // ps) + 1)
            self._table_dirty = True       # first flush uploads the table
        else:
            self.pool = None
            self.sched = Scheduler(b)
        # Speculation telemetry (benchmarks record the mean accepted
        # draft length next to tokens/s).
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

    # --------------------------------------------------------- requests ----
    def submit(self, request: Request) -> int:
        """Queue a request; returns its request_id. In the paged layout a
        prompt whose page demand can never fit the pool is rejected here
        (ValueError) rather than deadlocking the admission queue, and the
        prompt's page digests are memoized for the prefix-map lookup at
        admission."""
        if self.pool is not None:
            plen = int(np.asarray(request.prompt).reshape(-1).shape[0])
            if plen and self.pool.target_pages(plen) > self.pool.capacity:
                raise ValueError(
                    f"prompt needs {self.pool.target_pages(plen)} KV pages "
                    f"but the pool only has {self.pool.capacity} "
                    f"allocatable pages — it could never be admitted. "
                    f"Raise EngineConfig.num_pages or shorten the prompt.")
        rid = self.sched.submit(request)
        if self.pool is not None and request.max_new_tokens > 0:
            self.pool.note_submit(rid, request.prompt)
        return rid

    def reset(self):
        """Drop all queued/active requests and cache state."""
        self._init_host_state()
        self.cache = None

    # ---------------------------------------------------------- paging ----
    def _flush_pool_ops(self, ops: "kv_pool.StepOps"):
        """Apply one batch of allocator decisions to the device cache:
        COW copies + fresh-page wipes + the full host page table (one
        fixed-shape jitted call — ids are padded with null-page no-ops),
        then any debug poisons. No-op when nothing changed."""
        if ops.any() or self._table_dirty:
            cap = self._op_cap
            assert len(ops.wipes) <= cap and len(ops.copies) <= cap, \
                (len(ops.wipes), len(ops.copies), cap)
            wipes = np.zeros((cap,), np.int32)     # pad: re-wipe null page
            wipes[:len(ops.wipes)] = ops.wipes
            src = np.zeros((cap,), np.int32)       # pad: null self-copy
            dst = np.zeros((cap,), np.int32)
            for i, (s, d) in enumerate(ops.copies):
                src[i], dst[i] = s, d
            self.cache = self._apply_ops(self.cache, self.pool.table,
                                         wipes, src, dst)
            self._table_dirty = False
        if ops.poisons:
            # Pad by repeating a real pid (the null page is never
            # poisoned); fixed capacity = the whole pool.
            pids = np.full((self.pool.capacity,), ops.poisons[0], np.int32)
            pids[:len(ops.poisons)] = ops.poisons
            self.cache = self._apply_poison(self.cache, pids)

    # ------------------------------------------------------------- step ----
    def step(self) -> List[Completion]:
        """One engine step: admit arrived requests into free slots (wiping
        their cache rows), feed every active slot (chunked prefill for
        prompt-phase slots, one token for decode-phase slots), sample, and
        return any completions (their slots free up for the next step).

        Paged layout (DESIGN.md §13): admission maps prefix-map hits into
        the slot's page table (those prompt tokens skip prefill — the
        final prompt token is always re-fed, its logits seed sampling);
        before the device step the allocator makes every page the step
        writes privately mapped (fresh allocations wiped, shared/
        registered pages copy-on-write); after it, freshly completed
        prompt pages register in the prefix map and finished slots release
        their pages (back to the free list, or parked in the cached LRU
        when registered — poisoned in ``SONIQ_KV_POISON=1`` debug mode).

        ``spec_tokens > 0`` routes the step through the speculative
        draft-k/verify-1 round instead (DESIGN.md §14) — same admission,
        same completions contract, 1..k+1 tokens committed per decoding
        slot per step.
        """
        b = self.ecfg.max_batch
        if self.cache is None:
            self.cache = self.init_cache(b)
            if self.pool is not None:
                self._table_dirty = True
        admitted = self.sched.admit()
        if admitted:
            idx = np.full((b,), admitted[0][0], np.int32)
            idx[:len(admitted)] = [s for s, _ in admitted]
            self.cache = self._reset(self.cache, idx)
            for slot, req in admitted:
                self._keys[slot] = _key_bits(jax.random.PRNGKey(req.seed))
                self._temps[slot] = req.temperature
                if self.pool is not None:
                    shared = self.pool.admit(slot, req)
                    if shared:
                        # Prefix hit: those tokens are already in mapped
                        # pages — prefill starts after them.
                        self.sched.slots[slot].n_fed = shared
                        self._table_dirty = True
        if self.ecfg.spec_tokens > 0:
            return self._spec_step()
        plan = self.sched.plan(self.chunk)
        if not plan:                       # idle: let queued arrivals age in
            return self.sched.advance({}, {})
        widths = {s: len(t) for s, t in plan.items()}
        if self.pool is not None:
            ops = kv_pool.StepOps()
            for slot, n in widths.items():
                self.pool.prepare(slot, self.sched.slots[slot].n_fed, n,
                                  ops)
            if ops.any():
                self._table_dirty = True
            self._flush_pool_ops(ops)
        counts = np.zeros((b,), np.int32)
        for slot in plan:
            counts[slot] = len(self.sched.slots[slot].generated)
        if max(widths.values()) > 1:
            c = self.chunk                 # fixed width: one compiled shape
            tokens = np.zeros((b, c), np.int32)
            pos = np.full((b, c), -1, np.int32)
            last = np.zeros((b,), np.int32)
            for slot, toks in plan.items():
                n = widths[slot]
                st = self.sched.slots[slot]
                tokens[slot, :n] = toks
                pos[slot, :n] = st.n_fed + np.arange(n)
                last[slot] = n - 1
            out, self.cache = self._prefill(self.params, self.cache,
                                            tokens, pos, last, self._keys,
                                            self._temps, counts)
        else:
            tokens = np.zeros((b,), np.int32)
            pos = np.zeros((b,), np.int32)
            active = np.zeros((b,), bool)
            for slot, toks in plan.items():
                tokens[slot] = toks[0]
                pos[slot] = self.sched.slots[slot].n_fed
                active[slot] = True
            out, self.cache = self._decode(self.params, self.cache,
                                           tokens, pos, active, self._keys,
                                           self._temps, counts)
        # soniq-lint: disable=SQ005(the one budgeted [B]-int sync per step)
        sampled = np.asarray(out)
        slot_of = {st.request.request_id: s
                   for s, st in self.sched.slots.items()}
        # Post-step fed counts, captured before advance() pops finished
        # slots: note_filled's wrapped-through guard needs the TRUE fed
        # count (prompt + generated - 1), not the prompt length — a
        # wrap-overwritten page must never register as prompt content.
        fed_of = {st.request.request_id: st.n_fed + widths.get(s, 0)
                  for s, st in self.sched.slots.items()}
        done = self.sched.advance(
            widths, {s: int(sampled[s]) for s in plan})
        if self.pool is not None:
            self._paged_after_advance(done, slot_of, fed_of, plan,
                                      kv_pool.StepOps())
        return done

    def _paged_after_advance(self, done, slot_of, fed_of, plan, ops):
        """Post-advance pool bookkeeping shared by both step flavors:
        register finished prompts' full pages (before release parks them
        in the cached LRU for future hits) and release their pages,
        register freshly completed prompt pages of still-active slots,
        then flush the accumulated device ops."""
        for c in done:
            slot = slot_of.get(c.request_id)
            if slot is None:               # zero-generation immediate
                continue
            self.pool.note_filled(slot, c.request.prompt,
                                  fed_of[c.request_id])
            self.pool.release(slot, ops)
            self._table_dirty = True
        for slot in plan:
            st = self.sched.slots.get(slot)
            if st is not None:
                self.pool.note_filled(slot, st.request.prompt, st.n_fed)
        self._flush_pool_ops(ops)

    # ------------------------------------------------------ speculative ----
    def _spec_rng(self, st, tag: int) -> np.random.Generator:
        """Deterministic host rng for temperature > 0 speculative
        sampling, keyed by (request seed, purpose tag, generated count):
        a request's stream depends only on its own state — never on batch
        composition (the scheduling-invariance contract of DESIGN.md
        §10). Spec-mode temp > 0 streams are distribution-correct but
        NOT bitwise-equal to the spec-off device sampler (§14)."""
        return np.random.default_rng(
            (int(st.request.seed) & 0x7FFFFFFF, tag, len(st.generated)))

    def _accept(self, st, drafts, dprobs, targets, lg_rows, rng):
        """Acceptance rule for one slot's k drafts given the verify
        argmaxes ``targets`` [k+1] and (temp > 0 only) the verify logits
        ``lg_rows`` [k+1, V] — lane j is the full-mix distribution of
        the token FOLLOWING draft j. Returns the committed token list
        (accepted prefix + one bonus/correction token — 1..k+1 tokens).

        temp 0: longest prefix of drafts matching the verify argmaxes,
        then the argmax at the first mismatch (correction) or after the
        last draft (bonus) — exactly the token-by-token greedy stream.
        temp > 0: standard speculative rejection sampling — accept draft
        d with prob min(1, q(d)/p(d)); on reject, sample the residual
        max(q - p, 0); if all accepted, sample the bonus from q."""
        t = st.request.temperature
        committed = []
        if t <= 0:
            a = 0
            while a < len(drafts) and drafts[a] == int(targets[a]):
                committed.append(drafts[a])
                a += 1
            committed.append(int(targets[a]))
            return committed
        for j, d in enumerate(drafts):
            q = _softmax(lg_rows[j] / t)
            p = dprobs[j]
            if rng.random() < q[d] / max(p[d], 1e-30):
                committed.append(d)
                continue
            resid = np.maximum(q - p, 0.0)
            tot = resid.sum()
            probs = resid / tot if tot > 0 else q
            committed.append(int(rng.choice(len(q), p=probs)))
            return committed
        q = _softmax(lg_rows[len(drafts)] / t)
        committed.append(int(rng.choice(len(q), p=q)))
        return committed

    def _spec_step(self) -> List[Completion]:
        """One speculative engine round (DESIGN.md §14): draft k tokens
        per decoding slot with the low-slice forward, verify them in ONE
        batched full-mix ``lm.verify_step`` of fixed width k+1 (which
        doubles as the chunked-prefill feed for prompt-phase slots riding
        the same call), commit the accepted prefix + the verify step's
        own token, and roll the rejected suffix back (ring: pure
        accounting — rejected entries carry future position stamps the
        causal mask excludes until legitimately overwritten; paged:
        wholly-stale freshly-allocated pages release).

        A slot whose round would wrap the KV ring cannot draft (the
        wrap-clobbered history could not be restored on rejection); it
        rides the verify step with just its own token — a plain full-mix
        decode step, so the guard never costs correctness."""
        b = self.ecfg.max_batch
        k = self.ecfg.spec_tokens
        c = self.spec_width                             # k + 1
        plan = self.sched.plan(c)
        if not plan:                       # idle: let queued arrivals age in
            return self.sched.advance({}, {})
        clen = min(self.ecfg.cache_len, self.cfg.window) \
            if self.cfg.window else self.ecfg.cache_len
        base_fed = {s: self.sched.slots[s].n_fed for s in plan}
        decode_slots = [s for s in plan
                        if self.sched.slots[s].phase == DECODE]
        draft_slots = [s for s in decode_slots if base_fed[s] + c <= clen]

        if self.pool is not None:
            ops = kv_pool.StepOps()
            for s in plan:
                w = c if s in draft_slots else \
                    (1 if s in decode_slots else len(plan[s]))
                self.pool.prepare(s, base_fed[s], w, ops)
            if ops.any():
                self._table_dirty = True
            self._flush_pool_ops(ops)

        # --- draft sub-steps: low-slice forward, decode-phase slots only
        # (a draft write to a PROMPT position would never be rewritten by
        # verify, so prefill-phase slots sit out with pos = -1).
        cur = np.zeros((b,), np.int32)
        for s in decode_slots:
            cur[s] = int(plan[s][0])
        hot = [s for s in decode_slots
               if self.sched.slots[s].request.temperature > 0]
        round_rng = {s: self._spec_rng(self.sched.slots[s], 0x5EC)
                     for s in hot}
        drafts = {s: [] for s in draft_slots}
        dprobs = {s: [] for s in draft_slots}
        active = np.zeros((b,), bool)
        for s in draft_slots:
            active[s] = True
        if draft_slots:
            for j in range(k):
                pos = np.zeros((b,), np.int32)
                for s in draft_slots:
                    pos[s] = base_fed[s] + j
                gr, lg, self.cache = self._draft(self.params, self.cache,
                                                 cur, pos, active)
                # soniq-lint: disable=SQ005(host acceptance needs the draft)
                gr = np.asarray(gr)
                # soniq-lint: disable=SQ005(logits only cross when sampling)
                lgh = np.asarray(lg, np.float32) if hot else None
                for s in draft_slots:
                    if self.sched.slots[s].request.temperature > 0:
                        p = _softmax(
                            lgh[s] / self.sched.slots[s].request.temperature)
                        tok = int(round_rng[s].choice(len(p), p=p))
                        dprobs[s].append(p)
                    else:
                        tok = int(gr[s])
                    drafts[s].append(tok)
                    cur[s] = tok

        # --- one batched full-mix verify (+ prefill feed) step
        tokens = np.zeros((b, c), np.int32)
        pos = np.full((b, c), -1, np.int32)
        for s, toks in plan.items():
            feed = [int(plan[s][0])] + drafts[s] if s in draft_slots \
                else [int(x) for x in toks[:c]]
            tokens[s, :len(feed)] = feed
            pos[s, :len(feed)] = base_fed[s] + np.arange(len(feed))
        gr, lg, self.cache = self._verify(self.params, self.cache,
                                          tokens, pos)
        # soniq-lint: disable=SQ005(per-round acceptance sync, DESIGN §14)
        gr = np.asarray(gr)                             # [B, C] argmaxes
        need_lg = bool(hot) or any(
            self.sched.slots[s].request.temperature > 0 for s in plan
            if s not in decode_slots)
        # soniq-lint: disable=SQ005(logits only cross when a slot samples)
        lgh = np.asarray(lg, np.float32) if need_lg else None   # [B, C, V]

        # --- host-side acceptance + commit
        fed = {}
        sampled = {}
        for s, toks in plan.items():
            st = self.sched.slots[s]
            if s not in decode_slots:
                n = len(toks)
                fed[s] = n
                if st.n_fed + n >= len(st.request.prompt):
                    # Prompt completes this step: its last lane's logits
                    # seed sampling (argmax at temp 0 — identical to the
                    # device sampler's greedy branch).
                    sampled[s] = int(gr[s, n - 1]) \
                        if st.request.temperature <= 0 \
                        else self._pick(st, lgh[s, n - 1])
                continue
            committed = self._accept(st, drafts.get(s, []),
                                     dprobs.get(s, []), gr[s],
                                     None if lgh is None else lgh[s],
                                     round_rng.get(s))
            a = len(committed) - 1          # accepted drafts
            fed[s] = 1 + a
            sampled[s] = committed
            if s in draft_slots:
                self.spec_rounds += 1
                self.spec_drafted += k
                self.spec_accepted += a
                if self.pool is not None and a < k:
                    self._table_dirty = True

        # --- paged rollback of wholly-rejected pages, then advance
        slot_of = {st.request.request_id: s
                   for s, st in self.sched.slots.items()}
        fed_of = {st.request.request_id: st.n_fed + fed.get(s, 0)
                  for s, st in self.sched.slots.items()}
        ops = kv_pool.StepOps()
        if self.pool is not None:
            for s in draft_slots:
                self.pool.rollback(s, base_fed[s] + fed[s],
                                   base_fed[s] + c, ops)
        done = self.sched.advance(fed, sampled)
        if self.pool is not None:
            self._paged_after_advance(done, slot_of, fed_of, plan, ops)
        return done

    def _pick(self, st, lg_row) -> int:
        """Sample one token from a host-side fp32 logits row with the
        slot's params (greedy argmax at temp 0; host rng otherwise)."""
        t = st.request.temperature
        if t <= 0:
            return int(np.argmax(lg_row))
        p = _softmax(lg_row / t)
        return int(self._spec_rng(st, 0x9EF).choice(len(p), p=p))

    def spec_stats(self) -> dict:
        """Speculation telemetry: rounds drafted, draft tokens proposed /
        accepted, and the mean accepted draft length per round (the
        benchmark's acceptance figure — k accepted means every draft
        survived verification)."""
        return {
            "spec_tokens": self.ecfg.spec_tokens,
            "rounds": self.spec_rounds,
            "drafted": self.spec_drafted,
            "accepted": self.spec_accepted,
            "mean_accepted": (self.spec_accepted / self.spec_rounds
                              if self.spec_rounds else 0.0),
        }

    # ----------------------------------------------------- cancellation ----
    def cancel(self, request_id: int) -> Optional[Completion]:
        """Cancel a request by id — queued or active — releasing every
        resource it holds. A queued request leaves the admission queue
        (and, paged, drops its memoized digests + any admissible()
        reservation); an active one frees its batch slot AND routes its
        pool pages through ``PagePool.release`` with the device table
        re-uploaded before the next step — ``Scheduler.evict`` alone
        would leak them (refcount drift, ``PagePool.check()`` asserts).
        Returns the "evicted" Completion, or None when the id is unknown
        or already finished. Call between engine steps."""
        comp = self.sched.cancel(request_id)
        if comp is not None:
            if self.pool is not None:
                self.pool.forget_submit(request_id)
            return comp
        slot = next((s for s, st in self.sched.slots.items()
                     if st.request.request_id == request_id), None)
        if slot is None:
            return None
        if self.pool is not None:
            ops = kv_pool.StepOps()
            # A cancelled slot's finished prompt pages still register
            # (they are valid shared-prefix content for future requests);
            # mid-prefill slots simply have no full pages to offer.
            st = self.sched.slots[slot]
            self.pool.note_filled(slot, st.request.prompt, st.n_fed)
            self.pool.release(slot, ops)
            self._table_dirty = True
            self._flush_pool_ops(ops)
        return self.sched.evict(slot)

    # ---------------------------------------------------------- metrics ----
    def paged_kv_stats(self) -> dict:
        """Occupancy / sharing metrics of the paged KV pool (benchmarks
        record these next to tokens/s — DESIGN.md §13). Byte figures count
        K/V *payload* only (codes + scales / fp k and v), matching
        ``kv_quant.cache_payload_bytes`` on the ring side; a "page" spans
        every layer (the allocator maps one physical id in all layers)."""
        assert self.pool is not None, "paged_kv_stats needs kv_layout='paged'"
        assert self.cache is not None, "run at least one step first"
        per_page = kv_pool.paged_payload_bytes_per_page(self.cache)
        pool = self.pool
        return {
            "page_size": pool.page_size,
            "num_pages": pool.num_pages,
            "page_payload_bytes": per_page,
            "resident_pages": pool.resident_pages,
            "peak_resident_pages": pool.peak_resident,
            "resident_payload_bytes": pool.resident_pages * per_page,
            "peak_resident_payload_bytes": pool.peak_resident * per_page,
            "reserved_payload_bytes": pool.capacity * per_page,
            "prefix_hits": pool.hits,
            "prefix_lookups": pool.lookups,
            "prefix_hit_rate": pool.prefix_hit_rate,
        }

    # -------------------------------------------------------- streaming ----
    def run(self) -> Iterator[Completion]:
        """Drive steps until queue and slots drain, yielding completions in
        finish order."""
        while self.sched.has_work():
            yield from self.step()

    def serve(self, requests: Iterable[Request]) -> Iterator[Completion]:
        """Submit all requests, then stream completions."""
        for r in requests:
            self.submit(r)
        return self.run()

    # ------------------------------------------------------------ compat ----
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 rng: Optional[jax.Array] = None) -> np.ndarray:
        """Lockstep-compatible batch call: same-length prompts [B, S0] ->
        stacked [B, S0 + max_new]. Resets any in-flight engine state.
        Greedy unless the engine temperature > 0 AND ``rng`` is given (the
        per-request seeds are then derived from ``rng``; the stream is
        reproducible but not bitwise-identical to lockstep sampling, which
        shares one rng across the batch)."""
        self.reset()
        prompts = np.asarray(prompts, np.int32)
        temp = self.ecfg.temperature if rng is not None else 0.0
        base = int(_key_bits(rng).ravel()[-1]) if rng is not None else 0
        reqs = [Request(prompt=p, max_new_tokens=max_new_tokens,
                        temperature=temp, seed=base + i)
                for i, p in enumerate(prompts)]
        out = {c.request_id - reqs[0].request_id: c.tokens
               for c in self.serve(reqs)}
        return np.stack([out[i] for i in range(len(reqs))])


# Leaf-name vocabulary for packed_model_bytes. Packed carriers count one
# byte per element; fp leaves count their dtype itemsize; metadata leaves
# (permutations / precision maps — the paper's "3 ints per layer" lives in
# buffer shapes, not here) are excluded from the network-size metric.
_PACKED_LEAVES = frozenset({"w4", "w2", "w1"})
_FP_LEAVES = frozenset({"w", "table", "wscale", "b", "g", "conv_w",
                        "conv_b", "A_log", "D", "dt_bias", "norm_g"})
_META_LEAVES = frozenset({"perm", "pbits_sorted", "pbits", "s"})


def packed_model_bytes(serve_params) -> int:
    """Total packed weight bytes (the paper's network-size metric).

    Every leaf name must be classified (packed carrier / fp weight /
    metadata); an unknown name raises ``ValueError`` instead of being
    silently skipped — a renamed carrier leaf must not make the metric
    under-report."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(serve_params)[0]:
        if leaf is None:
            continue
        name = str(getattr(path[-1], "key", ""))
        if name in _PACKED_LEAVES:
            total += leaf.size
        elif name in _FP_LEAVES:
            total += leaf.size * np.dtype(leaf.dtype).itemsize
        elif name not in _META_LEAVES:
            raise ValueError(
                f"packed_model_bytes: unknown leaf {jax.tree_util.keystr(path)!r}"
                f" (name {name!r}) — classify it in engine._PACKED_LEAVES/"
                "_FP_LEAVES/_META_LEAVES so the size metric stays honest")
    return int(total)
