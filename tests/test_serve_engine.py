"""Serve path: QAT -> packed conversion -> batched generation — and the
self-speculative decode round + request cancellation (DESIGN.md §14)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import base as backend_base
from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine
from repro.serve.scheduler import Request


def _tiny(mode="qat"):
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode=mode))


@pytest.fixture(scope="module")
def served():
    cfg = _tiny()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_rebudget_pbits_respects_ranking():
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25))
    w = np.random.default_rng(0).normal(0, 1, (128, 16)).astype(np.float32)
    pbits = np.array([1, 4, 4, 2, 1, 2, 4, 4], np.int8)
    out = engine.rebudget_pbits(pbits, w, qcfg)
    assert sorted(out.tolist()) == sorted([4, 4, 4, 4, 2, 2, 1, 1])
    # trained 4-bit groups keep 4 bits while budget allows
    assert all(out[i] == 4 for i in (1, 2, 6, 7))


def test_serve_convert_stacked_layers():
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    sp = engine.serve_convert(jax.device_get(params), cfg.quant)
    wq = sp["groups"][0]["attn"]["wq"]
    assert "w4" in wq and wq["w4"].dtype == jnp.uint8
    assert wq["w4"].shape[0] == 2          # stacked over 2 layers
    assert engine.packed_model_bytes(sp) > 0


def test_generate_shapes_and_determinism():
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = engine.DecodeEngine(jax.device_get(params), cfg,
                              engine.EngineConfig(cache_len=64))
    prompts = np.asarray([[1, 2, 3], [4, 5, 6]], np.int32)
    out1 = eng.generate(prompts, max_new_tokens=5)
    out2 = eng.generate(prompts, max_new_tokens=5)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)      # greedy = deterministic
    assert (out1[:, 3:] < cfg.vocab_size).all()


def test_serve_logits_close_to_qat():
    """Packed decode must track the QAT model it was converted from."""
    cfg = _tiny()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.asarray([3, 7], jnp.int32)
    pos = jnp.asarray([0, 0], jnp.int32)

    cache_q = lm.init_cache(cfg, 2, 32, jnp.float32)
    lg_qat, _ = lm.decode_step(params, cfg, cache_q, tok, pos)

    scfg = dataclasses.replace(cfg,
                               quant=dataclasses.replace(cfg.quant,
                                                         mode="serve"))
    sp = engine.serve_convert(jax.device_get(params), scfg.quant)
    cache_s = lm.init_cache(scfg, 2, 32, jnp.float32)
    lg_srv, _ = lm.decode_step(sp, scfg, cache_s, tok, pos)
    # same argmax on a clear margin is the serving contract
    corr = np.corrcoef(np.asarray(lg_qat).ravel(),
                       np.asarray(lg_srv).ravel())[0, 1]
    assert corr > 0.98


# ================================== self-speculative decoding (§14) =======
def _ecfg(**kw):
    base = dict(max_batch=2, cache_len=32, prefill_chunk=4)
    base.update(kw)
    return engine.EngineConfig(**base)


def _mixed_requests(rng, lens=(3, 9, 5, 2), news=(6, 9, 4, 7), **kw):
    return [Request(prompt=rng.integers(1, 100, (l,)), max_new_tokens=n,
                    seed=i, **kw)
            for i, (l, n) in enumerate(zip(lens, news))]


def _tokens_of(eng, reqs):
    got = {c.request_id: c.tokens for c in eng.serve(
        [dataclasses.replace(r) for r in reqs])}
    return {k - min(got): v for k, v in got.items()}


@pytest.mark.parametrize("kv_bits", [None, 4])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_spec_greedy_token_identity(served, kv_bits, kv_layout):
    """THE §14 acceptance pin: at temperature 0 the speculative engine's
    token streams are IDENTICAL to the spec-off engine's — on the ring
    and the paged layout, at fp and q4 KV alike — from the same packed
    checkpoint with zero extra weight bytes."""
    cfg, params = served
    layout_kw = dict(kv_bits=kv_bits) if kv_layout == "ring" else \
        dict(kv_bits=kv_bits, kv_layout="paged", page_size=4)
    reqs = _mixed_requests(np.random.default_rng(0))
    base = engine.DecodeEngine(params, cfg, _ecfg(**layout_kw))
    spec = engine.DecodeEngine(params, cfg, _ecfg(
        spec_tokens=3, spec_draft_bits=2, **layout_kw))
    want = _tokens_of(base, reqs)
    got = _tokens_of(spec, reqs)
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    st = spec.spec_stats()
    assert st["rounds"] > 0 and st["drafted"] == 3 * st["rounds"]
    # the draft shares every packed carrier: no extra weight memory
    assert engine.packed_model_bytes(spec.params) == \
        engine.packed_model_bytes(base.params)
    if kv_layout == "paged":
        spec.pool.check()


def test_spec_draft_path_dispatched(served):
    """The draft forward must actually take the low-slice branch of the
    shared packed_matmul driver (trace-time counter, same pattern as the
    kernel-dispatch asserts) — and the spec-off engine must never tick
    it."""
    cfg, params = served
    reqs = _mixed_requests(np.random.default_rng(1), lens=(3, 5),
                           news=(4, 6))
    before = backend_base.draft_matmul_call_count()
    base = engine.DecodeEngine(params, cfg, _ecfg())
    _tokens_of(base, reqs)
    assert backend_base.draft_matmul_call_count() == before
    spec = engine.DecodeEngine(params, cfg, _ecfg(spec_tokens=2))
    _tokens_of(spec, reqs)
    assert backend_base.draft_matmul_call_count() > before


def test_spec_ring_wrap_guard_keeps_parity(served):
    """A decoding slot whose draft round would write past the ring end
    cannot roll back (the wrap clobbers in-window history), so it must
    ride the verify step with one token — parity holds right up to a
    completely full cache."""
    cfg, params = served
    reqs = [Request(prompt=np.arange(1, 6, dtype=np.int32),
                    max_new_tokens=11, seed=0)]      # 5 + 11 = 16 = clen
    base = engine.DecodeEngine(params, cfg, _ecfg(max_batch=1,
                                                  cache_len=16))
    spec = engine.DecodeEngine(params, cfg, _ecfg(max_batch=1,
                                                  cache_len=16,
                                                  spec_tokens=3))
    want, got = _tokens_of(base, reqs), _tokens_of(spec, reqs)
    np.testing.assert_array_equal(want[0], got[0])
    st = spec.spec_stats()
    assert st["rounds"] > 0          # early rounds drafted ...
    # ... but the tail rounds (base_fed + 4 > 16) were guarded: fewer
    # drafted tokens than an unguarded run would produce.
    assert st["drafted"] < 11 * 3


def test_spec_temperature_reproducible_and_live(served):
    """temp > 0 speculation: distribution-correct rejection sampling on
    the host rng — reproducible across engine resets, and actually
    sampling (different seeds diverge). Bitwise equality with the
    spec-off device sampler is explicitly NOT the contract (§14)."""
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg, _ecfg(spec_tokens=2))

    def run(seed_offset=0):
        eng.reset()
        return _tokens_of(eng, _mixed_requests(
            np.random.default_rng(2), lens=(3, 6), news=(8, 6),
            temperature=0.8))

    a, b = run(), run()
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    eng.reset()
    other = _tokens_of(eng, [
        dataclasses.replace(r, seed=100 + i) for i, r in enumerate(
            _mixed_requests(np.random.default_rng(2), lens=(3, 6),
                            news=(8, 6), temperature=0.8))])
    assert any(not np.array_equal(a[k], other[k]) for k in a)


def test_ring_rewind_stale_future_entries_are_masked(served):
    """The §14 ring-rollback argument, pinned at the model level: after
    entries land at positions [0, 6), re-feeding from position 3 (the
    rollback) must produce logits identical to a cache that never saw
    positions 3..5 — the stale entries carry future pos stamps the
    causal mask excludes."""
    cfg, params = served
    toks = np.asarray([[7], [11], [13], [17], [19], [23]], np.int32)
    dirty = lm.init_cache(cfg, 1, 16, jnp.float32)
    for t in range(6):
        _, dirty = lm.decode_step(params, cfg, dirty, toks[t],
                                  jnp.asarray([t], jnp.int32))
    clean = lm.init_cache(cfg, 1, 16, jnp.float32)
    for t in range(3):
        _, clean = lm.decode_step(params, cfg, clean, toks[t],
                                  jnp.asarray([t], jnp.int32))
    # rollback to n_fed=3, then feed a DIFFERENT continuation
    new_tok = jnp.asarray([29], jnp.int32)
    pos3 = jnp.asarray([3], jnp.int32)
    lg_dirty, _ = lm.decode_step(params, cfg, dirty, new_tok, pos3)
    lg_clean, _ = lm.decode_step(params, cfg, clean, new_tok, pos3)
    np.testing.assert_array_equal(np.asarray(lg_dirty),
                                  np.asarray(lg_clean))


# ============================================ request cancellation ========
def test_cancel_queued_request(served):
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg, _ecfg(max_batch=1))
    rid0 = eng.submit(Request(prompt=np.asarray([1, 2, 3], np.int32),
                              max_new_tokens=4))
    rid1 = eng.submit(Request(prompt=np.asarray([4, 5], np.int32),
                              max_new_tokens=4))
    eng.step()                               # admits rid0 only
    comp = eng.cancel(rid1)
    assert comp is not None and comp.finish_reason == "evicted"
    assert comp.new_tokens.size == 0 and comp.steps == 0
    assert eng.cancel(99999) is None         # unknown id
    done = list(eng.run())
    assert [c.request_id for c in done] == [rid0]
    assert done[0].finish_reason == "length"


@pytest.mark.parametrize("steps_before_cancel", [1, 4])
def test_cancel_active_paged_releases_pages(served, steps_before_cancel):
    """Satellite regression: cancelling an ACTIVE request (mid-prefill at
    1 step, mid-decode at 4) must route its pages through
    ``PagePool.release`` — ``Scheduler.evict`` alone leaked them — with
    the allocator invariants intact and follow-up traffic ring-parity."""
    cfg, params = served
    paged_kw = dict(kv_bits=4, kv_layout="paged", page_size=4)
    eng = engine.DecodeEngine(params, cfg, _ecfg(max_batch=2, **paged_kw))
    victim = Request(prompt=np.arange(1, 11, dtype=np.int32),
                     max_new_tokens=8)
    rid = eng.submit(victim)
    for _ in range(steps_before_cancel):
        eng.step()
    st = eng.sched.slots[0]
    mid_prefill = st.n_fed < len(victim.prompt)
    assert mid_prefill == (steps_before_cancel == 1)
    comp = eng.cancel(rid)
    assert comp is not None and comp.finish_reason == "evicted"
    eng.pool.check()
    assert (eng.pool.table[0] == -1).all()   # every page reference dropped
    assert eng.cancel(rid) is None           # idempotent: already finished
    # Follow-up requests admit into the freed slot and stay ring-parity.
    reqs = _mixed_requests(np.random.default_rng(3), lens=(3, 7),
                           news=(5, 4))
    ring = engine.DecodeEngine(params, cfg, _ecfg(max_batch=2, kv_bits=4))
    want, got = _tokens_of(ring, reqs), _tokens_of(eng, reqs)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    eng.pool.check()


def test_cancel_active_ring_and_spec_engine(served):
    """Cancellation on the ring layout (no pool) frees the slot; the
    speculative engine cancels mid-flight too, and the survivors' tokens
    are untouched."""
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg, _ecfg(max_batch=2,
                                                 spec_tokens=2))
    keep = Request(prompt=np.asarray([9, 8, 7], np.int32),
                   max_new_tokens=6, seed=1)
    solo = engine.DecodeEngine(params, cfg, _ecfg(max_batch=2,
                                                  spec_tokens=2))
    want = _tokens_of(solo, [keep])[0]
    rid_victim = eng.submit(Request(
        prompt=np.asarray([1, 2], np.int32), max_new_tokens=8, seed=0))
    rid_keep = eng.submit(dataclasses.replace(keep))
    for _ in range(2):
        eng.step()
    comp = eng.cancel(rid_victim)
    assert comp is not None and comp.finish_reason == "evicted"
    done = {c.request_id: c for c in eng.run()}
    assert set(done) == {rid_keep}
    np.testing.assert_array_equal(done[rid_keep].tokens, want)


def test_spec_config_validation(served):
    cfg, params = served
    with pytest.raises(ValueError, match="cache_len"):
        engine.DecodeEngine(params, cfg, _ecfg(cache_len=4, spec_tokens=8))
    with pytest.raises(AssertionError):
        QuantConfig(mode="serve", draft_slice_bits=3)
