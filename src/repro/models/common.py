"""Shared model components: norms, embeddings, rotary position encodings."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .shard import shard


def rms_norm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps: float = 1e-5):
    xf = jnp.asarray(x, jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["g"]).astype(x.dtype)


def layer_norm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps: float = 1e-5):
    xf = jnp.asarray(x, jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["g"] + params["b"]).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed_lookup(params, tokens, compute_dtype):
    t = shard(params["table"], "vocab", "embed").astype(compute_dtype)
    return jnp.take(t, tokens, axis=0)


def embed_logits(params, x):
    """Tied readout: x [..., D] @ table.T -> [..., V] (fp32)."""
    t = shard(params["table"], "vocab", "embed")
    return jax.lax.dot_general(
        jnp.asarray(x, jnp.float32), jnp.asarray(t, jnp.float32),
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


# ------------------------------------------------------------------ RoPE ----
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 1e4,
               mrope_sections: Optional[tuple] = None):
    """Rotary embedding.

    x         : [B, S, H, Dh]
    positions : [B, S] int32, or [3, B, S] for M-RoPE (temporal/height/width
                position streams — Qwen2-VL §3; for text all three streams
                are equal, which reduces exactly to standard RoPE).
    """
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)   # [Dh/2]
    if positions.ndim == 2:            # standard RoPE
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,Dh/2]
    else:                               # M-RoPE: split freq dim into sections
        assert mrope_sections is not None and positions.shape[0] == 3
        secs = np.asarray(mrope_sections)
        assert secs.sum() == dh // 2, (mrope_sections, dh)
        parts = []
        off = 0
        for i, sec in enumerate(secs):
            f = freqs[off:off + sec]
            parts.append(positions[i][..., None].astype(jnp.float32) * f)
            off += sec
        ang = jnp.concatenate(parts, axis=-1)                  # [B,S,Dh/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(jnp.asarray(x, jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(max_len: int, d: int):
    """Whisper-style fixed sinusoidal embeddings [max_len, d]."""
    pos = np.arange(max_len)[:, None]
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / (1e4 ** (dim / d))
    out = np.zeros((max_len, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]
