"""The SMOL/SONIQ quantization grid and straight-through fake-quantization.

Grid (paper §II-B): an n-bit string b_1..b_n (MSB first) represents
    v = sum_i (2 b_i - 1) * 2^(1-i)
Equivalently, with u = unsigned integer value of the bits,
    v = (2u - (2^n - 1)) * 2^(1-n)
i.e. the odd multiples of 2^(1-n) in [-(2 - 2^(1-n)), +(2 - 2^(1-n))]:
    n=1: {-1, +1}
    n=2: {-1.5, -0.5, +0.5, +1.5}
    n=4: {-1.875, ..., -0.125, +0.125, ..., +1.875}
The grid is symmetric and zero-free; step 2^(2-n); max round-off 2^(1-n)
(which is exactly the Phase-I noise scale sigma(s_init)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def smol_values(p: int) -> np.ndarray:
    """All representable values of the p-bit SMOL grid, ascending."""
    u = np.arange(2 ** p)
    return (2 * u - (2 ** p - 1)) * 2.0 ** (1 - p)


def grid_max(p) -> jnp.ndarray:
    """Largest representable magnitude: 2 - 2^(1-p). Works on traced p."""
    return 2.0 - jnp.exp2(1.0 - p)


def quantize_to_int(x, p):
    """x (already scaled into the +-2 range) -> unsigned int codes u.

    Branchless in ``p`` (p may be a traced array broadcast against x).
    """
    p = jnp.asarray(p, jnp.float32)
    h = jnp.exp2(1.0 - p)            # 2^(1-p): half-step == max error
    two_p = 2.0 / h                  # 2^p
    u = jnp.round((jnp.asarray(x, jnp.float32) / h + (two_p - 1.0)) / 2.0)
    return jnp.clip(u, 0.0, two_p - 1.0)


def dequantize_int(u, p):
    """Unsigned codes u -> grid values, branchless in p."""
    p = jnp.asarray(p, jnp.float32)
    h = jnp.exp2(1.0 - p)
    two_p = 2.0 / h
    return (2.0 * jnp.asarray(u, jnp.float32) - (two_p - 1.0)) * h


def snap_to_grid(x, p):
    """Round x (scaled) to the nearest p-bit SMOL grid point (with clipping)."""
    return dequantize_int(quantize_to_int(x, p), p)


def _expand_groups(pbits, k, group_size):
    """[K//G] per-group values -> [K] per-channel values."""
    return jnp.repeat(jnp.asarray(pbits), group_size, axis=-1,
                      total_repeat_length=k)


# ---------------------------------------------------------------------------
# Clipped straight-through fake quantization.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant(x, pbits, scale, group_size=16):
    """Quantize-dequantize ``x`` along its last dim with per-group precisions.

    x      : [..., K]
    pbits  : [K // group_size] float/int in {1,2,4} (traced OK — branchless)
    scale  : broadcastable against x after grouping; the per-group scale is
             expanded along the last dim. Use scale=1.0 for the
             paper-faithful no-scale grid.
    """
    y, _ = _fake_quant_fwd_impl(x, pbits, scale, group_size)
    return y


def _fake_quant_fwd_impl(x, pbits, scale, group_size):
    k = x.shape[-1]
    p = _expand_groups(pbits, k, group_size).astype(jnp.float32)
    s = jnp.asarray(scale, jnp.float32)
    if s.ndim and s.shape[-1] == max(1, k // group_size) and k > s.shape[-1]:
        s = _expand_groups(s, k, group_size)
    xs = jnp.asarray(x, jnp.float32) / s
    q = snap_to_grid(xs, p)
    y = (q * s).astype(x.dtype)
    in_range = (jnp.abs(xs) <= grid_max(p)).astype(x.dtype)
    return y, in_range


def _fake_quant_fwd(x, pbits, scale, group_size):
    y, in_range = _fake_quant_fwd_impl(x, pbits, scale, group_size)
    return y, (in_range, pbits, scale)


def _fake_quant_bwd(group_size, res, g):
    in_range, pbits, scale = res
    # Clipped STE: pass gradient where |x/scale| is inside the grid range.
    dx = g * in_range
    return (dx, jnp.zeros_like(jnp.asarray(pbits, jnp.float32)),
            jnp.zeros_like(jnp.asarray(scale, jnp.float32)))


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)


def _static_grid_max(p: int) -> float:
    """grid_max for a static Python precision (trace-safe)."""
    return 2.0 - 2.0 ** (1 - p)


# Floor on any dynamic abs-max before it becomes a divisor: all-zero rows
# (padding lanes, freshly reset cache slots) must yield a tiny finite
# scale, never a 0 divisor. The single home of the zero-row guarantee —
# re-exported (and documented operationally) by ``repro.backend.base`` and
# shared by the serve KV quantizer and the in-kernel scale prologues.
ACT_SCALE_EPS = 1e-6


def abs_max_scale(x, axis=None, grid_p=4, eps=ACT_SCALE_EPS):
    """Dynamic scale mapping abs-max of x to the top of the 4-bit grid.

    stop_gradient'ed: scales are data statistics, not trained (beyond-paper
    extension; see DESIGN.md §8).
    """
    m = jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)), axis=axis, keepdims=True)
    return jax.lax.stop_gradient(jnp.maximum(m, eps)
                                 / _static_grid_max(grid_p))


def per_group_weight_scale(w, group_size=16, grid_p=4, eps=ACT_SCALE_EPS):
    """Per-(16-channel K group) scale for a [K, ...] weight."""
    k = w.shape[0]
    wg = jnp.abs(jnp.asarray(w, jnp.float32)).reshape(k // group_size, group_size, -1)
    m = jnp.max(wg, axis=(1, 2))
    return jax.lax.stop_gradient(jnp.maximum(m, eps)
                                 / _static_grid_max(grid_p))


# ---------------------------------------------------------------------------
# 16.6 fixed-point accumulator emulation (fidelity reference only — TPU uses
# fp32; see DESIGN.md §2 "Assumptions that changed").
# ---------------------------------------------------------------------------

def to_fixed_16_6(x):
    """Round to the paper's 16.6 fixed-point output format (10 int + 6 frac
    bits, signed): values k/64, |v| <= (2^15 - 1)/64."""
    x = jnp.asarray(x, jnp.float32)
    q = jnp.round(x * 64.0)
    q = jnp.clip(q, -(2 ** 15), 2 ** 15 - 1)
    return q / 64.0
