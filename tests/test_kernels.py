"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import transforms
from repro.core import pack as pack_lib
from repro.core import quant, smol
from repro.core.qtypes import QuantConfig
from repro.kernels import ops, prng, ref


def _rand_packed(key, kp, n, p):
    u = jax.random.randint(key, (kp, n), 0, 2 ** p).astype(jnp.uint8)
    return pack_lib.pack_codes(u, p)


# ----------------------------------------------------- packed matmul ----
@pytest.mark.parametrize("p", [1, 2, 4])
@pytest.mark.parametrize("m,kp,n", [(8, 128, 128), (32, 256, 128),
                                    (16, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_segment_matmul_sweep(p, m, kp, n, dtype):
    key = jax.random.PRNGKey(p * 1000 + m + kp + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (m, kp), dtype)
    wp = _rand_packed(k2, kp, n, p)
    scales = jax.random.uniform(k3, (kp // 16,), jnp.float32, 0.5, 2.0)
    got = ops.packed_segment_matmul(x, wp, scales, p=p, interpret=True,
                                    block_m=32, block_n=128, block_k=128)
    want = ref.packed_segment_matmul_ref(x, wp, scales, p)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("p", [1, 2, 4])
def test_packed_segment_matmul_no_scales(p):
    key = jax.random.PRNGKey(p)
    x = jax.random.normal(key, (16, 128))
    wp = _rand_packed(key, 128, 128, p)
    got = ops.packed_segment_matmul(x, wp, None, p=p, interpret=True,
                                    block_m=16, block_n=128, block_k=128)
    want = ref.packed_segment_matmul_ref(x, wp, None, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("p", [2, 4])
def test_packed_segment_matmul_act_quant(p):
    key = jax.random.PRNGKey(7 + p)
    x = jax.random.normal(key, (8, 256)) * 0.7
    wp = _rand_packed(key, 256, 128, p)
    s = quant.abs_max_scale(x)
    got = ops.packed_segment_matmul(x, wp, None, p=p, act_quant=True,
                                    act_scale=s, interpret=True,
                                    block_m=8, block_n=128, block_k=128)
    want = ref.packed_segment_matmul_ref(x / s, wp, None, p,
                                         act_quant=True) * s
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_packed_matmul_mixed_vs_serve_linear():
    """The fused kernel path must match the jnp serve path of SmolLinear."""
    qcfg = QuantConfig(mode="qat", mix=(0.5, 0.25, 0.25))
    key = jax.random.PRNGKey(0)
    params = smol.linear_init(key, 256, 128, qcfg)
    params["pbits"] = jnp.asarray(
        np.array([4, 1, 2, 4, 2, 1, 4, 4, 1, 2, 4, 2, 1, 4, 4, 2], np.int8))
    sp = transforms.pack_linear(params, qcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256))
    qserve = QuantConfig(mode="serve", mix=qcfg.mix)
    y_jnp = smol.linear_apply(sp, x, qserve)
    y_kern = ops.packed_matmul(x, sp, act_quant=True, interpret=True,
                               block_m=4, block_n=128, block_k=32)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_jnp),
                               rtol=1e-4, atol=1e-3)


# ------------------------------------------------------- quantize pack ----
@pytest.mark.parametrize("p", [1, 2, 4])
@pytest.mark.parametrize("k,n", [(128, 128), (256, 256), (64, 128)])
def test_quantize_pack_sweep(p, k, n):
    key = jax.random.PRNGKey(p * 31 + k + n)
    w = jax.random.normal(key, (k, n)) * 0.8
    scales = jax.random.uniform(jax.random.PRNGKey(1), (k // 16,),
                                jnp.float32, 0.5, 1.5)
    got = ops.quantize_pack(w, scales, p=p, interpret=True,
                            block_k=64, block_n=128)
    want = ref.quantize_pack_ref(w, p, scales)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_pack_roundtrips_through_matmul():
    """pack(w) then packed matmul == fake_quant(w) matmul."""
    p, k, n = 4, 128, 128
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (k, n)) * 0.4
    scales = quant.per_group_weight_scale(w, 16)
    wp = ops.quantize_pack(w, scales, p=p, interpret=True)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, k))
    y = ops.packed_segment_matmul(x, wp, scales, p=p, interpret=True,
                                  block_m=8, block_n=128, block_k=128)
    wq = np.asarray(quant.fake_quant(jnp.asarray(np.asarray(w).T),
                                     jnp.full((k // 16,), 4.0), scales, 16)).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ wq,
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- noise inject ----
@pytest.mark.parametrize("k,n", [(64, 128), (256, 256), (128, 512)])
def test_noise_inject_matches_ref(k, n):
    key = jax.random.PRNGKey(k + n)
    w = jax.random.normal(key, (k, n)) * 0.5
    s = jax.random.normal(jax.random.PRNGKey(1), (k // 16,))
    got = ops.noise_inject(w, s, 1234, interpret=True,
                           block_k=64, block_n=128)
    want = ref.noise_inject_ref(w, s, 1234)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_noise_inject_respects_bounds_and_scale():
    k, n = 128, 256
    w = jnp.zeros((k, n))
    from repro.core import noise as noise_lib
    s = jnp.asarray([noise_lib.s_init(4)] * 4 + [noise_lib.s_init(2)] * 4)
    out = np.asarray(ops.noise_inject(w, s, 7, interpret=True))
    assert np.max(np.abs(out[:64])) <= 2 ** -3 + 1e-6     # sigma = 1/8
    assert np.max(np.abs(out[64:])) <= 2 ** -1 + 1e-6     # sigma = 1/2
    assert np.max(np.abs(out[64:])) > 2 ** -3             # actually scaled up


def test_noise_inject_deterministic_and_seed_sensitive():
    w = jnp.zeros((64, 128))
    s = jnp.zeros((4,))
    a = np.asarray(ops.noise_inject(w, s, 1, interpret=True))
    b = np.asarray(ops.noise_inject(w, s, 1, interpret=True))
    c = np.asarray(ops.noise_inject(w, s, 2, interpret=True))
    np.testing.assert_array_equal(a, b)
    assert np.abs(a - c).max() > 0


# ------------------------------------------- package naming (satellite) ----
def test_kernels_module_aliases_and_deprecated_reexports():
    """Satellite fix: the legacy function re-exports shadowed their home
    modules (`repro.kernels.packed_matmul` was a function). The modules
    are now importable under unambiguous `*_mod` aliases, the legacy
    function names still resolve for compat but warn, and importlib-style
    dotted access reaches the real modules."""
    import importlib

    import repro.kernels as K

    for alias, dotted in (("packed_matmul_mod", "repro.kernels.packed_matmul"),
                          ("quant_pack_mod", "repro.kernels.quant_pack"),
                          ("noise_inject_mod", "repro.kernels.noise_inject"),
                          ("fake_quant_mod", "repro.kernels.fake_quant")):
        assert getattr(K, alias) is importlib.import_module(dotted)
    assert callable(K.packed_matmul_mod.packed_segment_matmul)
    # legacy function names: still the ops wrappers, now warning
    for name in ("packed_matmul", "packed_segment_matmul", "quantize_pack",
                 "noise_inject"):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            assert getattr(K, name) is getattr(ops, name)
    # unshadowed module names stay plain module attributes (no warning)
    assert K.fake_quant is K.fake_quant_mod
    assert K.quant_pack is K.quant_pack_mod
    with pytest.raises(AttributeError):
        K.no_such_attribute


def test_prng_uniformity():
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    u = np.asarray(prng.uniform_pm1(idx, 42))
    assert abs(u.mean()) < 0.02
    assert abs(u.std() - 1 / np.sqrt(3)) < 0.02    # std of U[-1,1]
    assert u.min() >= -1.0 and u.max() < 1.0
