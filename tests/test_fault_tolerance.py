"""Crash-and-resume: SIGKILL a training subprocess mid-run, restart it, and
verify it resumes from the checkpoint and finishes with a contiguous step
history (the loop-level fault-tolerance contract)."""
import json
import os
import signal
import subprocess
import sys
import time

SCRIPT = r"""
import json, sys
sys.path.insert(0, "src")
import jax
from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.data import synthetic
from repro.train import loop, state as state_lib

ckpt, out, slow = sys.argv[1], sys.argv[2], sys.argv[3] == "slow"
cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                 num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                 head_dim=16, dtype="float32", param_dtype="float32",
                 q_block=32, quant=QuantConfig(mode="qat"))
tcfg = state_lib.TrainConfig(t1=4, t2=14, warmup=1, checkpoint_every=2,
                             ckpt_dir=ckpt)
stream = synthetic.TokenStream(synthetic.TokenStreamConfig(
    vocab_size=64, seq_len=16, batch_size=2))
def slow_hook(step, state, metrics):
    if slow:
        import time; time.sleep(0.4)
res = loop.train(cfg, tcfg, stream.batches(), hooks=[slow_hook])
json.dump([h["step"] for h in res["history"]], open(out, "w"))
"""


def test_kill_and_resume(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    out = str(tmp_path / "steps.json")
    script = str(tmp_path / "train.py")
    with open(script, "w") as f:
        f.write(SCRIPT)
    env = dict(os.environ, PYTHONPATH="src")

    # run slow, kill mid-training
    p = subprocess.Popen([sys.executable, script, ckpt, out, "slow"],
                         env=env, cwd=os.getcwd())
    deadline = time.time() + 120
    while time.time() < deadline:
        if os.path.exists(os.path.join(ckpt, "LATEST")):
            with open(os.path.join(ckpt, "LATEST")) as f:
                if int(f.read().strip() or 0) >= 4:
                    break
        time.sleep(0.3)
    p.send_signal(signal.SIGKILL)
    p.wait()
    assert not os.path.exists(out), "should have died before finishing"
    with open(os.path.join(ckpt, "LATEST")) as f:
        resumed_from = int(f.read().strip())
    assert resumed_from >= 2

    # restart: must resume from checkpoint and complete
    subprocess.run([sys.executable, script, ckpt, out, "fast"], env=env,
                   cwd=os.getcwd(), check=True, timeout=600)
    steps = json.load(open(out))
    assert steps[0] == resumed_from          # resumed, not restarted
    assert steps[-1] == 13                   # ran to completion
    assert steps == list(range(resumed_from, 14))
