"""Whisper-medium [arXiv:2212.04356; unverified] — encoder-decoder, conv
frontend stubbed (input_specs provides precomputed mel frames): 24L enc +
24L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865, GELU, LayerNorm."""
from .base import ArchConfig
from .registry import register


@register("whisper-medium")
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865, head_dim=64,
        mlp_act="gelu", norm="ln", attn_bias=True,
        encoder_layers=24, frontend="audio_stub", frontend_dim=80,
        tie_embeddings=True,
        source="arXiv:2212.04356; hf:openai/whisper-medium",
    )
