"""Pallas TPU kernels for the SONIQ hot paths (validated via interpret=True).

packed_matmul — mixed 1/2/4-bit packed GEMM (the paper's vmac_Pn)
quant_pack    — fused SMOL quantize + bit-pack
noise_inject  — fused Phase-I perturbation with in-kernel PRNG
"""
from . import ops, prng, ref
from .ops import noise_inject, packed_matmul, packed_segment_matmul, quantize_pack

__all__ = ["ops", "prng", "ref", "noise_inject", "packed_matmul",
           "packed_segment_matmul", "quantize_pack"]
