from . import engine, kv_quant, scheduler
