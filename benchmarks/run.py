"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. REPRO_BENCH_STEPS scales the
training-based reproductions (default 150 steps/phase)."""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from . import (fig7_accuracy_bpp, fig9_layer_bpp, roofline,
                   runtime_proxy, serve_throughput, table1_smol_variants,
                   table2_patterns)
    benches = [
        ("table2_patterns", table2_patterns.main),
        # explicit empty argv: the harness's own sys.argv must not leak
        # into the benchmark's argparse
        ("runtime_proxy", lambda: runtime_proxy.main([])),
        ("table1_smol_variants", table1_smol_variants.main),
        ("fig7_accuracy_bpp", fig7_accuracy_bpp.main),
        ("fig9_layer_bpp", fig9_layer_bpp.main),
        ("roofline", roofline.main),
        # explicit empty argv: the harness's own sys.argv must not leak
        # into the benchmark's argparse
        ("serve_throughput", lambda: serve_throughput.main([])),
    ]
    failures = 0
    for name, fn in benches:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name},0,FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
