"""Two-phase SONIQ training orchestration (paper Alg. 3).

Phase I  (steps [0, t1)):   noise-injected precision search — mode="noise".
Boundary (step t1):          per-layer Problem-1 solve + PatternMatch +
                             channel-precision freeze — host-side transform
                             of the parameter pytree ("noise" -> "qat").
Phase II (steps [t1, t2)):   STE fine-tuning under frozen precisions.
Deploy:                      Phase.QAT -> Phase.SERVE packing (soniq.to_serve).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import noise as noise_lib
from . import patterns as patterns_lib
from . import smol
from .phases import Phase, PhaseSpec
from .qtypes import QuantConfig


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    t1: int          # Phase I steps (paper: T1 epochs)
    t2: int          # total steps   (paper: T2 epochs)

    def phase(self, step: int) -> PhaseSpec:
        return Phase.NOISE if step < self.t1 else Phase.QAT


def _iter_s_layers(params, path=()):  # yield (path, dict) holding (w, s)
    if isinstance(params, dict):
        if "s" in params and "w" in params:
            yield path, params
        for k, v in params.items():
            yield from _iter_s_layers(v, path + (k,))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            yield from _iter_s_layers(v, path + (i,))


def collect_histograms(params, qcfg: QuantConfig) -> List[Tuple[int, int, int]]:
    """Per-(layer, scan-slice) (N4, N2, N1) histograms from trained s."""
    out = []
    for _, node in _iter_s_layers(params):
        s = np.asarray(node["s"])
        g = qcfg.eff_group_size(node["w"].shape[-2])
        for s_row in s.reshape(-1, s.shape[-1]):
            out.append(patterns_lib.histogram_from_s(s_row, g))
    return out


def pattern_match_params(params, qcfg: QuantConfig):
    """The Phase I -> Phase II boundary transform (host-side, not jitted):

      1. select the hardware pattern subset (paper §V-A / Table III),
      2. per layer: Problem-1 solve under that subset, PatternMatch the s
         vector, freeze per-group precisions,
      3. swap each (w, s) SmolLinear into a (w, pbits) QAT layer.

    Returns (new_params, report) where report carries solver stats.
    """
    allowed = patterns_lib.patterns_for(qcfg.num_patterns) \
        if qcfg.num_patterns in patterns_lib.DESIGN_POINT_PATTERNS \
        else patterns_lib.select_hardware_subset(
            collect_histograms(params, qcfg), qcfg.num_patterns)

    report: Dict = {"layers": [], "allowed": allowed}

    def transform(node):
        if not (isinstance(node, dict) and "s" in node and "w" in node):
            return node
        new = {k: v for k, v in node.items() if k != "s"}
        s = np.asarray(node["s"])
        g = qcfg.eff_group_size(node["w"].shape[-2])
        s2 = s.reshape(-1, s.shape[-1])
        pb_rows = []
        for s_row in s2:
            n4, n2, n1 = patterns_lib.histogram_from_s(s_row, g)
            sol = patterns_lib.solve_problem1(n4, n2, n1, allowed)
            s_m = patterns_lib.pattern_match(s_row, sol, g)
            pb = patterns_lib.precisions_from_matched_s(s_m)
            pb_rows.append(pb)
            report["layers"].append({
                "hist": (n4, n2, n1), "vectors": sol.num_vectors,
                "bpp": float((4 * (pb == 4).sum() + 2 * (pb == 2).sum()
                              + (pb == 1).sum()) / pb.size)})
        pbits = np.stack(pb_rows).reshape(s.shape).astype(np.int8)
        new["pbits"] = jnp.asarray(pbits)
        return new

    return smol._tree_map_dicts(transform, params), report


def average_bpp(report) -> float:
    ls = report["layers"]
    return float(np.mean([l["bpp"] for l in ls])) if ls else 0.0
