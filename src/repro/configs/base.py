"""ArchConfig: one dataclass describes every assigned architecture.

`layer_plan()` yields homogeneous scan groups; `reduced()` returns a tiny
same-family config for CPU smoke tests; `param_count()` /
`active_param_count()` feed MODEL_FLOPS = 6*N*D in the roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.qtypes import QuantConfig

Plan = Tuple[Tuple[str, int], ...]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention
    rope_theta: float = 1e4
    window: Optional[int] = None     # sliding-window attention
    mrope_sections: Optional[Tuple[int, int, int]] = None   # qwen2-vl
    attn_bias: bool = False
    mlp_act: str = "swiglu"
    norm: str = "rms"                # rms | ln

    # moe
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0
    dense_d_ff: int = 0              # d_ff of the first dense layers
    moe_every: int = 1               # MoE at layers where i % moe_every == moe_every-1

    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: 1 attn per attn_every layers
    attn_offset: int = 3

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    frontend: Optional[str] = None   # audio_stub | vision_stub
    frontend_dim: int = 0

    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"
    quant: QuantConfig = dataclasses.field(
        default_factory=lambda: QuantConfig(mode="qat"))
    remat: str = "full"              # full | dots | none
    q_block: int = 512               # chunked-attention query block
    source: str = ""                 # provenance note

    # ------------------------------------------------------------ sizes ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def with_quant_mode(self, mode) -> "ArchConfig":
        """Copy with the quant lifecycle phase swapped (a mode string or a
        ``repro.core.phases.Phase`` object)."""
        return dataclasses.replace(self, quant=self.quant.with_mode(mode))

    def layer_plan(self) -> Plan:
        l = self.num_layers
        if self.family == "audio":
            return (("dec", l),)
        if self.family == "ssm":
            return (("mamba", l),)
        if self.family == "hybrid":
            assert l % self.attn_every == 0
            return (("hybrid_unit", l // self.attn_every),)
        if self.num_experts:
            plan = []
            if self.first_dense_layers:
                plan.append(("attn_mlp", self.first_dense_layers))
            plan.append(("attn_moe", l - self.first_dense_layers))
            return tuple(plan)
        return (("attn_mlp", l),)

    def hybrid_unit_kinds(self) -> Tuple[str, ...]:
        """Per-sublayer kinds of one hybrid (Jamba) unit: mixer x ffn."""
        kinds = []
        for i in range(self.attn_every):
            mixer = "attn" if i == self.attn_offset else "mamba"
            ffn = "moe" if (self.num_experts and
                            i % self.moe_every == self.moe_every - 1) else "mlp"
            kinds.append(f"{mixer}_{ffn}")
        return tuple(kinds)

    # ----------------------------------------------------- param counts ----
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        return d * hd * (self.num_heads * 2 + self.num_kv_heads * 2)

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.mlp_act == "swiglu" else 2
        return mult * self.d_model * d_ff

    def _mamba_params(self) -> int:
        d, di, n = self.d_model, self.d_inner, self.ssm_state
        h = di // 64
        return d * (2 * di + 2 * n + h) + di * d + 4 * (di + 2 * n)

    def _moe_params(self) -> int:
        p = self.num_experts * self._mlp_params(self.d_ff) \
            + self.d_model * self.num_experts
        if self.num_shared_experts:
            p += self._mlp_params(self.d_ff * self.num_shared_experts)
        return p

    def _moe_active(self) -> int:
        p = self.top_k * self._mlp_params(self.d_ff) \
            + self.d_model * self.num_experts
        if self.num_shared_experts:
            p += self._mlp_params(self.d_ff * self.num_shared_experts)
        return p

    def _count(self, active: bool) -> int:
        total = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            total *= 2
        enc = self.encoder_layers
        if enc:
            total += enc * (self._attn_params() + self._mlp_params(self.d_ff))
        for kind, n in self.layer_plan():
            if kind == "hybrid_unit":
                for sub in self.hybrid_unit_kinds():
                    mixer, ffn = sub.split("_")
                    per = (self._attn_params() if mixer == "attn"
                           else self._mamba_params())
                    if ffn == "moe":
                        per += self._moe_active() if active else self._moe_params()
                    else:
                        per += self._mlp_params(self.d_ff)
                    total += n * per
                continue
            per = 0
            if "attn" in kind or kind == "dec":
                per += self._attn_params()
                if kind == "dec":
                    per += self._attn_params()      # cross attention
            if "mamba" in kind:
                per += self._mamba_params()
            if "moe" in kind:
                per += self._moe_active() if active else self._moe_params()
            elif "mlp" in kind or kind == "dec":
                dff = self.d_ff
                if kind == "attn_mlp" and self.first_dense_layers:
                    dff = self.dense_d_ff or self.d_ff
                per += self._mlp_params(dff)
            total += n * per
        return total

    def param_count(self) -> int:
        return self._count(active=False)

    def active_param_count(self) -> int:
        return self._count(active=True)

    # ---------------------------------------------------------- reduced ----
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=min(self.num_layers, 4 if self.family != "hybrid"
                           else self.attn_every),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            d_ff=256,
            vocab_size=256,
            head_dim=32,
            window=min(self.window, 64) if self.window else None,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            dense_d_ff=256 if self.dense_d_ff else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=32,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_dim=min(self.frontend_dim, 16) if self.frontend_dim else 0,
            dtype="float32",
            param_dtype="float32",
            q_block=64,
            name=self.name + "-reduced",
        )
        if self.mrope_sections:
            small["mrope_sections"] = (8, 4, 4)     # sums to head_dim/2 = 16
        return dataclasses.replace(self, **small)
