"""Optimizer, schedules, gradient compression, data, checkpointing, FT."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.optim import adamw, grad_compress, schedules
from repro.train import checkpoint as ckpt
from repro.train import ft


# ----------------------------------------------------------------- adamw ----
def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"layer": {"w": jnp.asarray([[5.0, -3.0]]),
                        "pbits": jnp.asarray([4], jnp.int8)}}
    state = adamw.init_state(params)

    def loss(p):
        return jnp.sum(p["layer"]["w"] ** 2)

    for _ in range(120):
        g = jax.grad(loss, allow_int=True)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3
    # integer leaf untouched
    assert params["layer"]["pbits"].dtype == jnp.int8


def test_adamw_s_lr_multiplier():
    cfg = adamw.AdamWConfig(lr=0.01, s_lr_mult=10.0, weight_decay=0.0,
                            clip_norm=1e9)
    params = {"w": jnp.asarray([1.0]), "s": jnp.asarray([1.0])}
    state = adamw.init_state(params)
    g = {"w": jnp.asarray([1.0]), "s": jnp.asarray([1.0])}
    new, _, _ = adamw.apply_updates(params, g, state, cfg)
    dw = float((params["w"] - new["w"])[0])
    ds = float((params["s"] - new["s"])[0])
    assert ds == pytest.approx(10 * dw, rel=1e-3)


def test_grad_clipping():
    cfg = adamw.AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = adamw.init_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw.apply_updates(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_schedules():
    lr = schedules.warmup_cosine(jnp.asarray(0), warmup=10, total=100)
    assert float(lr) == 0.0
    lr_mid = float(schedules.warmup_cosine(jnp.asarray(10), warmup=10,
                                           total=100))
    assert lr_mid == pytest.approx(1.0, rel=1e-3)
    p1 = float(schedules.two_phase(jnp.asarray(50), t1=60, warmup=0,
                                   total=100))
    p2 = float(schedules.two_phase(jnp.asarray(70), t1=60, warmup=0,
                                   total=100))
    assert p2 < p1


# ------------------------------------------------------- grad compression ----
def test_compress_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (256,)).astype(np.float32))
    err = jnp.zeros_like(g)
    # Repeated compression of the same gradient: error feedback should make
    # the RUNNING SUM of decompressed gradients track the true sum.
    total = jnp.zeros_like(g)
    for i in range(20):
        q, scale, err = grad_compress.compress_leaf(g, err)
        total = total + grad_compress.decompress_leaf(q, scale)
    drift = float(jnp.max(jnp.abs(total / 20 - g)))
    assert drift < float(jnp.max(jnp.abs(g))) / 127 + 1e-5


def test_compress_tree_roundtrip():
    params = {"a": jnp.ones((8,)), "n": {"b": jnp.full((4,), -2.0)},
              "i": jnp.asarray([1], jnp.int8)}
    err = grad_compress.init_error_tree(params)
    q, err2 = grad_compress.compress_tree(params, err)
    out = grad_compress.decompress_tree(q)
    np.testing.assert_allclose(np.asarray(out["a"]), 1.0, rtol=0.02)
    np.testing.assert_allclose(np.asarray(out["n"]["b"]), -2.0, rtol=0.02)


# ------------------------------------------------------------------ data ----
def test_token_stream_deterministic_and_sharded():
    cfg = synthetic.TokenStreamConfig(vocab_size=128, seq_len=16,
                                      batch_size=4, seed=3)
    a = next(synthetic.TokenStream(cfg, host_id=0).batches())
    b = next(synthetic.TokenStream(cfg, host_id=0).batches())
    c = next(synthetic.TokenStream(cfg, host_id=1).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert np.any(a["tokens"] != c["tokens"])     # hosts draw disjoint data
    assert a["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_classification_learnable_structure():
    (xtr, ytr), (xte, yte) = synthetic.classification_dataset(
        num_classes=4, dim=(4, 4, 3), n_train=256, n_test=64)
    assert xtr.shape == (256, 4, 4, 3)
    # nearest-prototype on train means must beat chance on test
    protos = np.stack([xtr[ytr == c].mean(0).ravel() for c in range(4)])
    pred = np.argmin(((xte.reshape(64, -1)[:, None] - protos[None]) ** 2)
                     .sum(-1), axis=1)
    assert (pred == yte).mean() > 0.4


# ------------------------------------------------------------ checkpoint ----
def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"mu": {"w": jnp.ones((2, 3))}, "nu": {"w": None},
                     "count": jnp.asarray(5, jnp.int32)},
             "step": jnp.asarray(5, jnp.int32)}
    for s in (1, 2, 3, 4):
        ckpt.save(state, d, s, keep=2)
    assert ckpt.latest_step(d) == 4
    restored, step = ckpt.restore(d, state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert restored["opt"]["nu"]["w"] is None
    # GC keeps only 2
    kept = [p for p in os.listdir(d) if p.startswith("step_")]
    assert len(kept) == 2


def test_checkpoint_async(tmp_path):
    d = str(tmp_path / "ckpt")
    state = {"w": jnp.ones((4,))}
    t = ckpt.async_save(state, d, 7)
    t.join()
    restored, step = ckpt.restore(d, state)
    assert step == 7


# -------------------------------------------------------------------- ft ----
def test_heartbeat_failure_detection():
    hb = ft.HeartbeatMonitor([0, 1, 2], timeout=10.0)
    now = 1000.0
    for h in (0, 1, 2):
        hb.beat(h, now)
    hb.beat(0, now + 20)
    hb.beat(1, now + 20)
    assert hb.failed_hosts(now + 21) == [2]
    assert hb.surviving(now + 21) == [0, 1]


def test_straggler_detection():
    sm = ft.StragglerMonitor([0, 1, 2, 3], ratio=1.5, patience=3)
    for step in range(6):
        for h in (0, 1, 2):
            sm.record(h, 1.0)
        sm.record(3, 3.0)
        out = sm.stragglers()
    assert out == [3]


def test_plan_remesh_preserves_tp():
    data, model = ft.plan_remesh(survivors=60, model=16, chips_per_host=4)
    assert model == 16
    assert data * model <= 60 * 4
    assert data & (data - 1) == 0        # power of two
    mb = ft.rescale_microbatches(256, old_data=16, new_data=8, old_mb=1)
    assert mb == 2                       # global batch preserved
