"""Sharded, atomic, resumable checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
             manifest.json       — step, leaf paths, shapes/dtypes, mesh info
             shard_<host>.npz    — this host's addressable array shards
         <dir>/LATEST            — atomically-updated pointer

Writes go to a temp dir then os.replace (atomic on POSIX), so a crash
mid-save never corrupts the restore target. Saves can run on a background
thread (async_save) — the arrays are snapshotted with jax.device_get first.
Restore reshards to whatever mesh the restoring process runs (elastic
re-mesh: a surviving-host subset reloads the same checkpoint under a new
mesh; GSPMD places shards per the new specs).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(state, ckpt_dir: str, step: int, *, host_id: int = 0,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
              if v is not None}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "none_leaves": [k for k, v in flat.items() if v is None],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)

    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def async_save(state, ckpt_dir: str, step: int, **kw) -> threading.Thread:
    """Snapshot to host memory now; write on a background thread."""
    snap = jax.tree.map(lambda x: None if x is None else
                        np.asarray(jax.device_get(x)), state,
                        is_leaf=lambda x: x is None)
    t = threading.Thread(target=save, args=(snap, ckpt_dir, step), kwargs=kw,
                         daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, template, *, step: Optional[int] = None,
            host_id: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (values replaced; device
    placement/sharding follows whatever jit consumes them under)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, f"shard_{host_id}.npz")) as z:
        arrays = {k: z[k] for k in z.files}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: x is None)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if leaf is None:
            new_leaves.append(None)
        else:
            arr = arrays[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape)
            new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp0"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
