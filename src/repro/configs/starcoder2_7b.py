"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA kv=4, RoPE, biases,
GELU MLP (non-gated), learned... we follow the brief: 32L d_model=4608 36H
(GQA kv=4) d_ff=18432 vocab=49152."""
from .base import ArchConfig
from .registry import register


@register("starcoder2-7b")
def starcoder2_7b() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-7b", family="dense",
        num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
        d_ff=18432, vocab_size=49152, head_dim=128,
        rope_theta=1e5, attn_bias=True, mlp_act="gelu",
        tie_embeddings=False,
        source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
    )
