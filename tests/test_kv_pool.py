"""Paged KV-cache subsystem (DESIGN.md §13): the block-pool allocator's
invariants, the paged device cache + ``qkv_attn_decode_paged`` backend op,
engine token parity against the ring layout, admission behaviour under
page pressure, and the ``SONIQ_KV_POISON`` use-after-free trip wire."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import pallas as pallas_backend
from repro.backend import registry
from repro.configs.base import ArchConfig
from repro.core.qtypes import QuantConfig
from repro.models import lm
from repro.serve import engine, kv_pool, kv_quant
from repro.serve.scheduler import Request


# ============================================== host allocator (jax-free) =
def test_pool_alloc_wipe_release_roundtrip():
    pool = kv_pool.PagePool(5, 4, 4, 2, poison=False)
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 8, ops)               # two fresh pages
    assert sorted(ops.wipes) == [1, 2] and not ops.copies
    assert pool.table[0, :2].tolist() == [1, 2]
    assert pool.resident_pages == 2
    pool.check()
    pool.release(0, ops)
    assert (pool.table[0] == -1).all()
    assert pool.resident_pages == 0 and sorted(pool.free) == [1, 2, 3, 4]
    pool.check()


def test_pool_cow_on_shared_and_registered_pages():
    """Writing into a page another slot maps (or a registered prefix page)
    must allocate a private copy, never mutate in place."""
    pool = kv_pool.PagePool(6, 4, 4, 2, poison=False)
    ops = kv_pool.StepOps()
    prompt = np.arange(8, dtype=np.int32)
    pool.admit(0, Request(prompt=prompt, max_new_tokens=4, request_id=0))
    pool.prepare(0, 0, 8, ops)
    pool.note_filled(0, prompt, 8)           # pages 1, 2 now registered
    first = int(pool.table[0, 0])
    assert first in pool.page_hash
    ops = kv_pool.StepOps()
    pool.prepare(0, 8, 1, ops)               # decode rolls into page 3
    assert int(pool.table[0, 2]) not in (first, -1)
    # Rolling over INTO a registered page copies it out of the map's reach.
    ops = kv_pool.StepOps()
    pool.prepare(0, 16, 1, ops)              # wraps to logical page 0
    new = int(pool.table[0, 0])
    assert new != first and (first, new) in ops.copies
    assert new not in pool.page_hash         # the copy is private
    assert first in pool.cached              # canonical page parked in LRU
    pool.check()


def test_pool_prefix_sharing_and_lru_revival():
    pool = kv_pool.PagePool(8, 4, 4, 4, poison=False)
    prompt = np.arange(9, dtype=np.int32)    # 2 full pages + 1 token
    ops = kv_pool.StepOps()
    pool.note_submit(0, prompt)
    pool.admit(0, Request(prompt=prompt, max_new_tokens=2, request_id=0))
    pool.prepare(0, 0, 9, ops)
    pool.note_filled(0, prompt, 9)
    # Second request with the same prompt: both full pages hit.
    pool.note_submit(1, prompt)
    shared = pool.admit(1, Request(prompt=prompt, max_new_tokens=2,
                                   request_id=1))
    assert shared == 8 and pool.hits == 2
    p0 = int(pool.table[0, 0])
    assert int(pool.table[1, 0]) == p0 and pool.refcount[p0] == 2
    pool.check()
    # Both slots release: registered pages park in the LRU, not the free
    # list, and a third admission revives them.
    ops = kv_pool.StepOps()
    pool.release(0, ops)
    pool.release(1, ops)
    assert p0 in pool.cached and p0 not in pool.free
    shared = pool.admit(2, Request(prompt=prompt, max_new_tokens=2,
                                   request_id=2))
    assert shared == 8 and p0 not in pool.cached
    pool.check()


def test_pool_exhaustion_raises_not_corrupts():
    pool = kv_pool.PagePool(3, 4, 4, 2, poison=False)
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 8, ops)               # takes both usable pages
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.prepare(1, 0, 4, ops)
    pool.check()


def test_pool_poison_ops_and_realloc_cancellation():
    """Freed pages are queued for poisoning; a page freed and reallocated
    within the same StepOps batch must NOT stay queued (the engine applies
    poisons after wipes — a stale poison would corrupt the new page)."""
    pool = kv_pool.PagePool(4, 4, 4, 2, poison=True)
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 4, ops)
    pool.release(0, ops)
    assert ops.poisons == [int(pool.free[-1])]
    pid = ops.poisons[0]
    pool.prepare(1, 0, 4, ops)               # reallocates the same page
    assert int(pool.table[1, 0]) == pid
    assert pid not in ops.poisons and pid in ops.wipes
    pool.check()


# A deterministic allocator fuzz driver shared by the always-on seeded
# test and the hypothesis property test: random interleavings of
# admission (some with shared prompts), prefill/decode prepares,
# speculative rollbacks (a prepared suffix un-commits, DESIGN.md §14),
# engine-style cancels (note_filled + release mid-flight) and releases,
# with pool.check() asserting the partition/refcount invariants after
# every operation.
def _run_pool_program(seed, num_pages, page_size, pages_per_seq,
                      max_batch, n_ops):
    rng = np.random.default_rng(seed)
    pool = kv_pool.PagePool(num_pages, page_size, pages_per_seq,
                            max_batch, poison=bool(seed % 2))
    prompts = [rng.integers(0, 50, (int(l),)).astype(np.int32)
               for l in rng.integers(1, pages_per_seq * page_size + 1,
                                     (4,))]
    active = {}                              # slot -> (prompt, n_fed)
    rid = 0
    for _ in range(n_ops):
        ops = kv_pool.StepOps()
        kind = rng.choice(["admit", "feed", "release", "cancel"])
        if kind == "admit" and len(active) < max_batch:
            slot = next(s for s in range(max_batch) if s not in active)
            prompt = prompts[int(rng.integers(0, len(prompts)))]
            req = Request(prompt=prompt, max_new_tokens=4, request_id=rid)
            if not pool.admissible(req):
                continue
            pool.note_submit(rid, prompt)
            shared = pool.admit(slot, req)
            active[slot] = [prompt, shared]
            rid += 1
        elif kind == "feed" and active:
            slot = int(rng.choice(sorted(active)))
            prompt, n_fed = active[slot]
            width = int(rng.integers(1, page_size + 2))
            try:
                pool.prepare(slot, n_fed, width, ops)
            except RuntimeError:
                pool.check()                 # exhaustion must not corrupt
                continue
            # At allocation time (before the step registers anything),
            # shared (refcount > 1) and registered pages must never be
            # handed out as in-place write targets (shared definition
            # with PagePool.check() and the model checker).
            assert kv_pool.step_ops_violations(pool, ops) == []
            fed = n_fed + width
            if fed <= pages_per_seq * page_size and rng.random() < 0.4:
                # Speculative rollback: the verify pass rejected a random
                # suffix of this round's writes (no-wrap rounds only —
                # the engine's spec guard, DESIGN.md §14).
                committed = int(rng.integers(n_fed, fed + 1))
                rops = kv_pool.StepOps()
                pool.rollback(slot, committed, fed, rops)
                pool.check()
                fed = committed
            active[slot][1] = fed
            pool.note_filled(slot, prompt, fed)
        elif kind == "cancel" and active:
            # The engine's cancel path: finished prompt pages register,
            # then every page reference drops (DecodeEngine.cancel).
            slot = int(rng.choice(sorted(active)))
            prompt, n_fed = active[slot]
            pool.note_filled(slot, prompt, n_fed)
            pool.release(slot, ops)
            del active[slot]
        elif kind == "release" and active:
            slot = int(rng.choice(sorted(active)))
            pool.release(slot, ops)
            del active[slot]
        pool.check()


def test_pool_wrap_never_registers_overwritten_pages():
    """Once decode growth wraps the logical ring, the early pages hold
    wrap content, not prompt content — ``note_filled`` must not register
    them under the prompt's page hashes (a poisoned prefix map would feed
    later requests garbage)."""
    pool = kv_pool.PagePool(8, 4, 2, 2, poison=False)  # 2 logical pages
    prompt = np.arange(8, dtype=np.int32)    # exactly fills the ring
    pool.admit(0, Request(prompt=prompt, max_new_tokens=8, request_id=0))
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 8, ops)
    # Decode token 8 wraps into logical page 0 BEFORE any registration:
    # the private page is legally rewritten in place.
    pool.prepare(0, 8, 1, ops)
    pool.note_filled(0, prompt, 9)
    h = pool.page_hashes(prompt)
    assert h[0] not in pool.prefix_map       # overwritten: must not enter
    assert h[1] in pool.prefix_map           # untouched: registers fine
    pool.check()


def test_pool_wrap_into_registered_page_at_full_residency():
    """Regression: COW into a registered page that is ours alone
    (refcount 1), with no free or cached page anywhere — the state a
    full-residency slot's decode wrap reaches under the default pool
    sizing — must unregister the canonical and write in place (the ring
    layout wraps the same page), not raise pool exhaustion."""
    pool = kv_pool.PagePool(3, 4, 2, 1, poison=False)   # capacity 2
    prompt = np.arange(8, dtype=np.int32)
    pool.admit(0, Request(prompt=prompt, max_new_tokens=4, request_id=0))
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 8, ops)               # both pages mapped
    pool.note_filled(0, prompt, 8)           # both registered
    first = int(pool.table[0, 0])
    ops = kv_pool.StepOps()
    pool.prepare(0, 8, 1, ops)               # decode wraps into page 0
    assert int(pool.table[0, 0]) == first    # wrote in place
    assert not ops.copies and not ops.wipes
    assert first not in pool.page_hash       # canonical unregistered
    assert pool.page_hashes(prompt)[0] not in pool.prefix_map
    assert pool.page_hashes(prompt)[1] in pool.prefix_map
    pool.check()


def test_pool_same_step_admission_reserves_capacity():
    """Regression: an ``admissible()`` pass that returns True must
    reserve the request's page demand — Scheduler.admit() checks every
    head-of-queue request before any pool.admit() runs, so a second
    same-step check that cannot see the first's demand overcommits a
    tight pool (prefill then dies with pool exhaustion)."""
    pool = kv_pool.PagePool(5, 4, 4, 2, poison=False)   # capacity 4
    r0 = Request(prompt=np.arange(12, dtype=np.int32), max_new_tokens=2,
                 request_id=0)                           # 3 pages
    r1 = Request(prompt=np.arange(50, 58, dtype=np.int32),
                 max_new_tokens=2, request_id=1)         # 2 pages
    assert pool.admissible(r0)
    assert not pool.admissible(r1)           # 3 + 2 > 4: must wait
    pool.admit(0, r0)
    assert not pool.admissible(r1)           # demand now tracked via slot
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 12, ops)
    assert not pool.admissible(r1)           # 3 mapped + 2 > 4
    pool.release(0, ops)
    assert pool.admissible(r1)               # capacity freed up
    pool.check()


def test_admissible_own_prefix_pages_not_double_counted():
    """Regression (the spec-PR lifecycle bug): ``admissible()`` counted a
    request's own revivable cached-LRU prefix pages twice — once as
    shareable (subtracted from the demand) and once as evictable (added
    to the supply). On the repro state — free list empty, cached LRU
    holding exactly the request's prefix pages — the double count admits
    the request and its first fresh allocation dies with the mid-step
    pool-exhausted RuntimeError."""
    def fill_and_park(pool):
        p1 = np.arange(8, dtype=np.int32)            # 2 full pages
        r1 = Request(prompt=p1, max_new_tokens=2, request_id=0)
        pool.note_submit(0, p1)
        assert pool.admissible(r1)
        pool.admit(0, r1)
        ops = kv_pool.StepOps()
        pool.prepare(0, 0, 8, ops)
        pool.note_filled(0, p1, 8)
        pool.release(0, ops)                          # both pages park
        return Request(prompt=np.arange(12, dtype=np.int32),
                       max_new_tokens=2, request_id=1)

    # Repro sizing: capacity 2, so after the fill free=[] and cached =
    # exactly the 12-token extension's 2 prefix-hit pages. It still
    # needs 1 fresh page -> must NOT be admissible (revived pages are
    # not evictable), where the double count said 0 + 2 >= 1.
    pool = kv_pool.PagePool(3, 4, 3, 1, poison=False)
    r2 = fill_and_park(pool)
    assert not pool.free and len(pool.cached) == 2
    pool.note_submit(1, r2.prompt)
    assert not pool.admissible(r2)
    pool.forget_submit(1)
    pool.check()

    # Control: one genuinely free page makes the same request admissible
    # and the fresh allocation succeeds.
    pool = kv_pool.PagePool(4, 4, 3, 1, poison=False)
    r2 = fill_and_park(pool)
    assert len(pool.free) == 1
    pool.note_submit(1, r2.prompt)
    assert pool.admissible(r2)
    assert pool.admit(1 - 1, r2) == 8                 # both prefix pages hit
    ops = kv_pool.StepOps()
    pool.prepare(0, 8, 4, ops)                        # the fresh page
    pool.check()


def test_pool_rollback_frees_wholly_stale_pages_keeps_boundary():
    """Speculative rollback (DESIGN.md §14): after a draft round writes
    positions [committed, touched), every logical page WHOLLY beyond the
    committed content unmaps and frees; the partially-committed boundary
    page stays (its stale tail carries future pos stamps the causal mask
    excludes)."""
    pool = kv_pool.PagePool(6, 4, 4, 1, poison=False)
    prompt = np.arange(6, dtype=np.int32)
    pool.admit(0, Request(prompt=prompt, max_new_tokens=8, request_id=0))
    ops = kv_pool.StepOps()
    pool.prepare(0, 0, 6, ops)                        # pages 0, 1 mapped
    pool.note_filled(0, prompt, 6)
    ops = kv_pool.StepOps()
    pool.prepare(0, 6, 4, ops)                        # round: pos 6..9
    boundary = int(pool.table[0, 1])
    fresh = int(pool.table[0, 2])
    assert fresh >= 0
    free_before = len(pool.free)
    ops = kv_pool.StepOps()
    pool.rollback(0, 7, 10, ops)   # verify committed only pos 6 (+bonus)
    assert int(pool.table[0, 2]) == -1                # wholly stale: freed
    assert int(pool.table[0, 1]) == boundary          # boundary stays
    assert len(pool.free) == free_before + 1 and fresh in pool.free
    pool.check()
    # committed == touched is a no-op; a wrapped round is rejected (the
    # engine's draft guard makes it unreachable).
    table_before = pool.table.copy()
    pool.rollback(0, 7, 7, ops)
    np.testing.assert_array_equal(pool.table, table_before)
    with pytest.raises(AssertionError):
        pool.rollback(0, 4, 17, ops)                  # 17 > 4 * 4: wrap


@pytest.mark.parametrize("seed", range(8))
def test_pool_random_program_invariants(seed):
    _run_pool_program(seed, num_pages=int(5 + seed), page_size=4,
                      pages_per_seq=4, max_batch=3, n_ops=40)


# ===================================================== paged device cache =
def _toy_paged(kv_bits, num_pages=6, ps=4, npg=4, b=2, hk=2, d=8):
    cache = kv_pool.init_paged_cache(num_pages, ps, npg, b, hk, d,
                                     kv_bits=kv_bits, dtype=jnp.float32)
    # slot 0 -> pages 1, 2; slot 1 -> page 3 (allocator-style mapping)
    table = np.full((b, npg), -1, np.int32)
    table[0, :2] = [1, 2]
    table[1, 0] = 3
    cache["page_table"] = jnp.asarray(table)
    return cache


@pytest.mark.parametrize("kv_bits", [None, 4])
def test_paged_write_gather_roundtrip(kv_bits):
    """Tokens written through the page table must come back from
    ``gather_paged`` at ring position pos with everything else empty;
    masked lanes (pos < 0) and unmapped logical pages must drop."""
    cache = _toy_paged(kv_bits)
    kv = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 2, 8))
    pos = jnp.asarray([[2, 3, 4], [0, -1, 1]], jnp.int32)
    cache = kv_pool.update_paged_cache(cache, kv, -kv, pos)
    k, v, kpos = kv_pool.gather_paged(cache)
    want0 = np.full((16,), -1); want0[[2, 3, 4]] = [2, 3, 4]
    want1 = np.full((16,), -1); want1[[0, 1]] = [0, 1]
    np.testing.assert_array_equal(np.asarray(kpos),
                                  np.stack([want0, want1]))
    tol = dict(rtol=0, atol=0) if kv_bits is None else \
        dict(rtol=0.2, atol=0.1)
    np.testing.assert_allclose(np.asarray(k[0, 2]), np.asarray(kv[0, 0]),
                               **tol)
    np.testing.assert_allclose(np.asarray(v[1, 1]), np.asarray(-kv[1, 2]),
                               **tol)
    assert np.asarray(k[0, 5:]).sum() == 0   # beyond writes: empty
    # The masked lane of row 1 never landed anywhere.
    assert np.asarray(kpos[1]).tolist().count(1) == 1


def test_paged_write_never_touches_unmapped_pool_pages():
    """A write to a position whose logical page is unmapped (table -1)
    must drop — not land on the null page or any pool page."""
    cache = _toy_paged(4)
    before = {n: np.asarray(v) for n, v in cache.items()}
    kv = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 2, 8))
    pos = jnp.asarray([[9], [5]], jnp.int32)  # logical pages 2, 1: unmapped
    cache = kv_pool.update_paged_cache(cache, kv, -kv, pos)
    for name in cache:
        np.testing.assert_array_equal(np.asarray(cache[name]),
                                      before[name], err_msg=name)


def test_apply_step_ops_copy_then_wipe_and_stacked_table():
    """COW copies carry payload + pos; wipes clear payload and stamp pos
    -1; stacked [L, ...] caches broadcast one table across layers."""
    cache = _toy_paged(4)
    kv = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 2, 8))
    cache = kv_pool.update_paged_cache(
        cache, kv, -kv, jnp.asarray([[0, 1, 2, 3], [0, 1, 2, 3]],
                                    jnp.int32))
    stacked = {n: (v if n == "page_table"
                   else jnp.stack([v, v])) for n, v in cache.items()}
    stacked["page_table"] = jnp.stack([cache["page_table"]] * 2)
    table = np.array(cache["page_table"])
    table[0, 0] = 4                          # remap after COW 1 -> 4
    out = kv_pool.apply_step_ops(stacked, table, np.asarray([2], np.int32),
                                 np.asarray([1], np.int32),
                                 np.asarray([4], np.int32))
    for l in range(2):
        np.testing.assert_array_equal(np.asarray(out["page_table"][l]),
                                      table)
        np.testing.assert_array_equal(np.asarray(out["k_codes"][l, 4]),
                                      np.asarray(cache["k_codes"][1]))
        np.testing.assert_array_equal(np.asarray(out["pos"][l, 4]),
                                      np.asarray(cache["pos"][1]))
        assert (np.asarray(out["pos"][l, 2]) == -1).all()
        assert (np.asarray(out["k_codes"][l, 2]) == 0).all()


def test_poisoned_page_keeps_pos_and_nans_payload():
    """``apply_poison`` must keep the pos stamps (so a stale table
    reference passes the mask) while NaN-ing scales / 0xFF-ing codes —
    and attention through the stale table must go NaN, which is the
    whole point of SONIQ_KV_POISON=1."""
    cache = _toy_paged(4)
    kv = jax.random.normal(jax.random.PRNGKey(3), (2, 4, 2, 8))
    cache = kv_pool.update_paged_cache(
        cache, kv, -kv, jnp.asarray([[0, 1, 2, 3], [0, 1, 2, 3]],
                                    jnp.int32))
    poisoned = kv_pool.apply_poison(cache, np.asarray([1], np.int32))
    np.testing.assert_array_equal(np.asarray(poisoned["pos"][1]),
                                  np.asarray(cache["pos"][1]))
    assert (np.asarray(poisoned["k_codes"][1]) == 0xFF).all()
    assert np.isnan(np.asarray(poisoned["k_scale"][1],
                               np.float32)).all()
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 1, 2, 2, 8))
    q_pos = jnp.asarray([[3], [3]], jnp.int32)
    out = registry.get("xla_ref").qkv_attn_decode_paged(q, poisoned, q_pos)
    assert np.isnan(np.asarray(out[0])).any()   # slot 0 read page 1: trip
    assert np.isfinite(np.asarray(out[1])).all()  # slot 1 untouched


# ==================================================== paged backend op ====
def _filled_paged(kv_bits, seed=0):
    cache = _toy_paged(kv_bits, num_pages=7, ps=4, npg=4)
    table = np.full((2, 4), -1, np.int32)
    table[0] = [1, 2, 3, 4]                  # full logical ring
    table[1, :2] = [5, 6]
    cache["page_table"] = jnp.asarray(table)
    key = jax.random.PRNGKey(seed)
    for t in range(14):
        kv = jax.random.normal(jax.random.fold_in(key, t), (2, 1, 2, 8))
        pos = jnp.asarray([t, t if t < 7 else -1], jnp.int32)
        cache = kv_pool.update_paged_cache(cache, kv, -kv, pos)
    q = jax.random.normal(jax.random.fold_in(key, 99), (2, 3, 2, 2, 8))
    q_pos = jnp.asarray([[12, -1, 13], [5, 6, -1]], jnp.int32)
    return cache, q, q_pos


@pytest.mark.parametrize("kv_bits", [None, 4])
@pytest.mark.parametrize("window", [None, 6])
def test_paged_op_backend_parity(kv_bits, window):
    """xla_ref (gather_paged + dense oracle) and pallas_interpret (the
    online-softmax paged kernel) must agree to fp32 tolerance, wrapped
    rings / masked lanes / windows included — and the q4 leg must
    actually dispatch the kernel (trace-time counter)."""
    cache, q, q_pos = _filled_paged(kv_bits)
    ref = registry.get("xla_ref").qkv_attn_decode_paged(q, cache, q_pos,
                                                        window=window)
    before = pallas_backend.qkv_attn_paged_call_count()
    got = registry.get("pallas_interpret").qkv_attn_decode_paged(
        q, cache, q_pos, window=window)
    dispatched = pallas_backend.qkv_attn_paged_call_count() - before
    assert dispatched == (1 if kv_bits == 4 else 0)  # fp falls back
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert np.isfinite(np.asarray(got)).all()


def test_paged_oracle_matches_ring_oracle_on_same_content():
    """Acceptance cross-check: identical K/V content read through the
    paged table and through the ring cache must attend identically (the
    layouts are bit-compatible per token)."""
    hk, d, t = 2, 8, 8
    key = jax.random.PRNGKey(7)
    kv = jax.random.normal(key, (1, t, hk, d))
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    ring = kv_quant.update_qkv_cache(
        kv_quant.init_qkv_cache(1, t, hk, d), kv, -kv, pos)
    paged = kv_pool.init_paged_cache(3, 4, 2, 1, hk, d, kv_bits=4)
    paged["page_table"] = jnp.asarray([[1, 2]], jnp.int32)
    paged = kv_pool.update_paged_cache(paged, kv, -kv, pos)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, hk, 2, d))
    q_pos = jnp.asarray([[t - 1]], jnp.int32)
    ref = registry.get("xla_ref")
    np.testing.assert_array_equal(
        np.asarray(ref.qkv_attn_decode(q, ring, q_pos)),
        np.asarray(ref.qkv_attn_decode_paged(q, paged, q_pos)))


def test_paged_op_supports_probe():
    assert registry.get("pallas_interpret").supports(
        "qkv_attn_decode_paged")
    assert not registry.get("xla_ref").supports("qkv_attn_decode_paged")


# ======================================================= engine parity ====
def _tiny_cfg():
    return ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
        num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=32,
        dtype="float32", param_dtype="float32", q_block=32,
        quant=QuantConfig(mode="qat"))


@pytest.fixture(scope="module")
def served():
    cfg = _tiny_cfg()
    params = jax.device_get(lm.init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _ecfg(**kw):
    base = dict(max_batch=2, cache_len=32, prefill_chunk=4)
    base.update(kw)
    return engine.EngineConfig(**base)


def _mixed_requests(rng, lens=(3, 9, 5, 2), news=(4, 7, 3, 6)):
    return [Request(prompt=rng.integers(1, 100, (l,)), max_new_tokens=n,
                    seed=i) for i, (l, n) in enumerate(zip(lens, news))]


@pytest.mark.parametrize("kv_bits", [None, 4])
def test_paged_engine_token_parity(served, kv_bits):
    """THE acceptance pin: the paged DecodeEngine's greedy tokens are
    identical to the ring DecodeEngine's AND the LockstepEngine's on the
    same packed checkpoint, at q4 and fp alike."""
    cfg, params = served
    prompts = np.random.default_rng(3).integers(
        1, 100, (3, 7)).astype(np.int32)
    ring = engine.DecodeEngine(params, cfg, _ecfg(kv_bits=kv_bits))
    paged = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=kv_bits, kv_layout="paged", page_size=4))
    lock = engine.LockstepEngine(params, cfg, _ecfg(kv_bits=kv_bits))
    out_p = paged.generate(prompts, 6)
    np.testing.assert_array_equal(out_p, ring.generate(prompts, 6))
    np.testing.assert_array_equal(out_p, lock.generate(prompts, 6))
    paged.pool.check()


def test_paged_cross_backend_token_identity_and_dispatch(served):
    """xla_ref and pallas_interpret agree token-for-token through the
    paged engine at q4, and the Pallas leg served every layer through the
    paged kernel — not the fallback (trace-time counter: one dispatch per
    stacked scan body per compiled step shape)."""
    cfg, params = served
    outs = {}
    for name in ("xla_ref", "pallas_interpret"):
        eng = engine.DecodeEngine(params, cfg, _ecfg(
            backend=name, kv_bits=4, kv_layout="paged", page_size=4))
        before = pallas_backend.qkv_attn_paged_call_count()
        got = {c.request_id: c.tokens
               for c in eng.serve(_mixed_requests(np.random.default_rng(1)))}
        outs[name] = {k - min(got): v for k, v in got.items()}
        dispatched = pallas_backend.qkv_attn_paged_call_count() - before
        assert dispatched == (0 if name == "xla_ref" else 2), dispatched
        eng.pool.check()
    assert set(outs["xla_ref"]) == set(outs["pallas_interpret"])
    for k in outs["xla_ref"]:
        np.testing.assert_array_equal(outs["xla_ref"][k],
                                      outs["pallas_interpret"][k])


def test_paged_engine_prefix_sharing_and_occupancy(served):
    """Shared-system-prompt traffic: the prefix map must actually hit, the
    tokens must stay parity with the ring engine, and peak resident
    payload bytes must stay <= 0.5x the ring layout's reserved bytes (the
    occupancy win: the ring pays for configured capacity up front, the
    pool pays per token actually cached)."""
    cfg, params = served
    rng = np.random.default_rng(5)
    system = rng.integers(1, 100, (9,)).astype(np.int32)
    reqs = [Request(prompt=np.concatenate(
                [system, rng.integers(1, 100, (2 + i,)).astype(np.int32)]),
            max_new_tokens=4 + i, seed=i) for i in range(4)]

    def run(ecfg):
        eng = engine.DecodeEngine(params, cfg, ecfg)
        outs = {c.request_id: c.tokens for c in eng.serve(
            [dataclasses.replace(r) for r in reqs])}
        return eng, {k - min(outs): v for k, v in outs.items()}

    ring_eng, ring_out = run(_ecfg(kv_bits=4, cache_len=64))
    paged_eng, paged_out = run(_ecfg(kv_bits=4, cache_len=64,
                                     kv_layout="paged", page_size=4))
    for k in ring_out:
        np.testing.assert_array_equal(ring_out[k], paged_out[k])
    paged_eng.pool.check()
    stats = paged_eng.paged_kv_stats()
    assert stats["prefix_hits"] > 0
    ring_reserved = kv_quant.cache_payload_bytes(ring_eng.cache)
    assert stats["reserved_payload_bytes"] == ring_reserved
    assert stats["peak_resident_payload_bytes"] <= 0.5 * ring_reserved, \
        stats


def test_paged_submit_rejects_impossible_prompt(served):
    """Satellite regression: a prompt whose page demand can never fit the
    pool raises at submit() — it must not sit in the queue deadlocking
    admission forever."""
    cfg, params = served
    eng = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=4, kv_layout="paged", page_size=4, num_pages=5))
    with pytest.raises(ValueError, match="KV pages"):
        eng.submit(Request(prompt=np.arange(1, 40, dtype=np.int32),
                           max_new_tokens=2))
    # An admissible request still flows end to end afterwards.
    outs = list(eng.serve([Request(prompt=np.asarray([1, 2, 3], np.int32),
                                   max_new_tokens=3)]))
    assert len(outs) == 1 and outs[0].new_tokens.size == 3


def test_paged_page_pressure_queues_without_deadlock(served):
    """A pool too small for full concurrency must gate admission (requests
    wait for pages) and still drain with ring-identical tokens."""
    cfg, params = served
    reqs = _mixed_requests(np.random.default_rng(2))
    ring = engine.DecodeEngine(params, cfg, _ecfg(kv_bits=4))
    want = {c.request_id: c.tokens for c in ring.serve(
        [dataclasses.replace(r) for r in reqs])}
    tight = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=4, kv_layout="paged", page_size=4, num_pages=11))
    got = {c.request_id: c.tokens for c in tight.serve(
        [dataclasses.replace(r) for r in reqs])}
    assert len(got) == len(want)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    tight.pool.check()


def test_paged_decode_wrap_at_full_residency(served):
    """Regression: a single long-running request on a max_batch=1 engine
    with the default pool sizing must wrap its logical ring in place
    (unregistering the canonical prompt page) with ring-identical
    tokens — not crash prefill/decode with pool exhaustion — and the
    completion-path note_filled must see the TRUE fed count, so the
    wrap-overwritten page never re-registers as prompt content."""
    cfg, params = served
    prompt = np.arange(1, 7, dtype=np.int32)
    ring = engine.DecodeEngine(params, cfg, _ecfg(
        max_batch=1, cache_len=8, kv_bits=4))
    want = ring.generate(prompt[None], 8)[0]
    paged = engine.DecodeEngine(params, cfg, _ecfg(
        max_batch=1, cache_len=8, kv_bits=4, kv_layout="paged",
        page_size=4))
    first = list(paged.serve([Request(prompt=prompt, max_new_tokens=8)]))
    np.testing.assert_array_equal(first[0].tokens, want)
    paged.pool.check()
    # Page 0 was wrapped through by decode growth: it must have left the
    # prefix map (in-place fallback) and must NOT have been re-registered
    # at completion (the n_fed=len(prompt) bug registered decode garbage
    # under the prompt's hash there).
    assert paged.pool.page_hashes(prompt)[0] not in paged.pool.prefix_map
    # A repeat of the same prompt re-prefills instead of mapping a stale
    # page, so its tokens stay ring-identical too.
    second = list(paged.serve([Request(prompt=prompt, max_new_tokens=8)]))
    np.testing.assert_array_equal(second[0].tokens, want)
    paged.pool.check()


def test_paged_same_step_admission_does_not_overcommit(served):
    """Regression: two prompts whose joint page demand exceeds a tight
    pool must not be co-admitted in one step — the second waits for
    pages (head-of-line) and both finish with ring-identical tokens."""
    cfg, params = served
    rng = np.random.default_rng(6)
    reqs = [Request(prompt=rng.integers(1, 100, (12,)).astype(np.int32),
                    max_new_tokens=4, seed=i) for i in range(2)]
    ring = engine.DecodeEngine(params, cfg, _ecfg(kv_bits=4))
    want = {c.request_id: c.tokens for c in ring.serve(
        [dataclasses.replace(r) for r in reqs])}
    tight = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=4, kv_layout="paged", page_size=4, num_pages=5))
    got = {c.request_id: c.tokens for c in tight.serve(
        [dataclasses.replace(r) for r in reqs])}
    assert len(got) == len(want)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])
    tight.pool.check()


def test_paged_poison_mode_is_parity_preserving(served):
    """SONIQ_KV_POISON=1 must not change tokens for correct code — freed
    pages are poisoned but allocation wipes before reuse."""
    cfg, params = served
    prompts = np.random.default_rng(4).integers(
        1, 100, (3, 5)).astype(np.int32)
    plain = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=4, kv_layout="paged", page_size=4))
    out = plain.generate(prompts, 5)
    poisoned = engine.DecodeEngine(params, cfg, _ecfg(
        kv_bits=4, kv_layout="paged", page_size=4))
    poisoned.pool.poison = True
    np.testing.assert_array_equal(out, poisoned.generate(prompts, 5))
    poisoned.pool.check()


def test_pool_poison_env_knob(monkeypatch):
    monkeypatch.setenv(kv_pool.POISON_ENV, "1")
    assert kv_pool.PagePool(4, 4, 4, 1).poison
    monkeypatch.setenv(kv_pool.POISON_ENV, "0")
    assert not kv_pool.PagePool(4, 4, 4, 1).poison


def test_paged_geometry_validation(served):
    cfg, params = served
    with pytest.raises(ValueError, match="page_size"):
        engine.DecodeEngine(params, cfg, _ecfg(
            kv_layout="paged", page_size=5))
    with pytest.raises(ValueError, match="kv_layout"):
        engine.DecodeEngine(params, cfg, _ecfg(kv_layout="blocked"))
    with pytest.raises(ValueError, match="ring"):
        engine.LockstepEngine(params, cfg, _ecfg(
            kv_layout="paged", page_size=4)).generate(
                np.ones((1, 3), np.int32), 2)


# --------------------------------------------- hypothesis properties ----
# Guarded import (not a module-level importorskip, which would skip every
# test above too): CI installs hypothesis and fails fast if the property
# tests would silently vanish from the run.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                          # pragma: no cover
    HAVE_HYPOTHESIS = False


if not HAVE_HYPOTHESIS:
    def test_property_tests_require_hypothesis():
        pytest.skip("hypothesis not installed — property tests skipped")
else:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 16), st.integers(3, 12),
           st.sampled_from([2, 4]), st.integers(2, 5), st.integers(1, 3),
           st.integers(5, 50))
    def test_pool_program_property(seed, num_pages, page_size,
                                   pages_per_seq, max_batch, n_ops):
        """Allocator invariants under arbitrary admit/feed/release
        interleavings: the free list / cached LRU / mapped set partition
        the pool (no double-free, no lost pages), refcounts equal table
        references, and shared-prefix pages are never in-place write
        targets — checked after every single operation."""
        _run_pool_program(seed, num_pages, page_size, pages_per_seq,
                          max_batch, n_ops)
