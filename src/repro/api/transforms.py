"""Composable pytree transforms between SONIQ lifecycle phases.

State-level (the public ``soniq`` surface):

    init / init_linear   build a SoniqState in the phase its config selects
    apply                forward pass (dispatches LM / CNN / single linear)
    to_qat               Phase I -> Phase II  (Problem-1 + PatternMatch +
                         precision freeze; host-side)
    to_serve             Phase II -> deployment (rebudget -> channel
                         reorder -> bit-pack)

Pytree-level building blocks (same transforms without the SoniqState
wrapper — what the train loop and the decode engine compose):

    freeze_qat           (noise params, qcfg) -> (qat params, report)
    rebudget_pbits       project trained per-group precisions onto the
                         static segment budget (scan groups must share
                         packed shapes)
    pack_linear          (w, pbits) -> packed serve leaf  [K, N]
    pack_conv            (w, pbits) -> packed serve leaf  [kh, kw, Cin, Cout]
    convert_linear       rebudget + pack one linear leaf
    convert_tree         walk a whole QAT pytree (stacked scan/expert dims
                         and conv leaves included)

These absorb the converters that used to live in ``repro.core.smol``
(``serve_params_from_qat``) and ``repro.serve.engine`` (``rebudget_pbits``,
``serve_convert``); the old names remain as deprecation shims.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend import registry as backend_registry
from repro.core import patterns as patterns_lib
from repro.core import quant
from repro.core import schedule as schedule_lib
from repro.core import smol
from repro.core.phases import Phase
from repro.core.qtypes import QuantConfig
from repro.models import cnn, lm

from .state import LinearSpec, SoniqState

average_bpp = schedule_lib.average_bpp


# ---------------------------------------------------------------------------
# Config helpers.
# ---------------------------------------------------------------------------

def with_phase(cfg, phase):
    """Copy of a QuantConfig / ArchConfig / CNNConfig / LinearSpec with the
    given lifecycle phase applied (string or Phase object)."""
    phase = Phase.from_mode(phase)
    if isinstance(cfg, QuantConfig):
        return cfg.with_mode(phase)
    if hasattr(cfg, "with_quant_mode"):      # ArchConfig
        return cfg.with_quant_mode(phase)
    return dataclasses.replace(cfg, quant=cfg.quant.with_mode(phase))


# ---------------------------------------------------------------------------
# State lifecycle.
# ---------------------------------------------------------------------------

def init(model_cfg, qcfg: Optional[QuantConfig] = None, *, rng) -> SoniqState:
    """Build a :class:`SoniqState` in the phase its quant config selects.

    ``model_cfg`` is an ``ArchConfig`` (LM), ``CNNConfig`` or
    :class:`LinearSpec`; ``qcfg`` (optional) overrides its quant field.
    """
    if qcfg is not None:
        model_cfg = dataclasses.replace(model_cfg, quant=qcfg)
    phase = model_cfg.quant.phase
    if isinstance(model_cfg, LinearSpec):
        params = smol.linear_init(rng, model_cfg.k, model_cfg.n,
                                  model_cfg.quant,
                                  use_bias=model_cfg.use_bias)
    elif isinstance(model_cfg, cnn.CNNConfig):
        params = cnn.cnn_init(rng, model_cfg)
    else:
        params = lm.init_params(rng, model_cfg)
    return SoniqState(phase, params, model_cfg)


def init_linear(rng, k: int, n: int, qcfg: QuantConfig, *,
                use_bias: bool = False) -> SoniqState:
    """Single-SmolLinear state (quickstart / unit tests)."""
    return init(LinearSpec(k=k, n=n, use_bias=use_bias, quant=qcfg), rng=rng)


def apply(state: SoniqState, x=None, *, rng: Optional[jax.Array] = None,
          **inputs):
    """Forward pass of a state in its current phase.

    * LinearSpec: ``apply(state, x)`` -> ``[..., N]``
    * CNNConfig:  ``apply(state, images)`` -> logits
    * ArchConfig: ``apply(state, tokens)`` (or ``embeds=/frames=/
      positions=`` keywords) -> fp32 logits ``[B, S, V]``
    """
    cfg = state.forward_cfg
    if isinstance(state.model_cfg, LinearSpec):
        return smol.linear_apply(state.params, x, cfg.quant, rng)
    if isinstance(state.model_cfg, cnn.CNNConfig):
        return cnn.cnn_apply(state.params, x, cfg, rng)
    hidden, _ = lm.forward(
        state.params, cfg, tokens=inputs.get("tokens", x),
        embeds=inputs.get("embeds"), frames=inputs.get("frames"),
        positions=inputs.get("positions"), rng=rng)
    return lm.logits(state.params, cfg, hidden)


def to_qat(state: SoniqState) -> Tuple[SoniqState, Dict]:
    """Phase I -> Phase II boundary: freeze trained ``s`` into per-group
    ``pbits`` (Problem-1 solve + PatternMatch; host-side, not jittable).
    Returns (qat_state, pattern_report)."""
    if state.phase is not Phase.NOISE:
        raise ValueError(f"to_qat expects {Phase.NOISE!r}, got "
                         f"{state.phase!r}")
    params, report = freeze_qat(jax.device_get(state.params), state.qcfg)
    return state.replace(phase=Phase.QAT, params=params), report


def to_serve(state: SoniqState, *, rebudget="auto") -> SoniqState:
    """Phase II -> deployment: rebudget (where packed shapes must be
    shared), reorder channels (paper Obs. 4) and bit-pack every quantized
    leaf. Host-side. ``rebudget``: True (always), False (never — trained
    precisions kept verbatim; stacked trees then require identical
    per-slice distributions) or "auto" (only stacked scan/expert leaves,
    whose packed buffers must share shapes)."""
    if state.phase is not Phase.QAT:
        raise ValueError(f"to_serve expects {Phase.QAT!r}, got "
                         f"{state.phase!r}")
    sp = convert_tree(jax.device_get(state.params), state.model_cfg.quant,
                      rebudget=rebudget)
    return state.replace(phase=Phase.SERVE, params=sp)


# ---------------------------------------------------------------------------
# Pytree-level transforms.
# ---------------------------------------------------------------------------

def tree_map_layers(fn, tree):
    """Map ``fn`` over every dict node of a params pytree (returning a new
    dict stops recursion into that node) — the layer-walking primitive the
    lifecycle transforms are built on."""
    return smol._tree_map_dicts(fn, tree)


def freeze_qat(params, qcfg: QuantConfig) -> Tuple[Any, Dict]:
    """(noise params, qcfg) -> (qat params, pattern report). Wraps the
    Phase I -> II boundary transform (paper Alg. 3)."""
    return schedule_lib.pattern_match_params(params, qcfg)


def rebudget_pbits(pbits: np.ndarray, w: np.ndarray,
                   qcfg: QuantConfig) -> np.ndarray:
    """Project trained per-group precisions onto the static segment budget
    (counts from qcfg.mix) preserving the trained ranking; ties broken by
    group abs-max (importance proxy). Identity when the trained
    distribution already matches the budget counts."""
    n = pbits.shape[0]
    k = w.shape[0]
    g = k // n
    counts = qcfg.group_pbits(k)
    n4 = int((counts == 4).sum())
    n2 = int((counts == 2).sum())
    mag = np.abs(w).reshape(n, g, -1).max(axis=(1, 2))
    order = np.lexsort((-mag, -pbits.astype(np.int64)))  # pbits desc, mag desc
    out = np.empty(n, np.int8)
    out[order[:n4]] = 4
    out[order[n4:n4 + n2]] = 2
    out[order[n4 + n2:]] = 1
    return out


def pack_linear(params: Dict, qcfg: QuantConfig) -> Dict:
    """Offline deploy conversion of one [K, N] linear: trained (w, pbits)
    -> channel-reordered packed buffers + metadata. The returned dict is a
    valid SmolLinear serve params pytree (Phase.SERVE.param_schema)."""
    w = np.asarray(params["w"], np.float32)
    pbits = np.asarray(params["pbits"])
    k, _ = w.shape
    g = qcfg.eff_group_size(k)
    gperm = patterns_lib.reorder_channels(pbits)
    perm = patterns_lib.expand_group_perm(gperm, g)
    w_sorted = w[perm]
    pbits_sorted = pbits[gperm]
    if qcfg.scale_mode == "none":
        scales = None
    else:
        scales = np.asarray(quant.per_group_weight_scale(
            jnp.asarray(w_sorted), g))
    # Deploy-time packing runs on the configured kernel backend (fused
    # quantize+pack on Pallas; jnp on xla_ref — identical uint8 codes).
    backend = backend_registry.resolve(qcfg.backend_name)
    packed = backend.quantize_pack_mixed(jnp.asarray(w_sorted),
                                         pbits_sorted, scales, g)
    out = {
        "w4": packed["w4"], "w2": packed["w2"], "w1": packed["w1"],
        "perm": jnp.asarray(perm, jnp.int32),
        "pbits_sorted": jnp.asarray(pbits_sorted),
        "wscale": None if scales is None else jnp.asarray(scales),
    }
    if "b" in params:
        out["b"] = jnp.asarray(params["b"])
    return out


def pack_conv(params: Dict, qcfg: QuantConfig) -> Dict:
    """Deploy conversion of one conv [kh, kw, Cin, Cout] quantized along
    Cin (paper's input-channel granularity). Packed buffers keep the
    spatial/output structure ([rows, kh, kw, Cout]) so the serve forward
    can reconstruct the kernel without extra metadata."""
    w = np.asarray(params["w"], np.float32)
    kh, kw, cin, cout = w.shape
    w2d = {"w": np.moveaxis(w, 2, 0).reshape(cin, -1),
           "pbits": params["pbits"]}
    out = pack_linear(w2d, qcfg)
    for name in ("w4", "w2", "w1"):
        out[name] = out[name].reshape((-1, kh, kw, cout))
    if "b" in params:
        out["b"] = jnp.asarray(params["b"])
    return out


def convert_linear(params: Dict, qcfg: QuantConfig, *,
                   rebudget: bool = True) -> Dict:
    """Rebudget (optional) + pack one [K, N] linear leaf."""
    w = np.asarray(params["w"], np.float32)
    pbits = np.asarray(params["pbits"])
    if rebudget:
        pbits = rebudget_pbits(pbits, w, qcfg)
    leaf = {"w": w, "pbits": pbits}
    if params.get("b") is not None:
        leaf["b"] = params["b"]
    return pack_linear(leaf, qcfg)


def convert_tree(params, qcfg: QuantConfig, *, rebudget="auto"):
    """QAT pytree -> serve pytree. Handles stacked scan/expert leading dims
    (packed per slice then re-stacked — these are always rebudgeted unless
    ``rebudget=False``, since slices must share packed shapes) and conv
    leaves ([kh, kw, Cin, Cout] with 1-D pbits)."""
    assert rebudget in (True, False, "auto"), rebudget

    def fix(node):
        if not (isinstance(node, dict) and "w" in node and "pbits" in node):
            return node
        w = np.asarray(node["w"])
        pb = np.asarray(node["pbits"])
        b = np.asarray(node["b"]) if "b" in node else None
        if w.ndim == 4 and pb.ndim == 1:          # conv [kh, kw, Cin, Cout]
            leaf = {"w": w, "pbits": rebudget_pbits(
                pb, np.moveaxis(w, 2, 0).reshape(w.shape[2], -1), qcfg)
                if rebudget is True else pb}
            if b is not None:
                leaf["b"] = b
            return pack_conv(leaf, qcfg)
        if w.ndim == 2:
            leaf = {"w": w, "pbits": pb, "b": b}
            return convert_linear(leaf, qcfg, rebudget=rebudget is True)
        # Stacked scan/expert dims: pack per slice, re-stack.
        reb = rebudget in (True, "auto")
        lead = w.shape[:-2]
        flat_w = w.reshape((-1,) + w.shape[-2:])
        flat_pb = pb.reshape((-1, pb.shape[-1]))
        flat_b = b.reshape((-1, b.shape[-1])) if b is not None else None
        converted = [
            convert_linear({"w": flat_w[i], "pbits": flat_pb[i],
                            "b": None if flat_b is None else flat_b[i]},
                           qcfg, rebudget=reb)
            for i in range(flat_w.shape[0])]
        return jax.tree.map(lambda *xs: jnp.stack(xs).reshape(
            lead + xs[0].shape), *converted)

    return tree_map_layers(fix, params)
