"""Roofline report over the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh):
    compute term    = corrected dot FLOPs / (197 TFLOP/s)      [per chip]
    memory term     = corrected bytes      / (819 GB/s)
    collective term = corrected coll bytes / (50 GB/s/link)
(all per-device — the HLO is post-SPMD), dominant term, MODEL_FLOPS/HLO
ratio, and the MFU bound implied by the dominant term.

"corrected" = trip-count-corrected per launch/hlo_cost.py (XLA's aggregate
cost_analysis counts scan bodies once; we re-walk the call graph with
known_trip_count).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (conservative single-link)

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def model_flops(cell: Dict) -> float:
    """Analytic useful FLOPs per device: 6*N_active*tokens (train) or
    2*N_active*tokens (inference)."""
    n = cell["active_params"]
    toks = SHAPE_TOKENS[cell["shape"]]
    mult = 6.0 if cell["kind"] == "train" else 2.0
    return mult * n * toks / cell["devices"]


def roofline_row(cell: Dict) -> Optional[Dict]:
    if "skipped" in cell or "error" in cell:
        return None
    c = cell["corrected"]
    t_compute = c["dot_flops"] / PEAK_FLOPS
    t_mem = c["bytes_accessed"] / HBM_BW
    t_coll = sum(c["collective_bytes"].values()) / ICI_BW
    terms = {"compute": t_compute, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]
    mf = model_flops(cell)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_ratio": mf / max(c["dot_flops"], 1.0),
        "mfu_bound": (mf / PEAK_FLOPS) / max(bound, 1e-12),
        "hbm_gib": cell["memory"].get("argument_size_in_bytes", 0) / 2**30
        + cell["memory"].get("temp_size_in_bytes", 0) / 2**30,
        "fallbacks": len(cell.get("fallbacks", [])),
    }


def load(dirname: str = "results/dryrun") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        cell = json.load(open(f))
        r = roofline_row(cell)
        if r is None:
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh"],
                         "skipped": cell.get("skipped",
                                             "error")[:40]})
        else:
            rows.append(r)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'dom':>6s} {'MFUbnd':>7s} "
           f"{'6ND/HLO':>8s} {'HBM GiB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                         f"SKIP: {r['skipped']}")
            continue
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:10.4g} {r['t_memory_s']:10.4g} "
            f"{r['t_collective_s']:10.4g} {r['dominant'][:6]:>6s} "
            f"{r['mfu_bound']:7.3f} {r['model_flops_ratio']:8.3f} "
            f"{r['hbm_gib']:8.2f}")
    return "\n".join(lines)


def main(dirname: str = "results/dryrun"):
    from . import _common
    rows = load(dirname)
    for r in rows:
        if "skipped" in r:
            _common.csv_row(
                f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
                f"skipped={r['skipped']}")
        else:
            _common.csv_row(
                f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}", 0.0,
                f"t_compute={r['t_compute_s']:.4g}"
                f"|t_memory={r['t_memory_s']:.4g}"
                f"|t_coll={r['t_collective_s']:.4g}"
                f"|dominant={r['dominant']}"
                f"|mfu_bound={r['mfu_bound']:.3f}"
                f"|model_flops_ratio={r['model_flops_ratio']:.3f}")
    return rows


if __name__ == "__main__":
    print(format_table(load()))
