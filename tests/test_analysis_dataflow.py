"""repro.analysis.dataflow: the interprocedural scale-dataflow pass
(SQ008) catches the cross-function unclamped divides the intraprocedural
SQ002 cannot see, stays quiet when any path clamps, propagates through
returns / call arguments / dict packing / closures, honors per-site
suppressions, and runs clean on the committed tree (DESIGN.md §16)."""
import textwrap
from pathlib import Path

from repro.analysis import dataflow

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"


def _analyze(code, path="mod.py"):
    return dataflow.analyze_source(textwrap.dedent(code), path)


def _codes(result):
    return sorted(v.code for v in result.findings)


# ------------------------------------------------ the SQ002 gap closes ----

def test_cross_function_unclamped_divide_is_flagged():
    """The mutant SQ002 misses: producer and divider live in different
    functions, so no single function contains both the abs-max and the
    divide."""
    r = _analyze("""
        import jax.numpy as jnp

        def make_scale(x):
            return jnp.max(jnp.abs(x), axis=-1, keepdims=True)

        def quantize(x):
            s = make_scale(x)
            return x / s
    """)
    assert _codes(r) == ["SQ008"]
    assert "no ACT_SCALE_EPS clamp" in r.findings[0].message


def test_intraprocedural_sq002_cases_not_duplicated():
    """Same-function abs-max divides are SQ002's beat; the dataflow pass
    still sees them (same lattice), which is fine — but the clamped form
    must be quiet in both."""
    r = _analyze("""
        import jax.numpy as jnp

        def make_scale(x):
            return jnp.maximum(jnp.max(jnp.abs(x), axis=-1,
                                       keepdims=True), 1e-6)

        def quantize(x):
            return x / make_scale(x)
    """)
    assert r.ok


def test_clamped_at_use_site_is_quiet():
    r = _analyze("""
        import jax.numpy as jnp

        def make_scale(x):
            return jnp.max(jnp.abs(x), axis=-1, keepdims=True)

        def quantize(x, eps):
            s = jnp.maximum(make_scale(x), eps)
            return x / s
    """)
    assert r.ok


def test_raw_scale_into_dividing_callee_param():
    """The other direction: the raw scale is *passed into* a function
    that divides by its parameter."""
    r = _analyze("""
        import jax.numpy as jnp

        def apply_scale(x, s):
            return x / s

        def quantize(x):
            s = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
            return apply_scale(x, s)
    """)
    assert _codes(r) == ["SQ008"]
    assert "apply_scale" in r.findings[0].message
    assert "'s'" in r.findings[0].message


def test_reciprocal_multiply_counts_as_divide():
    r = _analyze("""
        import jax.numpy as jnp

        def make_scale(x):
            return jnp.max(jnp.abs(x), axis=-1, keepdims=True)

        def quantize(x):
            return x * jnp.reciprocal(make_scale(x))
    """)
    assert _codes(r) == ["SQ008"]


def test_dict_pytree_packing_propagates():
    r = _analyze("""
        import jax.numpy as jnp

        def pack(x):
            return {"scale": jnp.max(jnp.abs(x)), "data": x}

        def unpack_and_divide(x):
            st = pack(x)
            return x / st["scale"]
    """)
    assert _codes(r) == ["SQ008"]


def test_closure_propagates():
    r = _analyze("""
        import jax.numpy as jnp

        def outer(x):
            s = jnp.max(jnp.abs(x))

            def inner(y):
                return y / s

            return inner(x)
    """)
    assert _codes(r) == ["SQ008"]


def test_stop_gradient_keeps_taint():
    r = _analyze("""
        import jax
        import jax.numpy as jnp

        def make_scale(x):
            return jax.lax.stop_gradient(jnp.max(jnp.abs(x)))

        def quantize(x):
            return x / make_scale(x)
    """)
    assert _codes(r) == ["SQ008"]


def test_non_scale_divide_is_quiet():
    r = _analyze("""
        def mean(x, n):
            return x / n
    """)
    assert r.ok


# ------------------------------------------------------- suppressions ----

def test_sq008_suppression_honored_with_reason():
    r = _analyze("""
        import jax.numpy as jnp

        def make_scale(x):
            return jnp.max(jnp.abs(x))

        def quantize(x):
            return x / make_scale(x)  # soniq-lint: disable=SQ008(padded rows impossible here)
    """)
    assert r.ok
    assert [s.code for s in r.suppressed] == ["SQ008"]
    assert r.suppressed[0].reason == "padded rows impossible here"


def test_stale_sq008_suppression_becomes_sq007():
    r = _analyze("""
        def harmless(x, n):
            return x / n  # soniq-lint: disable=SQ008(stale claim)
    """)
    assert _codes(r) == ["SQ007"]
    assert "SQ008 does not fire" in r.findings[0].message


# ----------------------------------------------------------- repo-wide ----

def test_repo_src_tree_is_clean():
    """The committed tree has no cross-function unclamped scale divides —
    the same gate CI's static-analysis leg enforces."""
    r = dataflow.analyze_paths([SRC_ROOT])
    assert r.ok, "\n".join(v.format() for v in r.findings)
